"""Paper Fig. 4(a): adaptivity ablation — non-adaptive uniform sampling has
poor accuracy even at multiples of BMO-NN's coordinate budget."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, set_accuracy
from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle
from repro.core.datasets import DenseDataset
from repro.data.synthetic import make_knn_benchmark_data
from repro.kernels import ops as kops


def uniform_knn(corpus, queries, k, budget_per_query, block, rng):
    """Fig. 1(b): estimate every θ_i with an equal number of samples, then
    take the top-k of the estimates."""
    ds = DenseDataset.build(corpus, block)
    qs = ds.pad_query(jnp.asarray(queries))
    n = ds.n
    pulls_per_arm = max(int(budget_per_query / (n * block)), 1)
    out = []
    for qi in range(queries.shape[0]):
        rng, sub = jax.random.split(rng)
        blk = jax.random.randint(sub, (n, pulls_per_arm), 0, ds.n_blocks)
        vals = kops.block_pull(ds.x, qs[qi], jnp.arange(n), blk,
                               block=block, metric="l2", impl="ref")
        est = vals.mean(axis=1)
        out.append(jax.lax.top_k(-est, k)[1])
    return jnp.stack(out)


def main(n: int = 2000, d: int = 4096, Q: int = 6, k: int = 5):
    corpus, queries = make_knn_benchmark_data("dense", n, d, Q, seed=11)
    ex = oracle.exact_knn(corpus, queries, k, "l2")
    cfg = BMOConfig(k=k, delta=0.01, block=128, batch_arms=32, metric="l2")
    res = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(0))
    bmo_acc = set_accuracy(res.indices, ex.indices)
    budget = float(np.mean(np.asarray(res.coord_ops)))
    emit("fig4a_bmo", 0.0, f"acc={bmo_acc:.3f} budget={budget:.0f}")
    for mult in (1, 2, 4):
        t0 = time.perf_counter()
        uni = uniform_knn(corpus, queries, k, budget * mult, cfg.block,
                          jax.random.PRNGKey(1))
        dt = (time.perf_counter() - t0) * 1e6 / Q
        acc = set_accuracy(uni, ex.indices)
        emit(f"fig4a_uniform_{mult}x", dt, f"acc={acc:.3f}")


if __name__ == "__main__":
    main()
