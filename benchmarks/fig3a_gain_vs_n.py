"""Paper Fig. 3(a): gain vs number of points n — the paper observes the gain
is roughly flat in n (BMO-NN's savings come from the d-subsampling)."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, set_accuracy
from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle
from repro.data.synthetic import make_knn_benchmark_data


def main(ns=(1000, 2000, 4000), d: int = 4096, Q: int = 8, k: int = 5):
    gains = []
    for n in ns:
        corpus, queries = make_knn_benchmark_data("dense", n, d, Q, seed=n)
        ex = oracle.exact_knn(corpus, queries, k, "l2")
        cfg = BMOConfig(k=k, delta=0.01, block=128, batch_arms=32,
                        pulls_per_round=2, metric="l2")
        t0 = time.perf_counter()
        res = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(0))
        dt = (time.perf_counter() - t0) * 1e6 / Q
        acc = set_accuracy(res.indices, ex.indices)
        gain = float(Q * n * d / np.sum(np.asarray(res.coord_ops)))
        gains.append(gain)
        emit(f"fig3a_n{n}", dt, f"gain={gain:.1f}x acc={acc:.3f}")
    spread = max(gains) / max(min(gains), 1e-9)
    emit("fig3a_flatness", 0.0, f"max/min_gain_ratio={spread:.2f}")


if __name__ == "__main__":
    main()
