"""Fig. 8 (ours, beyond-paper): index-serving throughput — the epoch-fused,
survivor-compacted driver (DESIGN.md §4) vs the PR-1 per-round batched
driver vs the per-query ``lax.map`` baseline, same corpus, same box, same
exactness.

The PR-1 driver pays one kernel launch and O(Q·n) bookkeeping (CI radii,
top-k selection, acceptance masks) *every round*, even late in the race when
nearly every arm is rejected. The fused driver runs R rounds per launch
(on-chip Welford, double-buffered corpus DMA), runs acceptance only at epoch
boundaries, and compacts the survivor frontier into shrinking power-of-two
buckets — bookkeeping scales with survivors, not n.

Acceptance bar: ≥ 2× queries/sec over the PR-1 driver at Q=32, n=16384,
d=4096 on CPU. Results are emitted both as the CSV convention
(benchmarks/common.py) and as machine-readable ``BENCH_fig8.json``
(qps / rounds / coord_ops per entry) so the perf trajectory is diffable
across PRs.

    PYTHONPATH=src python -m benchmarks.fig8_batched_serve            # full
    PYTHONPATH=src python -m benchmarks.fig8_batched_serve --smoke    # CI
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from benchmarks.common import emit, set_accuracy
from repro.api import Index
from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle
from repro.data.synthetic import make_knn_benchmark_data


def _time(fn, reps: int, Q: int = 0):
    """(seconds per call, last result, per-query latency histogram) — the
    timed calls double as the stats source, no extra un-timed race. The
    per-rep per-query walls land in an obs Histogram so the JSON entries
    carry the same quantile estimator serving reports."""
    from repro.obs import ObsContext
    jax.block_until_ready(fn().values)     # warm (compile), fully drained
    hist = ObsContext("fig8", enabled=False).registry.histogram(
        "repro_bench_query_ms", "per-query bench latency (ms)")
    t0 = time.perf_counter()
    for _ in range(max(reps, 1)):
        t1 = time.perf_counter()
        res = fn()
        jax.block_until_ready(res.values)
        if Q:
            hist.observe((time.perf_counter() - t1) * 1e3 / Q)
    return (time.perf_counter() - t0) / reps, res, hist


def _bench(fn, mode: str, Q: int, reps: int, exact_idx):
    """One timed entry — every driver row in BENCH_fig8.json shares this
    shape, so a field/unit change cannot drift between modes."""
    t, res, hist = _time(fn, reps, Q=Q)
    return {
        "mode": mode,
        "time_per_query_us": t * 1e6 / Q,
        "qps": Q / t,
        "latency_p50_ms": hist.quantile(0.50),
        "latency_p95_ms": hist.quantile(0.95),
        "latency_p99_ms": hist.quantile(0.99),
        "mean_rounds": float(np.mean(np.asarray(res.rounds))),
        "coord_ops": float(np.sum(np.asarray(res.coord_ops))),
        "acc": set_accuracy(res.indices, exact_idx),
    }


def _bench_mode(handle: Index, queries, mode: str, Q: int, reps: int,
                exact_idx):
    # cache bypassed: the bench measures the racing drivers, not the LRU
    fn = lambda: handle.query(queries, jax.random.PRNGKey(1), mode=mode,
                              cache="bypass")
    return _bench(fn, mode, Q, reps, exact_idx)


# (Q, n) grid, R sweep, d, reps, lax.map baseline per preset. "quick" is the
# benchmarks/run.py harness entry (old fig8 scale, no JSON unless asked);
# "smoke" is the CI step; "full" is the committed-evidence run. The
# "sharded_*" presets bench the mesh-spanning ShardedIndexStore (DESIGN.md
# §5) against the single-shard fused driver — they need
# max(shard_grid) visible devices (CI forces a host-platform mesh).
PRESETS = {
    "smoke": dict(d=1024, reps=1, with_permap=True,
                  qn_grid=[(8, 1024)], r_grid=[2, 4]),
    "quick": dict(d=4096, reps=2, with_permap=True,
                  qn_grid=[(32, 4096)], r_grid=[]),
    "full": dict(d=4096, reps=2, with_permap=False,
                 qn_grid=[(8, 4096), (32, 4096), (32, 16384)],
                 r_grid=[1, 2, 4, 8]),
    "sharded_smoke": dict(d=1024, reps=1, qn_grid=[(8, 1024)],
                          shard_grid=[2, 4]),
    "sharded_full": dict(d=4096, reps=2, qn_grid=[(32, 16384)],
                         shard_grid=[1, 2, 4, 8]),
}


def _sharded_sweep(p, k: int, reps: int, out: str):
    """Sharded columns: the single-shard fused driver vs the sharded index
    at each shard count, same corpus/box/exactness. Per entry: qps, rounds,
    coord_ops, per-shard balance (live slots + coordinate-ops per shard),
    and the handle's typed ServeStats snapshot."""
    import jax

    from repro.index.placement import balance

    d = p["d"]
    entries = []
    for Q, n_ in p["qn_grid"]:
        corpus, queries = make_knn_benchmark_data("dense", n_, d, Q, seed=8)
        ex = oracle.exact_knn(corpus, queries, k, "l2")
        cfg = BMOConfig(k=k, delta=0.01, block=128, batch_arms=32,
                        pulls_per_round=2, metric="l2")
        handle = Index.build(corpus, cfg, jax.random.PRNGKey(0))
        row = _bench_mode(handle, queries, "fused", Q, reps, ex.indices)
        row.update(Q=Q, n=n_, d=d, R=cfg.epoch_rounds, shards=1)
        entries.append(row)
        base_qps = row["qps"]
        emit(f"fig8_fused_single_Q{Q}_n{n_}", row["time_per_query_us"],
             f"qps={row['qps']:.1f} acc={row['acc']:.3f}")
        for S in p["shard_grid"]:
            sharded = Index.build(corpus, cfg, jax.random.PRNGKey(0),
                                  shards=S)
            row_of = np.full(sharded.capacity, -1)
            row_of[sharded.build_gids] = np.arange(n_)
            fn = lambda: sharded.query(queries, jax.random.PRNGKey(1),
                                       cache="bypass")
            row = _bench(fn, f"sharded{S}", Q, reps, ex.indices)
            res = fn()       # acc recomputed below through the gid map
            rows = row_of[np.asarray(res.indices)]
            row["acc"] = float(np.mean(
                [set(rows[i].tolist())
                 == set(np.asarray(ex.indices[i]).tolist())
                 for i in range(Q)]))
            row.update(
                Q=Q, n=n_, d=d, R=cfg.epoch_rounds, shards=S,
                speedup_vs_single=row["qps"] / base_qps,
                shard_balance=balance(sharded.store.live_per_shard),
                shard_live=sharded.store.live_per_shard,
                shard_coord_ops=res.shard_coord_ops,
                shard_rounds=res.shard_rounds,
                serve_stats=sharded.stats.as_dict(),
            )
            entries.append(row)
            emit(f"fig8_sharded{S}_Q{Q}_n{n_}", row["time_per_query_us"],
                 f"qps={row['qps']:.1f} acc={row['acc']:.3f} "
                 f"vs_single={row['speedup_vs_single']:.2f}x "
                 f"balance={row['shard_balance']:.2f}")
    if out:
        payload = {
            "bench": "fig8_batched_serve_sharded",
            "backend": jax.default_backend(),
            "devices": jax.device_count(),
            "d": d, "k": k, "reps": reps,
            "entries": entries,
        }
        with open(out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {out} ({len(entries)} entries)")


def main(preset: str = "quick", k: int = 5, out: str = "",
         reps: int = 0, with_permap: bool = False):
    p = PRESETS[preset]
    if "shard_grid" in p:
        return _sharded_sweep(p, k, reps or p["reps"], out)
    d = p["d"]
    reps = reps or p["reps"]
    with_permap = with_permap or p["with_permap"]
    qn_grid, r_grid = p["qn_grid"], p["r_grid"]

    entries = []
    data = {}               # (Q, n) -> (corpus, queries, store-less exact)

    def get_data(Q, n_):
        if (Q, n_) not in data:
            corpus, queries = make_knn_benchmark_data("dense", n_, d, Q, seed=8)
            ex = oracle.exact_knn(corpus, queries, k, "l2")
            data[(Q, n_)] = (corpus, queries, ex)
        return data[(Q, n_)]

    base_cfg = BMOConfig(k=k, delta=0.01, block=128, batch_arms=32,
                         pulls_per_round=2, metric="l2")

    # ---- (Q, n) sweep: fused vs PR-1 rounds driver -----------------------
    for Q, n_ in qn_grid:
        corpus, queries, ex = get_data(Q, n_)
        store = Index.build(corpus, base_cfg, jax.random.PRNGKey(0))
        if with_permap:
            row_b = _bench(
                lambda: bmo_nn.knn(corpus, queries, base_cfg,
                                   jax.random.PRNGKey(0)),
                "per_query_laxmap", Q, reps, ex.indices)
            row_b.update(Q=Q, n=n_, d=d, R=0)
            entries.append(row_b)
            emit(f"fig8_per_query_laxmap_Q{Q}_n{n_}",
                 row_b["time_per_query_us"],
                 f"qps={row_b['qps']:.1f} acc={row_b['acc']:.3f}")
        row_r = _bench_mode(store, queries, "rounds", Q, reps, ex.indices)
        row_f = _bench_mode(store, queries, "fused", Q, reps, ex.indices)
        # R = 0 marks drivers with no epoch structure (lax.map, rounds)
        row_r.update(Q=Q, n=n_, d=d, R=0)
        row_f.update(Q=Q, n=n_, d=d, R=base_cfg.epoch_rounds)
        entries.extend([row_r, row_f])
        row_f["speedup_vs_rounds"] = row_f["qps"] / row_r["qps"]
        emit(f"fig8_rounds_Q{Q}_n{n_}", row_r["time_per_query_us"],
             f"qps={row_r['qps']:.1f} acc={row_r['acc']:.3f}")
        emit(f"fig8_fused_Q{Q}_n{n_}", row_f["time_per_query_us"],
             f"qps={row_f['qps']:.1f} acc={row_f['acc']:.3f} "
             f"speedup={row_f['speedup_vs_rounds']:.2f}x")

    # ---- R sweep: rounds fused per epoch at the mid shape ----------------
    if r_grid:
        Q, n_ = qn_grid[min(1, len(qn_grid) - 1)]
        corpus, queries, ex = get_data(Q, n_)
        store0 = Index.build(corpus, base_cfg, jax.random.PRNGKey(0))
        for R in r_grid:
            # only the driver reads epoch_rounds — rebind cfg on the
            # wrapped store, reuse the built corpus layout/priors
            store = Index.open(dataclasses.replace(
                store0.store,
                cfg=dataclasses.replace(base_cfg, epoch_rounds=R)))
            row = _bench_mode(store, queries, "fused", Q, reps, ex.indices)
            row.update(Q=Q, n=n_, d=d, R=R)
            entries.append(row)
            emit(f"fig8_fused_R{R}_Q{Q}_n{n_}", row["time_per_query_us"],
                 f"qps={row['qps']:.1f} acc={row['acc']:.3f}")

    if out:
        payload = {
            "bench": "fig8_batched_serve",
            "backend": jax.default_backend(),
            "preset": preset,
            "d": d, "k": k, "reps": reps,
            "entries": entries,
        }
        with open(out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {out} ({len(entries)} entries)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="full",
                    help="smoke = CI shapes (<~60 s), quick = harness "
                         "comparison, full = the committed evidence sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="alias for --preset smoke")
    ap.add_argument("--reps", type=int, default=0,
                    help="0 = the preset's default")
    ap.add_argument("--with-permap", action="store_true",
                    help="also run the per-query lax.map baseline")
    ap.add_argument("--out", default="BENCH_fig8.json",
                    help="JSON output path ('' disables)")
    args = ap.parse_args()
    main(preset="smoke" if args.smoke else args.preset, reps=args.reps,
         with_permap=args.with_permap, out=args.out)
