"""Fig. 8 (ours, beyond-paper): index-serving throughput — cross-query
batched racing (repro.index.batched_race) vs the per-query ``lax.map``
baseline (core.bmo_nn.knn), same corpus, same box, same exactness.

The per-query path's wall-clock is the SUM of per-query round counts and
every round launches a tiny (B, P) pull; the batched path's wall-clock is
the MAX of round counts with one (Q, B, P) launch per round. The acceptance
bar for this figure: ≥ 2× queries/sec at Q=32, n=4096, d=4096 on CPU.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, set_accuracy
from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle
from repro.data.synthetic import make_knn_benchmark_data
from repro.index import build_index, index_knn


def _time(fn, reps: int = 3) -> float:
    fn()                                   # warm (compile)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn().values)
    return (time.perf_counter() - t0) / reps


def main(n: int = 4096, d: int = 4096, Q: int = 32, k: int = 5):
    corpus, queries = make_knn_benchmark_data("dense", n, d, Q, seed=8)
    cfg = BMOConfig(k=k, delta=0.01, block=128, batch_arms=32,
                    pulls_per_round=2, metric="l2")
    ex = oracle.exact_knn(corpus, queries, k, "l2")

    base = lambda: bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(0))
    t_base = _time(base)
    acc_base = set_accuracy(base().indices, ex.indices)

    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    batched = lambda: index_knn(store, queries, jax.random.PRNGKey(1))
    t_batch = _time(batched)
    acc_batch = set_accuracy(batched().indices, ex.indices)

    qps_base = Q / t_base
    qps_batch = Q / t_batch
    emit("fig8_per_query_laxmap", t_base * 1e6 / Q,
         f"qps={qps_base:.1f} acc={acc_base:.3f}")
    emit("fig8_batched_index", t_batch * 1e6 / Q,
         f"qps={qps_batch:.1f} acc={acc_batch:.3f} "
         f"speedup={qps_batch / qps_base:.2f}x")


if __name__ == "__main__":
    main()
