"""Request-plane open-loop serving latency (DESIGN.md §7.5) — the PR-5
serving figure: deadline-bounded p99 under Poisson overload, plane vs the
blocking FIFO baseline. Delegates to ``tools/bench_serve_plane.py`` (the
full evidence run lives there; this registry entry runs the smoke preset
so ``python -m benchmarks.run fig9`` stays minutes-cheap) and emits the
harness CSV convention."""
from __future__ import annotations

import importlib.util
import os

from benchmarks.common import emit

_TOOL = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                     "tools", "bench_serve_plane.py")


def main() -> None:
    spec = importlib.util.spec_from_file_location("bench_serve_plane", _TOOL)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    out = mod.main(["--smoke"])
    base, plane = out["baseline"]["bounded"], out["plane"]["bounded"]
    emit("fig9_baseline_p99_bounded", base["p99_ms"] * 1e3,
         derived=f"p50={base['p50_ms']}ms")
    emit("fig9_plane_p99_bounded", plane["p99_ms"] * 1e3,
         derived=f"speedup={out['speedup_p99_bounded']}x"
                 f";shed={out['plane']['shed_rate']}")


if __name__ == "__main__":
    main()
