"""Paper Fig. 5: BMO k-means — assignment-step gain over exact Lloyd at
matched (>99%) assignment accuracy."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import BMOConfig
from repro.core import kmeans
from repro.data.synthetic import clustered_dense


def main(n: int = 3000, d: int = 8192, k: int = 32, iters: int = 2):
    pts = clustered_dense(n, d, n_clusters=k, noise=0.1, seed=31)
    # small blocks + single-pull init: the per-arm floor cost is 64 coords
    # against the 8192-coord exact distance (the paper's k-means regime has
    # few arms per query, so the init floor dominates the gain cap)
    cfg = BMOConfig(k=1, delta=0.01, block=64, batch_arms=8,
                    pulls_per_round=1, init_pulls=1, metric="l2")
    t0 = time.perf_counter()
    res = kmeans.kmeans(pts, k, iters, cfg, jax.random.PRNGKey(0), use_bmo=True)
    dt = (time.perf_counter() - t0) * 1e6
    # accuracy of the final assignment vs exact assignment to the same centroids
    a_ex, _ = kmeans.assign_exact(pts, res.centroids)
    acc = float(np.mean(np.asarray(res.assignment) == np.asarray(a_ex)))
    gain = float(res.exact_ops / res.coord_ops)
    emit("fig5_kmeans", dt, f"gain={gain:.1f}x assign_acc={acc:.4f} k={k}")


if __name__ == "__main__":
    main()
