"""Paper Fig. 4(b): sparse Monte-Carlo box (§IV-A) on ~7%-dense RNA-seq-like
data, gain measured against the *sparsity-aware* exact ℓ1 baseline."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, set_accuracy
from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle
from repro.core.datasets import SparseDataset
from repro.data.synthetic import clustered_sparse


def main(n: int = 1500, d: int = 8192, Q: int = 6, k: int = 5):
    corpus = clustered_sparse(n, d, sparsity=0.07, seed=21)
    ds = SparseDataset.build(corpus)
    qi, qv, qn = ds.indices[:Q], ds.values[:Q], ds.nnz[:Q]
    ex = oracle.exact_knn_sparse(ds, qi, qv, qn, k)
    cfg = BMOConfig(k=k, delta=0.01, block=1, batch_arms=32,
                    pulls_per_round=8, init_pulls=16, metric="l1", sparse=True)
    t0 = time.perf_counter()
    res = bmo_nn.knn(ds, (qi, qv, qn), cfg, jax.random.PRNGKey(0))
    dt = (time.perf_counter() - t0) * 1e6 / Q
    acc = set_accuracy(res.indices, ex.indices)
    gain = float(ex.coord_ops / np.sum(np.asarray(res.coord_ops)))
    emit("fig4b_sparse", dt, f"gain={gain:.2f}x acc={acc:.3f} "
         f"nnz_frac={float(np.mean(np.asarray(ds.nnz)))/d:.3f}")


if __name__ == "__main__":
    main()
