"""Benchmark harness — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

  PYTHONPATH=src python -m benchmarks.run            # all figures
  PYTHONPATH=src python -m benchmarks.run fig2 fig5  # subset
"""
from __future__ import annotations

import sys
import time
import traceback

BENCHES = ["fig2", "fig3a", "fig4a", "fig4b", "fig5", "fig6", "fig7",
           "fig8", "fig9", "roofline"]


def main() -> None:
    want = sys.argv[1:] or BENCHES
    print("name,us_per_call,derived")
    for name in want:
        mod_name = {
            "fig2": "benchmarks.fig2_gain_vs_d",
            "fig3a": "benchmarks.fig3a_gain_vs_n",
            "fig4a": "benchmarks.fig4a_adaptivity",
            "fig4b": "benchmarks.fig4b_sparse",
            "fig5": "benchmarks.fig5_kmeans",
            "fig6": "benchmarks.fig6_wallclock",
            "fig7": "benchmarks.fig7_rotation",
            "fig8": "benchmarks.fig8_batched_serve",
            "fig9": "benchmarks.fig9_serve_plane",
            "roofline": "benchmarks.roofline_table",
        }[name]
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
            print(f"{name}_total,{(time.time() - t0) * 1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            print(f"{name}_total,{(time.time() - t0) * 1e6:.0f},ERROR:{type(e).__name__}")


if __name__ == "__main__":
    main()
