"""Paper Fig. 2 / Fig. 3(b): BMO-NN gain over exact computation (in
coordinate-wise distance computations) as the dimension d grows.
The paper observes near-linear growth of the gain with d (80× at d=12288 on
Tiny ImageNet); we reproduce the trend on the image-like synthetic corpus."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, set_accuracy
from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle
from repro.data.synthetic import make_knn_benchmark_data


def run(n: int = 3000, Q: int = 8, k: int = 5, dims=(1024, 2048, 4096, 8192),
        eliminate: bool = True, tag: str = "fig2"):
    rows = []
    for d in dims:
        corpus, queries = make_knn_benchmark_data("dense", n, d, Q, seed=d)
        ex = oracle.exact_knn(corpus, queries, k, "l2")
        cfg = BMOConfig(k=k, delta=0.01, block=128, batch_arms=32,
                        pulls_per_round=2, init_pulls=2, metric="l2")
        t0 = time.perf_counter()
        res = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(0),
                         eliminate=eliminate)
        dt = (time.perf_counter() - t0) * 1e6 / Q
        acc = set_accuracy(res.indices, ex.indices)
        gain = float(Q * n * d / np.sum(np.asarray(res.coord_ops)))
        emit(f"{tag}_d{d}", dt, f"gain={gain:.1f}x acc={acc:.3f}")
        rows.append((d, gain, acc))
    return rows


def main():
    rows = run()
    # paper claim: gain increases ~linearly with d
    gains = [g for _, g, _ in rows]
    trend = "increasing" if all(b > a for a, b in zip(gains, gains[1:])) else "mixed"
    emit("fig2_trend", 0.0, f"gain_vs_d={trend}")


if __name__ == "__main__":
    main()
