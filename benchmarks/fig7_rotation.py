"""Paper Fig. 7 / Lemma 3: the randomized Hadamard rotation lightens the
coordinate-distance tails (smaller ‖x−y‖∞²·d / ‖x−y‖₂² ratio → smaller
sub-Gaussian constant → fewer pulls)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, set_accuracy
from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle
from repro.core.datasets import hadamard_rotate
from repro.data.synthetic import make_knn_benchmark_data


def tail_ratio(x: np.ndarray, pairs: int = 64, seed: int = 0) -> float:
    """E[ d·max_j (x_a−x_b)_j² / ‖x_a−x_b‖₂² ] over random pairs — the
    Lemma 3 improvement factor proxy (1 = perfectly flat coordinates)."""
    rng = np.random.default_rng(seed)
    n, d = x.shape
    vals = []
    for _ in range(pairs):
        a, b = rng.integers(0, n, 2)
        diff2 = (x[a] - x[b]) ** 2
        denom = diff2.sum()
        if denom > 0:
            vals.append(d * diff2.max() / denom)
    return float(np.mean(vals))


def spiky(n: int, d: int, seed: int = 0) -> np.ndarray:
    """Image-like coordinate structure: per-coordinate scales are lognormal
    (a few coordinates carry most of the pairwise distance — the regime
    Lemma 3 targets; i.i.d. Gaussian coordinates are already flat and show
    no rotation benefit)."""
    rng = np.random.default_rng(seed)
    scales = rng.lognormal(0.0, 1.6, size=(1, d)).astype(np.float32)
    centers = rng.normal(size=(16, d)).astype(np.float32) * scales
    assign = rng.integers(0, 16, n)
    pts = centers[assign] + 0.2 * scales * rng.normal(size=(n, d)).astype(np.float32)
    return pts.astype(np.float32)


def main(n: int = 1000, d: int = 4096, Q: int = 6, k: int = 5):
    rng = np.random.default_rng(51)
    corpus = spiky(n, d, seed=51)
    qidx = rng.integers(0, n, Q)
    queries = corpus[qidx] + 0.02 * rng.normal(size=(Q, d)).astype(np.float32)
    both = jnp.concatenate([jnp.asarray(corpus), jnp.asarray(queries)], 0)
    rot, _ = hadamard_rotate(both, jax.random.PRNGKey(0), use_kernel="ref")
    rot = np.asarray(rot)
    r_before = tail_ratio(corpus)
    r_after = tail_ratio(rot[:n])
    emit("fig7_tail_before", 0.0, f"dmax/l2={r_before:.1f}")
    emit("fig7_tail_after", 0.0, f"dmax/l2={r_after:.1f} "
         f"improvement={r_before / r_after:.1f}x")

    ex = oracle.exact_knn(corpus, queries, k, "l2")
    for rotate in (False, True):
        cfg = BMOConfig(k=k, delta=0.01, block=128, batch_arms=32,
                        metric="l2", rotate=rotate)
        res = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(1))
        acc = set_accuracy(res.indices, ex.indices)
        gain = float(Q * n * d / np.sum(np.asarray(res.coord_ops)))
        emit(f"fig7_knn_rotate{int(rotate)}", 0.0,
             f"gain={gain:.1f}x acc={acc:.3f}")


if __name__ == "__main__":
    main()
