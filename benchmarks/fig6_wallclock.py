"""Paper Fig. 6: wall-clock comparison (paper: BMO-NN 1.5× faster than
sklearn exact, 5× faster than LSH). Here: jit-compiled BMO-NN vs the
XLA-fused exact oracle on this host (CPU — see EXPERIMENTS.md for the
TPU-target roofline treatment)."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, set_accuracy
from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle
from repro.data.synthetic import make_knn_benchmark_data


def main(n: int = 3000, d: int = 8192, Q: int = 8, k: int = 5):
    corpus, queries = make_knn_benchmark_data("dense", n, d, Q, seed=41)

    # exact (warm + timed)
    ex = oracle.exact_knn(corpus, queries, k, "l2")
    t0 = time.perf_counter()
    ex = oracle.exact_knn(corpus, queries, k, "l2")
    jax.block_until_ready(ex.values)
    t_exact = (time.perf_counter() - t0) * 1e6 / Q

    cfg = BMOConfig(k=k, delta=0.01, block=128, batch_arms=32, metric="l2")
    res = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(0))  # warm
    t0 = time.perf_counter()
    res = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(0))
    jax.block_until_ready(res.values)
    t_bmo = (time.perf_counter() - t0) * 1e6 / Q

    acc = set_accuracy(res.indices, ex.indices)
    emit("fig6_exact", t_exact, "")
    emit("fig6_bmo", t_bmo, f"speedup={t_exact / t_bmo:.2f}x acc={acc:.3f}")


if __name__ == "__main__":
    main()
