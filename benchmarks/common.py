"""Shared benchmark helpers. Output convention (benchmarks/run.py):
``name,us_per_call,derived`` CSV rows; `derived` carries the paper metric
(gain in coordinate-wise distance computations, accuracy, etc.)."""
from __future__ import annotations

import time
from typing import Callable

import numpy as np


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def time_call(fn: Callable, *, warmup: int = 0, reps: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def set_accuracy(got_idx, want_idx) -> float:
    got = np.asarray(got_idx)
    want = np.asarray(want_idx)
    return float(np.mean([set(got[i].tolist()) == set(want[i].tolist())
                          for i in range(len(want))]))
