"""Render the roofline table (EXPERIMENTS.md §Roofline) from the dry-run
JSONL results. Also usable as a benchmark row source: emits one CSV line per
cell with the dominant term."""
from __future__ import annotations

import json
import os
from collections import OrderedDict

from benchmarks.common import emit

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun.jsonl")


def load(path: str = DEFAULT_PATH, variant: str = None):
    rows = OrderedDict()
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if variant and r.get("variant") != variant:
                continue
            key = (r["arch"], r["shape"], r["mesh"], r.get("variant", "baseline"))
            rows[key] = r  # last write wins
    return rows


def markdown_table(rows, mesh: str = "single") -> str:
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bottleneck "
           "| useful/HLO | roofline frac | peak GiB/chip | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for (a, s, m, v), r in rows.items():
        if m != mesh:
            continue
        if r.get("status") == "skipped":
            out.append(f"| {a} | {s} | — | — | — | skipped: {r['reason']} | | | | |\n")
            continue
        if r.get("status") != "ok":
            out.append(f"| {a} | {s} | — | — | — | ERROR | | | | |\n")
            continue
        out.append(
            f"| {a} | {s} | {r['t_compute']:.3g} | {r['t_memory']:.3g} | "
            f"{r['t_collective']:.3g} | {r['bottleneck']} | "
            f"{r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.4f} | "
            f"{r['peak_memory_per_chip'] / 2**30:.2f} | "
            f"{'y' if r.get('fits_hbm') else 'OVER'} |\n")
    return "".join(out)


def main():
    rows = load()
    n_ok = sum(1 for r in rows.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in rows.values() if r.get("status") == "skipped")
    n_err = sum(1 for r in rows.values() if r.get("status") == "error")
    emit("roofline_cells", 0.0, f"ok={n_ok} skipped={n_skip} error={n_err}")
    for (a, s, m, v), r in rows.items():
        if r.get("status") == "ok":
            emit(f"roofline_{a}_{s}_{m}_{v}", 0.0,
                 f"bottleneck={r['bottleneck']} frac={r['roofline_fraction']:.4f}")


if __name__ == "__main__":
    main()
