"""BMO k-means (paper §V-A) as a data-pipeline clustering stage: cluster
synthetic embedding vectors with the bandit assignment step and compare the
coordinate-computation budget against exact Lloyd.

    PYTHONPATH=src python examples/kmeans_pipeline.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import BMOConfig
from repro.core import kmeans
from repro.data.synthetic import clustered_dense


def main():
    n, d, k, iters = 3000, 4096, 16, 3
    pts = clustered_dense(n, d, n_clusters=k, noise=0.1, seed=0)
    print(f"clustering {n} x {d} embeddings into {k} clusters, {iters} Lloyd iters")

    cfg = BMOConfig(k=1, delta=0.01, block=128, batch_arms=8, metric="l2")
    t0 = time.time()
    res = kmeans.kmeans(pts, k, iters, cfg, jax.random.PRNGKey(0), use_bmo=True)
    print(f"BMO assignment: {time.time() - t0:.1f}s, "
          f"{float(res.coord_ops):.3g} coordinate computations")
    print(f"exact assignment would cost {float(res.exact_ops):.3g} "
          f"→ gain {float(res.exact_ops / res.coord_ops):.1f}x")

    a_ex, _ = kmeans.assign_exact(pts, res.centroids)
    acc = float(np.mean(np.asarray(res.assignment) == np.asarray(a_ex)))
    print(f"assignment accuracy vs exact: {acc:.4f}")
    sizes = np.bincount(np.asarray(res.assignment), minlength=k)
    print("cluster sizes:", sizes.tolist())


if __name__ == "__main__":
    main()
