"""End-to-end serving driver: a small LM serving batched requests with the
BMO-NN kNN-LM retrieval hook — the paper's technique live in the decode loop.

    PYTHONPATH=src python examples/knn_serve.py

Flow: run the model over a corpus to collect (hidden, next-token) pairs →
**build** a persistent IndexStore from them (blocked layout + CI warm-start
priors, one-time cost) → **save** it through the checkpoint layer →
**load** it back (what a serving replica would do at boot) → **serve**:
every decode step's whole batch races the index in one batched launch
(repro.index.batched_race), and with ``index_append`` the generated tokens
are folded back into the datastore as they are produced.

With ``--shards N`` the walkthrough instead spans ONE index over an
N-device mesh (repro.index.sharded, DESIGN.md §5): build sharded →
save (per-shard checkpoints + manifest) → **reload at a different shard
count** (save at N, load at N//2 — elastic re-sharding with the global-id
remap applied to the payload) → serve with per-shard stats:

    PYTHONPATH=src python examples/knn_serve.py --shards 4
"""
import argparse
import os
import sys
import tempfile
import time

sys.path.insert(0, "src")

_ap = argparse.ArgumentParser()
_ap.add_argument("--shards", type=int, default=0,
                 help=">1: sharded-index walkthrough over this many devices")
ARGS = _ap.parse_args()
if ARGS.shards > 1 and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    # must happen before jax initializes its backends
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count="
                                 f"{ARGS.shards}")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.base import BMOConfig
from repro.data.synthetic import lm_batch
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serve.engine import KNNLMConfig, ServeEngine
from repro.sharding.spec import init_params


def build_datastore(model, params, vocab, n_seqs=8, seq=64):
    """Run the model over a corpus; collect (hidden_t, token_{t+1}) pairs."""
    keys, next_ids = [], []
    for i in range(n_seqs):
        batch = lm_batch(vocab, 1, seq, seed=123, step=i)
        toks = jnp.asarray(batch["tokens"])
        logits, _, hidden = model.apply(params, {"tokens": toks}, remat="none",
                                        return_hidden=True)
        keys.append(np.asarray(hidden[0, :-1].astype(jnp.float32)))
        next_ids.append(np.asarray(batch["tokens"][0, 1:]))
    return (jnp.asarray(np.concatenate(keys)),
            jnp.asarray(np.concatenate(next_ids).astype(np.int32)))


def main():
    entry = get_arch("qwen2.5-14b")
    cfg = entry.smoke                      # reduced config: runs on CPU
    model = build_model(cfg)
    plan = dataclasses.replace(entry.plan, fsdp=False, tp=False, sp=False,
                               param_dtype="float32")
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    mesh = make_host_mesh(1, 1)

    print("building kNN-LM datastore from model hidden states ...")
    datastore = build_datastore(model, params, cfg.vocab_size)
    print(f"datastore: {datastore[0].shape[0]} keys of dim {datastore[0].shape[1]}")

    knn = KNNLMConfig(lam=0.25, index_shards=ARGS.shards, bmo=BMOConfig(
        k=8, delta=0.05, block=16, batch_arms=16, metric="l2"))

    index_dir = tempfile.mkdtemp(prefix="bmo_index_") + "/idx"
    payload = np.asarray(datastore[1], np.int32)
    if ARGS.shards > 1:
        # multi-shard walkthrough: build at S → save (per-shard checkpoints
        # + manifest) → reload RE-SHARDED at S//2 — the returned old→new
        # global-id map realigns the slot-aligned payload
        from repro.index import (build_sharded_index, load_sharded_index,
                                 save_sharded_index)
        store, gids = build_sharded_index(np.asarray(datastore[0]), knn.bmo,
                                          jax.random.PRNGKey(7),
                                          shards=ARGS.shards)
        slot_payload = np.zeros((store.capacity,), np.int32)
        slot_payload[gids] = payload
        save_sharded_index(store, index_dir)
        reload_shards = max(ARGS.shards // 2, 1)
        store, old_ids = load_sharded_index(index_dir, shards=reload_shards)
        remapped = np.zeros((store.capacity,), np.int32)
        live = old_ids >= 0
        remapped[live] = slot_payload[old_ids[live]]
        payload = remapped
        print(f"sharded index: built at S={ARGS.shards}, saved via "
              f"{index_dir}, re-sharded on load to S={store.n_shards} "
              f"(stride {store.stride}, {store.n_live} live slots, "
              f"per-shard {store.live_per_shard})")
    else:
        # build once → save → load (what a serving replica does at boot)
        from repro.index import build_index, load_index, save_index
        store = build_index(datastore[0], knn.bmo, jax.random.PRNGKey(7))
        save_index(store, index_dir)
        store = load_index(index_dir)
        print(f"index: {store.n_live} live slots / capacity "
              f"{store.capacity}, saved+loaded via {index_dir}")

    batch_size, prompt_len, new_tokens = 4, 12, 16
    engine = ServeEngine(model, params, plan, mesh, batch_size=batch_size,
                         max_seq=prompt_len + new_tokens + 4,
                         knn_lm=knn, index=store,
                         datastore=(None, payload),
                         index_append=True)

    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (batch_size, prompt_len)).astype(np.int32)
    t0 = time.time()
    out, retrieval_ops = engine.generate(prompts, new_tokens)
    dt = time.time() - t0
    n_exact = datastore[0].shape[0] * datastore[0].shape[1] * new_tokens * batch_size
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s with retrieval)")
    print(f"retrieval coordinate-ops: {retrieval_ops:.3g} "
          f"(exact search: {float(n_exact):.3g} → "
          f"{float(n_exact) / max(retrieval_ops, 1):.1f}x)")
    print(f"index grew during decode: {engine.index.n_live} live slots "
          f"(+{engine.index.n_live - store.n_live} appended)")
    stats = engine.stats
    if "knn_shard_coord_ops" in stats:
        print(f"per-shard coord-ops: "
              f"{[f'{v:.3g}' for v in stats['knn_shard_coord_ops']]}, "
              f"max rounds {stats['knn_shard_rounds']} "
              f"(near_hits={stats['knn_near_hits']})")
    print("note: at this smoke scale (d=64, n≈500) exact search is cheap; "
          "the bandit gain appears at the paper's d≈4k–28k regime "
          "(see quickstart.py / benchmarks).")
    print("tokens:\n", out)


if __name__ == "__main__":
    main()
