"""End-to-end serving driver: a small LM serving batched requests with the
BMO-NN kNN-LM retrieval hook — the paper's technique live in the decode
loop, driven entirely through the unified ``repro.api`` surface.

    PYTHONPATH=src python examples/knn_serve.py

Flow: run the model over a corpus to collect (hidden, next-token) pairs →
``Index.build`` a persistent index from them with the next-token ids
attached as the handle's payload (blocked layout + CI warm-start priors,
one-time cost) → ``Index.save`` through the checkpoint layer →
``Index.load`` it back (what a serving replica would do at boot; the
payload sidecar rides along) → serve: every decode step's whole batch is
one ``Index.query`` (typed ``QuerySpec`` protocol, query LRU + near-repeat
warm starts behind ``CachePolicy``), and with ``index_append`` the
generated tokens are folded back into the datastore as they are produced
(``CompactionPolicy`` amortizes tombstone debt).

With ``--shards N`` the walkthrough spans ONE index over an N-device mesh
and exercises the PR-4 admin ops on the LIVE handle (DESIGN.md §6.3):
build sharded → save → load → **``Index.reshard(N//2)`` on the running
handle** (no checkpoint round-trip: quiesce → uniform-stride remap → swap
under the epoch fence, payload realigned automatically) →
``Index.add_replicas(2)`` read fan-out → serve with per-shard stats:

    PYTHONPATH=src python examples/knn_serve.py --shards 4

With ``--tune`` the walkthrough adds the PR-7 **self-racing autotuner**
(DESIGN.md §9): ``Index.tune()`` races roofline-pruned candidate configs
on measured wall time, installs the winner under the epoch fence (exact
top-k unchanged), and the ``tuned.json`` sidecar rides ``save``/``load``
so a reloaded replica serves tuned without re-racing:

    PYTHONPATH=src python examples/knn_serve.py --tune

The tail demos the PR-5 **async request plane** (DESIGN.md §7): submit an
anytime ticket against ``engine.plane``, stream certified-prefix partials,
exit early once enough of the answer is certified, then run a
deadline-bounded query that returns its certified prefix at expiry.

With ``--fleet`` the walkthrough adds the PR-9 **namespace fleet**
(DESIGN.md §11): three namespaces on one shared plane with an LRU
residency budget of two — create → query by ``namespace=`` label → force
an eviction → watch the next query reload the checkpoint transparently
with bit-identical top-k → drop one and recover the rest from the
manifest via ``Fleet.open``:

    PYTHONPATH=src python examples/knn_serve.py --fleet
"""
import argparse
import os
import sys
import tempfile
import time

sys.path.insert(0, "src")

_ap = argparse.ArgumentParser()
_ap.add_argument("--shards", type=int, default=0,
                 help=">1: sharded-index walkthrough over this many devices")
_ap.add_argument("--tune", action="store_true",
                 help="PR-7 walkthrough: self-race the autotuner over the "
                      "index (repro.tune, DESIGN.md §9), serve the winning "
                      "config, and round-trip the tuned.json sidecar")
_ap.add_argument("--trace", default="", metavar="PATH",
                 help="PR-6 obs walkthrough: dump the raw trace-event log "
                      "here and a Perfetto-loadable Chrome trace next to it "
                      "(PATH with a .perfetto.json suffix)")
_ap.add_argument("--audit", action="store_true",
                 help="PR-8 walkthrough: shadow δ-audit every certified "
                      "ticket off the critical path, then inject a wrong "
                      "answer below the plane and watch the auditor catch "
                      "it, bundle it, and replay it (DESIGN.md §10)")
_ap.add_argument("--audit-dir", default="", metavar="DIR",
                 help="where --audit writes flight-recorder bundles "
                      "(default: a temp dir)")
_ap.add_argument("--fleet", action="store_true",
                 help="PR-9 walkthrough: a 3-namespace fleet (2 resident) "
                      "on one shared request plane — transparent LRU "
                      "eviction/reload, bit-identical post-reload top-k, "
                      "manifest recovery (repro.fleet, DESIGN.md §11)")
ARGS = _ap.parse_args()
if ARGS.shards > 1 and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    # must happen before jax initializes its backends
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count="
                                 f"{ARGS.shards}")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Index
from repro.configs import get_arch
from repro.configs.base import BMOConfig
from repro.data.synthetic import lm_batch
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serve.engine import KNNLMConfig, ServeEngine
from repro.sharding.spec import init_params


def build_datastore(model, params, vocab, n_seqs=8, seq=64):
    """Run the model over a corpus; collect (hidden_t, token_{t+1}) pairs."""
    keys, next_ids = [], []
    for i in range(n_seqs):
        batch = lm_batch(vocab, 1, seq, seed=123, step=i)
        toks = jnp.asarray(batch["tokens"])
        logits, _, hidden = model.apply(params, {"tokens": toks}, remat="none",
                                        return_hidden=True)
        keys.append(np.asarray(hidden[0, :-1].astype(jnp.float32)))
        next_ids.append(np.asarray(batch["tokens"][0, 1:]))
    return (np.concatenate(keys),
            np.concatenate(next_ids).astype(np.int32))


def main():
    entry = get_arch("qwen2.5-14b")
    cfg = entry.smoke                      # reduced config: runs on CPU
    model = build_model(cfg)
    plan = dataclasses.replace(entry.plan, fsdp=False, tp=False, sp=False,
                               param_dtype="float32")
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    mesh = make_host_mesh(1, 1)

    print("building kNN-LM datastore from model hidden states ...")
    keys, next_ids = build_datastore(model, params, cfg.vocab_size)
    print(f"datastore: {keys.shape[0]} keys of dim {keys.shape[1]}")

    knn = KNNLMConfig(lam=0.25, index_shards=ARGS.shards, bmo=BMOConfig(
        k=8, delta=0.05, block=16, batch_arms=16, metric="l2"))
    audit_dir = None
    if ARGS.audit:
        from repro.serve.plane import PlaneConfig
        audit_dir = ARGS.audit_dir or tempfile.mkdtemp(prefix="bmo_audit_")
        knn = dataclasses.replace(knn, plane=PlaneConfig(
            audit_rate=1.0, audit_dir=audit_dir))

    # ONE construction path for any shard count: the handle hides the
    # single-shard/sharded split, and the next-token payload is attached at
    # build so it rides every remap (growth/compaction/re-shard) for free.
    index_dir = tempfile.mkdtemp(prefix="bmo_index_") + "/idx"
    store = Index.build(keys, knn.bmo, jax.random.PRNGKey(7),
                        shards=max(ARGS.shards, 1), payload=next_ids,
                        cache=knn.cache_policy(),
                        compaction=knn.compaction_policy())
    store.save(index_dir)                  # per-shard checkpoints + manifest
    store = Index.load(index_dir, cache=knn.cache_policy(),
                       compaction=knn.compaction_policy())
    print(f"index: {store.n_live} live slots / capacity {store.capacity} "
          f"({store.n_shards} shard(s)), saved+loaded via {index_dir}")

    if ARGS.shards > 1:
        # -- PR-4 admin ops on the LIVE handle (DESIGN.md §6.3) ------------
        # elastic re-shard with NO checkpoint round-trip: quiesce appends,
        # remap the live rows with the same deterministic uniform-stride
        # remap the save/load path uses, swap under the epoch fence (query
        # cache invalidated, payload realigned) — bit-identical results.
        before = store.query(keys[:2], jax.random.PRNGKey(11))
        toks_before = store.payload[before.indices]   # payload under OLD gids
        store.reshard(max(ARGS.shards // 2, 1))
        after = store.query(keys[:2], jax.random.PRNGKey(11))
        assert toks_before.tolist() == store.payload[after.indices].tolist()
        print(f"LIVE reshard S={ARGS.shards} -> S={store.n_shards} "
              f"(stride {store.store.stride}, epoch {store.epoch}, "
              f"per-shard {store.store.live_per_shard}) — no checkpoint "
              "written, top-k identical")
        # read fan-out: replica meshes round-robin the query batches
        store.add_replicas(2)
        print(f"read fan-out: {store.stats.replicas} replicas")

    if ARGS.tune:
        # -- PR-7: the self-racing autotuner (DESIGN.md §9) ----------------
        # Candidate (R, B, P, frontier, mode) configs are arms of the
        # paper's own bandit: the roofline model prunes the grid, the
        # survivors race on measured wall time, and the winner installs
        # under the epoch fence — identical top-k, cheaper schedule. The
        # tuned.json sidecar rides the checkpoint, so a replica that loads
        # this directory serves the tuned config with NO re-race.
        probe = keys[:8]

        def _qps(reps=3, seed=31):
            best = float("inf")
            for i in range(reps):       # min-of-reps: rep 0 eats compiles
                t0 = time.time()
                store.query(probe, jax.random.PRNGKey(seed + i))
                best = min(best, time.time() - t0)
            return probe.shape[0] / best

        base_qps = _qps()
        report = store.tune(rng=jax.random.PRNGKey(13))
        print(f"autotune: raced {report.get('raced', 0)} of "
              f"{report.get('grid_size', 0)} candidates -> "
              f"{report['config']}")
        print(f"  qps {base_qps:.0f} -> {_qps(seed=41):.0f} "
              "(same exact top-k: tuning changes cost, never results)")
        store.save(index_dir)           # tuned.json sidecar rides along
        assert Index.load(index_dir).tuned == store.tuned
        print("  sidecar round-trip: reloaded index serves the tuned "
              "config with no re-race")

    batch_size, prompt_len, new_tokens = 4, 12, 16
    engine = ServeEngine(model, params, plan, mesh, batch_size=batch_size,
                         max_seq=prompt_len + new_tokens + 4,
                         knn_lm=knn, index=store, index_append=True)

    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (batch_size, prompt_len)).astype(np.int32)
    n_live_before = store.n_live
    t0 = time.time()
    out, retrieval_ops = engine.generate(prompts, new_tokens)
    dt = time.time() - t0
    n_exact = keys.shape[0] * keys.shape[1] * new_tokens * batch_size
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s with retrieval)")
    print(f"retrieval coordinate-ops: {retrieval_ops:.3g} "
          f"(exact search: {float(n_exact):.3g} → "
          f"{float(n_exact) / max(retrieval_ops, 1):.1f}x)")
    print(f"index grew during decode: {engine.index.n_live} live slots "
          f"(+{engine.index.n_live - n_live_before} appended)")
    stats = engine.stats                   # typed repro.api.ServeStats (v2)
    print(f"serve stats: {stats.as_dict()}")
    if stats.shard_coord_ops is not None:
        print(f"per-shard coord-ops: "
              f"{[f'{v:.3g}' for v in stats.shard_coord_ops]}, "
              f"max rounds {stats.shard_rounds} "
              f"(near_hits={stats.near_hits})")

    # -- PR-5: the async request plane (DESIGN.md §7) ----------------------
    # The engine's plane is a shared scheduler: external callers submit
    # anytime tickets against the same index the decode loop retrieves
    # from. submit -> stream partials -> exit early once *enough* of the
    # answer is certified — the bandit race is anytime, so every epoch
    # boundary yields a certified prefix plus honest CI radii on the rest.
    from repro.api import Deadline, EffortBudget

    plane = engine.plane
    probe = keys[:4] + 0.01 * np.random.default_rng(5).normal(
        size=(4, keys.shape[1])).astype(np.float32)
    ticket = plane.submit(probe, rng=jax.random.PRNGKey(21),
                          budget=EffortBudget(epochs=8))
    want_certified = 2                     # early-exit bar: top-2 certified
    for partial in plane.stream(ticket):
        cc = partial.certified_count
        print(f"  anytime epoch {partial.epochs}: certified/row {cc}, "
              f"max CI radius {float(np.max(partial.ci_radii)):.3g}"
              + (f" [terminal: {partial.reason}]" if partial.terminal
                 else ""))
        if not partial.terminal and (cc >= want_certified).all():
            print(f"  early exit: every row has its top-{want_certified} "
                  "certified — consumer stops streaming, scheduler will "
                  "finish or retire the ticket")
            break
    # deadline-bounded traffic: the plane returns the certified prefix at
    # expiry instead of blocking everyone behind full certification
    late = plane.query(probe, rng=jax.random.PRNGKey(22),
                       deadline=Deadline(ms=5.0), cache="bypass")
    print(f"deadline(5ms) answer: reason={late.reason}, "
          f"certified/row {late.certified_count} of k={late.indices.shape[1]}"
          f" (epoch {late.epoch})")
    print(f"plane stats: "
          f"{ {k2: v for k2, v in engine.stats.as_dict().items() if k2.startswith('plane_')} }")

    # -- PR-6: race-level tracing (DESIGN.md §8) ---------------------------
    # Every ticket above recorded a full trace — submit → queue → admit →
    # per-epoch pulls/frontier/CI → terminal — into the process obs
    # context. --trace dumps it for offline reconstruction:
    #   PYTHONPATH=src python examples/knn_serve.py --trace trace.json
    #   PYTHONPATH=src python tools/trace_view.py trace.json   # text render
    #   (open trace.perfetto.json in ui.perfetto.dev for the timeline)
    if ARGS.trace:
        from repro.obs import dump_events, get_obs
        obs = get_obs()
        dump_events(ARGS.trace, obs)
        print(f"trace: {obs.events.total} events "
              f"({obs.events.drops} dropped) -> {ARGS.trace}")
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "tools"))
        import trace_view
        chrome = ARGS.trace.rsplit(".json", 1)[0] + ".perfetto.json"
        with open(chrome, "w") as f:
            import json as _json
            _json.dump(trace_view.to_chrome(trace_view.load_trace(
                ARGS.trace)), f, indent=1)
        print(f"trace: Perfetto timeline -> {chrome} "
              f"(open in ui.perfetto.dev)")
        demo = plane.stats
        mean_ms = (demo.obs_epoch_ms["sum"]
                   / max(demo.obs_epoch_ms["count"], 1))
        print(f"obs: {demo.obs_events} events recorded, "
              f"mean scheduler epoch {mean_ms:.2f} ms")

    # -- PR-8: online δ-audit + failure flight recorder (DESIGN.md §10) ----
    # A fraction of certified tickets (here: all of them) is re-answered
    # EXACTLY, off the critical path, and compared against what was
    # served. Clean traffic drives the Wilson upper bound on the error
    # rate down toward the paper's δ; a wrong answer is caught, written to
    # a replayable bundle, and reproduced offline.
    if ARGS.audit:
        from repro.obs import health_snapshot, print_health

        # 1) clean run: audit everything the plane served above. The
        # anytime/deadline tickets exited PARTIAL — they never claimed the
        # full 1-δ contract, so the auditor skips them as 'uncertified'.
        for j in range(4):
            plane.submit(probe + 0.001 * j, rng=jax.random.PRNGKey(50 + j),
                         cache="bypass")
        plane.drain()
        done = plane.audit_flush()          # the oracle bill, paid off-path
        a = plane.auditor.summary()
        print(f"audit (clean): {done} ticket(s) flushed, "
              f"{a['mismatch_rows']}/{a['sampled_rows']} rows mismatched, "
              f"err_upper={a['err_upper']:.4g} vs delta="
              f"{knn.bmo.delta} (skipped: {a['skipped']})")
        assert a["mismatch_rows"] == 0

        # 2) injected failure: corrupt ONE served answer BELOW the plane —
        # the scheduler, cache and certification all believe it; only the
        # shadow oracle can notice. A duplicated neighbor id means some
        # true neighbor is missing, which check_topk flags no matter how
        # the distances tie.
        real_build = plane._build_result

        def corrupted(entry, terminal, reason):
            res = real_build(entry, terminal, reason)
            if terminal and reason == "certified":
                res.indices[0, 0] = res.indices[0, 1]
                plane._build_result = real_build      # one ticket only
            return res

        plane._build_result = corrupted
        bad_ticket = plane.submit(probe, rng=jax.random.PRNGKey(60),
                                  cache="bypass")
        plane.drain()
        plane.audit_flush()
        a = plane.auditor.summary()
        assert a["mismatch_rows"] == 1 and len(a["bundles"]) == 1
        bundle = a["bundles"][0]
        print(f"audit (injected): ticket {bad_ticket.trace_id} flagged, "
              f"flight-recorder bundle -> {bundle}")

        # 3) replay: save the index as it is NOW, reload it like an
        # offline investigation would, and re-run the bundle through
        # tools/replay_audit.py — the mismatch reproduces deterministically.
        replay_dir = tempfile.mkdtemp(prefix="bmo_replay_") + "/idx"
        engine.index.save(replay_dir)
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "tools"))
        import replay_audit
        rc = replay_audit.main(["--index-dir", replay_dir, bundle])
        assert rc == 0
        print("replay: recorded mismatch reproduced against the reloaded "
              "index (exit 0)")
        print_health(health_snapshot(plane=plane), out=sys.stdout)

    # -- PR-9: the namespace fleet (DESIGN.md §11) -------------------------
    # Thousands of per-tenant collections can't each own a mesh. A Fleet
    # multiplexes named namespaces over ONE plane: an LRU residency budget
    # keeps the hot few in memory, everything else lives as a checkpoint
    # and reloads transparently (bit-identically) on its next query.
    if ARGS.fleet:
        from repro.fleet import Fleet, FleetConfig

        root = tempfile.mkdtemp(prefix="bmo_fleet_") + "/fleet"
        fleet = Fleet(root, FleetConfig(max_resident=2))
        fan = np.random.default_rng(3)
        for name in ("wiki", "code", "mail"):
            corpus = (keys + fan.normal(scale=0.05, size=keys.shape)
                      ).astype(np.float32)
            fleet.create(name, corpus, knn.bmo, jax.random.PRNGKey(7),
                         payload=next_ids)
        print(f"fleet @ {root}: {len(fleet)} namespaces, "
              f"{fleet.resident_count} resident (budget 2) — 'wiki' was "
              "LRU-evicted to its checkpoint at the third create")
        fplane = fleet.serve()
        q = keys[:2]
        before = fplane.query(q, rng=jax.random.PRNGKey(77),
                              namespace="code")
        assert fleet.evict("code")
        after = fplane.query(q, rng=jax.random.PRNGKey(77),
                             namespace="code")     # transparent reload
        assert before.indices.tolist() == after.indices.tolist()
        print("evict('code') → checkpoint; its next query reloaded it "
              f"transparently with bit-identical top-k "
              f"(reloads={fleet.reload_count})")
        wiki = fplane.query(q, rng=jax.random.PRNGKey(78), namespace="wiki")
        print(f"cold 'wiki' served through the SAME plane: "
              f"k={wiki.indices.shape[1]}, reason={wiki.reason}")
        st = fplane.stats
        print(f"fleet plane stats: resident={st.fleet_namespaces_resident} "
              f"evicted={st.fleet_namespaces_evicted} "
              f"reloads={st.fleet_reloads}")
        fleet.drop("mail")
        assert "mail" not in fleet and len(fleet) == 2
        reopened = Fleet.open(root)
        assert sorted(reopened.namespaces) == ["code", "wiki"]
        print(f"drop('mail') + Fleet.open(root): manifest recovered "
              f"{len(reopened)} namespaces — {sorted(reopened.namespaces)}")

    print("note: at this smoke scale (d=64, n≈500) exact search is cheap; "
          "the bandit gain appears at the paper's d≈4k–28k regime "
          "(see quickstart.py / benchmarks).")
    print("tokens:\n", out)


if __name__ == "__main__":
    main()
