"""Train a small LM end-to-end with the full production substrate:
sharded train step, deterministic loader, async checkpoints, straggler
watchdog, and fault-tolerant supervision (try --fail-at to watch a crash +
auto-resume mid-run).

    PYTHONPATH=src python examples/train_lm.py --steps 60 [--fail-at 35]

For the ~100M-class config use --arch xlstm-350m without --smoke (slow on
CPU; the mesh-scale path is proven by the dry-run).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--full", action="store_true",
                    help="use the full (not smoke) config")
    args = ap.parse_args()

    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--batch", "8", "--seq", "128",
            "--ckpt-dir", "/tmp/repro_example_ckpt",
            "--ckpt-every", "20", "--log-every", "5"]
    if not args.full:
        argv.append("--smoke")
    if args.fail_at:
        argv += ["--fail-at", str(args.fail_at)]
    train_cli.main(argv)


if __name__ == "__main__":
    main()
