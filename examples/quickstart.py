"""Quickstart: exact k-NN with BMO-NN on synthetic image-like data.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core claim in one page: BMO-NN returns the *exact*
nearest neighbours while computing a fraction of the coordinate-wise
distances that brute force needs.

Five-minute tour of the repo
----------------------------
One-shot queries (paper Algorithm 2, per-query racing)::

    from repro.configs.base import BMOConfig
    from repro.core import bmo_nn
    cfg = BMOConfig(k=5, delta=0.01, block=128)      # §III dense box
    res = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(0))
    # cfg.rotate=True → §IV-B Hadamard box; cfg.sparse=True → §IV-A box

Serving (build the index once, race whole query batches against it)::

    from repro.api import Index
    idx = Index.build(corpus, cfg, jax.random.PRNGKey(0))  # one handle
    idx.save("idx"); idx = Index.load("idx")               # persist
    res = idx.query(queries, jax.random.PRNGKey(1))        # batched race
    res = idx.query(queries, rng, k=10, delta=0.001)       # QuerySpec

Mutation and admin (the datastore can grow during decode — kNN-LM)::

    gids = idx.insert(new_rows)   # O(1) slot reuse / growth, global ids
    idx.delete(stale_gids)        # O(1) tombstones
    idx.maybe_compact()           # CompactionPolicy rebuild
    idx.reshard(4)                # LIVE elastic re-shard over a mesh
    idx.add_replicas(2)           # read fan-out over replica meshes

Benchmarks: ``python benchmarks/run.py`` (fig2–fig8; fig8 is the batched
index-serving throughput vs per-query racing). End-to-end LM serving with
the retrieval hook: ``examples/knn_serve.py``. Design rationale: DESIGN.md.
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle
from repro.data.synthetic import make_knn_benchmark_data


def main():
    n, d, n_queries, k = 2000, 8192, 8, 5
    print(f"corpus: {n} points in {d} dims; {n_queries} queries; k={k}")
    corpus, queries = make_knn_benchmark_data("dense", n, d, n_queries, seed=0)

    t0 = time.time()
    exact = oracle.exact_knn(corpus, queries, k, metric="l2")
    print(f"exact:  {time.time() - t0:.2f}s, "
          f"{float(exact.coord_ops):.3g} coordinate-wise distance computations")

    cfg = BMOConfig(k=k, delta=0.01,   # ≥99% exact-set probability
                    block=128,         # TPU-native coordinate-block sampling
                    batch_arms=32, pulls_per_round=2, metric="l2")
    t0 = time.time()
    res = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(0))
    bmo_ops = float(np.sum(np.asarray(res.coord_ops)))
    print(f"bmo-nn: {time.time() - t0:.2f}s, {bmo_ops:.3g} computations")

    acc = np.mean([set(np.asarray(res.indices[i]).tolist())
                   == set(np.asarray(exact.indices[i]).tolist())
                   for i in range(n_queries)])
    print(f"exact-set accuracy: {acc:.3f}  "
          f"gain: {float(exact.coord_ops) / bmo_ops:.1f}x fewer computations")


if __name__ == "__main__":
    main()
