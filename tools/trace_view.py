"""Offline trace reconstruction for the serving stack (DESIGN.md §8.4).

Input is the raw event dump ``repro.obs.dump_events`` writes (the
``--trace`` flag of ``launch/serve.py`` / ``tools/bench_serve_plane.py``,
or ``examples/knn_serve.py --trace``). Two outputs:

  * **render** (default): a per-ticket text reconstruction — submit →
    queue → admit → every race epoch (pulls, frontier width, survivors, R,
    worst uncertified CI, per-shard straggler split) → terminal — plus the
    race sessions' own epoch spans. A single plane-served query is fully
    reconstructable offline from one dump.
  * **--chrome out.json**: a Chrome-trace-event file (open in Perfetto /
    chrome://tracing): one timeline row per trace id, spans as complete
    ("X") events, instants as "i".

    PYTHONPATH=src python tools/trace_view.py trace.json
    PYTHONPATH=src python tools/trace_view.py trace.json --chrome perfetto.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List


def load_trace(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if "events" not in doc:
        raise ValueError(f"{path} is not a raw event dump "
                         "(missing 'events'; pass the --trace output, "
                         "not --metrics-dump)")
    return doc


# ---------------------------------------------------------------------------
# Chrome trace events (Perfetto-loadable)
# ---------------------------------------------------------------------------


def to_chrome(doc: dict) -> dict:
    """Convert a raw event dump to the Chrome trace event format: one
    timeline row (tid) per trace id, µs timestamps rebased to the dump's
    earliest event."""
    events = doc.get("events", [])
    if not events:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    t_base = min(e["ts"] for e in events)
    tids: Dict[str, int] = {}
    out: List[dict] = []
    for e in events:
        trace = e.get("trace") or "(untraced)"
        if trace not in tids:
            tids[trace] = len(tids) + 1
            out.append({"name": "thread_name", "ph": "M", "pid": 1,
                        "tid": tids[trace], "args": {"name": trace}})
        rec = {
            "name": e["name"],
            "ph": "X" if e.get("ph") == "X" else "i",
            "pid": 1,
            "tid": tids[trace],
            "ts": (e["ts"] - t_base) * 1e6,
            "args": e.get("attrs", {}),
        }
        if rec["ph"] == "X":
            rec["dur"] = e.get("dur", 0.0) * 1e6
        else:
            rec["s"] = "t"          # thread-scoped instant
        out.append(rec)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


# ---------------------------------------------------------------------------
# text reconstruction
# ---------------------------------------------------------------------------


def _fmt_attrs(attrs: dict, skip=()) -> str:
    parts = []
    for k, v in attrs.items():
        if k in skip:
            continue
        if isinstance(v, float):
            v = f"{v:.4g}"
        elif isinstance(v, list):
            v = "[" + ",".join(f"{float(x):.4g}" for x in v) + "]"
        parts.append(f"{k}={v}")
    return " ".join(parts)


def render(doc: dict) -> str:
    """Per-ticket lifecycle reconstruction, oldest ticket first. Session
    (``s-*``) race.epoch spans are folded under the tickets that joined
    them via the admit event's ``session`` attribute."""
    events = doc.get("events", [])
    if not events:
        return "(no events)\n"
    t_base = min(e["ts"] for e in events)
    by_trace: Dict[str, List[dict]] = {}
    for e in events:
        by_trace.setdefault(e.get("trace") or "(untraced)", []).append(e)
    session_epochs: Dict[str, List[dict]] = {}
    for trace, evs in by_trace.items():
        session_epochs[trace] = [e for e in evs if e["name"] == "race.epoch"]
    lines = [f"trace dump: {len(events)} events, "
             f"{doc.get('event_drops', 0)} dropped, "
             f"clock={doc.get('clock', '?')}"]
    tickets = sorted(
        (t for t, evs in by_trace.items()
         if any(e["name"].startswith(("plane.", "ticket.")) for e in evs)),
        key=lambda t: min(e["ts"] for e in by_trace[t]))
    for trace in tickets:
        evs = sorted(by_trace[trace], key=lambda e: e["ts"])
        lines.append(f"\n{trace}:")
        sessions = set()
        for e in evs:
            t_ms = (e["ts"] - t_base) * 1e3
            attrs = e.get("attrs", {})
            if e.get("ph") == "X":
                tag = f"{e['name']} [{e.get('dur', 0.0) * 1e3:.2f} ms]"
            else:
                tag = e["name"]
            lines.append(f"  +{t_ms:9.2f} ms  {tag}  {_fmt_attrs(attrs)}")
            if "session" in attrs:
                sessions.add(attrs["session"])
        for sid in sorted(sessions):
            for e in session_epochs.get(sid, []):
                t_ms = (e["ts"] - t_base) * 1e3
                lines.append(
                    f"  +{t_ms:9.2f} ms  └ {sid} race.epoch "
                    f"[{e.get('dur', 0.0) * 1e3:.2f} ms]  "
                    f"{_fmt_attrs(e.get('attrs', {}))}")
    orphans = [t for t in by_trace
               if t not in tickets and session_epochs.get(t)]
    joined = {a["attrs"]["session"] for t in tickets
              for a in by_trace[t]
              if a.get("attrs", {}).get("session")}
    loose = [t for t in orphans if t not in joined]
    if loose:
        lines.append(f"\nunjoined sessions: {', '.join(sorted(loose))}")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="raw event dump (from --trace)")
    ap.add_argument("--chrome", metavar="OUT",
                    help="write a Perfetto-loadable Chrome trace here")
    ap.add_argument("--no-render", action="store_true",
                    help="skip the text reconstruction")
    args = ap.parse_args(argv)
    doc = load_trace(args.trace)
    if args.chrome:
        with open(args.chrome, "w") as f:
            json.dump(to_chrome(doc), f, indent=1)
        print(f"wrote {args.chrome} "
              f"({len(doc.get('events', []))} events)", file=sys.stderr)
    if not args.no_render:
        sys.stdout.write(render(doc))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
