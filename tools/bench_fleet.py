"""Fleet serving bench (DESIGN.md §11.6): N namespaces, M resident, one
request plane.

Builds a fleet of ``--namespaces`` single-shard namespaces with an LRU
residency budget of ``--resident`` (everything else lives as a crash-safe
checkpoint), then offers mixed open-loop Poisson traffic through the ONE
shared ``RequestPlane``: a small hot set takes ``--hot-frac`` of requests,
the rest spread uniformly over the remaining (mostly cold) namespaces —
every cold hit pays a transparent reload inside ``submit``. Latency is
finish − intended arrival (open loop: arrivals never wait), charged
honestly to hot and cold traffic alike.

Evidence emitted (BENCH_fleet.json is the committed artifact; CI runs
``--smoke`` against benchmarks/baselines/BENCH_fleet_smoke.json via
tools/bench_compare.py):

  * per-class (hot / cold / all) p50/p99 + qps entries,
  * reload latency percentiles + count, resident-set ceiling over the run,
  * a bit-identity probe: one namespace queried, evicted, re-queried — the
    post-reload top-k must match exactly.

    PYTHONPATH=src python tools/bench_fleet.py --smoke
    PYTHONPATH=src python tools/bench_fleet.py --out BENCH_fleet.json
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.api.stream import percentile as _pct
from repro.configs.base import BMOConfig
from repro.fleet import Fleet, FleetConfig
from repro.serve.plane import PlaneConfig


def _summary(lat_ms):
    if not lat_ms:
        return {"p50_ms": None, "p99_ms": None, "mean_ms": None, "n": 0}
    return {"p50_ms": round(_pct(lat_ms, 50), 3),
            "p99_ms": round(_pct(lat_ms, 99), 3),
            "mean_ms": round(float(np.mean(lat_ms)), 3),
            "n": len(lat_ms)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--namespaces", type=int, default=64)
    ap.add_argument("--resident", type=int, default=8)
    ap.add_argument("--n", type=int, default=256, help="rows per namespace")
    ap.add_argument("--d", type=int, default=256)
    ap.add_argument("--q", type=int, default=4, help="queries per request")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--requests", type=int, default=160)
    ap.add_argument("--hot-frac", type=float, default=0.7,
                    help="fraction of requests aimed at the 2-namespace "
                         "hot set (the rest spread over the cold tail)")
    ap.add_argument("--load", type=float, default=2.0,
                    help="offered load as a multiple of measured hot "
                         "service capacity")
    ap.add_argument("--smoke", action="store_true",
                    help="small preset for CI (<~2 min)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--root", default="",
                    help="fleet root (default: a fresh temp dir)")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    if args.smoke:
        args.namespaces, args.resident = 12, 4
        args.n, args.d, args.requests = 192, 128, 40

    t0 = time.perf_counter()
    root = args.root or tempfile.mkdtemp(prefix="bmo_bench_fleet_") + "/fleet"
    cfg = BMOConfig(k=args.k, delta=0.05, block=min(64, args.d),
                    batch_arms=16, pulls_per_round=2, metric="l2")
    fleet = Fleet(root, FleetConfig(max_resident=args.resident))
    rng = np.random.default_rng(args.seed)
    names = [f"ns{i:03d}" for i in range(args.namespaces)]
    corpora = {}
    t = time.perf_counter()
    for i, name in enumerate(names):
        corpora[name] = rng.normal(
            size=(args.n, args.d)).astype(np.float32)
        fleet.create(name, corpora[name], cfg, jax.random.PRNGKey(i))
    build_s = time.perf_counter() - t
    print(f"[bench_fleet] built {args.namespaces} namespaces "
          f"(n={args.n} d={args.d}) in {build_s:.1f}s — "
          f"{fleet.resident_count} resident / "
          f"{fleet.evicted_count} checkpointed")

    # -- bit-identity probe: evict → reload must not change answers --------
    probe_ns = names[0]                     # cold by now (LRU)
    probe_q = corpora[probe_ns][:args.q] + 0.01
    plane = fleet.serve(PlaneConfig(max_group_queries=max(args.q * 8, 16)))
    r1 = plane.query(probe_q, rng=jax.random.PRNGKey(123),
                     namespace=probe_ns, cache="bypass")
    assert fleet.evict(probe_ns)
    t = time.perf_counter()
    r2 = plane.query(probe_q, rng=jax.random.PRNGKey(123),
                     namespace=probe_ns, cache="bypass")
    probe_reload_ms = (time.perf_counter() - t) * 1e3
    bit_identical = (r1.indices.tolist() == r2.indices.tolist()
                     and r1.values.tolist() == r2.values.tolist())
    assert bit_identical, "post-reload top-k diverged"
    print(f"[bench_fleet] evict→reload bit-identical "
          f"(reload+query {probe_reload_ms:.1f} ms)")

    # -- traffic mix: hot set vs long cold tail ----------------------------
    hot = names[-2:]                        # most recently created → warm
    cold_pool = names[:-2]
    picks = [rng.choice(hot) if rng.random() < args.hot_frac
             else rng.choice(cold_pool) for _ in range(args.requests)]
    reqs = [corpora[ns][rng.integers(0, args.n, args.q)]
            + 0.05 * rng.normal(size=(args.q, args.d)).astype(np.float32)
            for ns in picks]
    reqs = [r.astype(np.float32) for r in reqs]

    # warm the pow2 group-size specializations outside the timed window
    # (coalesced groups race at power-of-two row counts; each new size is
    # a fresh compile that must not be charged to the open loop)
    for size in {args.q, 2 * args.q, 4 * args.q, 8 * args.q}:
        warm = [plane.submit(reqs[0] + j, rng=jax.random.PRNGKey(7 + j),
                             namespace=hot[0], cache="bypass")
                for j in range(max(1, size // args.q))]
        plane.drain()
        del warm

    # measured hot service time sets the offered rate
    plane.query(reqs[0], rng=jax.random.PRNGKey(1), namespace=hot[0],
                cache="bypass")
    t = time.perf_counter()
    for i in range(3):
        plane.query(reqs[i], rng=jax.random.PRNGKey(2 + i),
                    namespace=hot[0], cache="bypass")
    t_service = (time.perf_counter() - t) / 3
    lam = args.load / t_service
    arrivals = np.cumsum(rng.exponential(1.0 / lam, args.requests))
    print(f"[bench_fleet] hot service {t_service * 1e3:.1f} ms → offered "
          f"{lam:.1f} req/s ({args.load}x), hot_frac={args.hot_frac}")

    tickets = [None] * args.requests
    reload_ms, max_resident = [], fleet.resident_count
    start = time.monotonic()
    i = 0
    while i < args.requests or plane.active:
        now = time.monotonic() - start
        while i < args.requests and arrivals[i] <= now:
            r0 = fleet.reload_count
            t = time.perf_counter()
            tickets[i] = plane.submit(reqs[i], tenant="bench",
                                      namespace=picks[i],
                                      rng=jax.random.PRNGKey(200 + i),
                                      cache="bypass")
            if fleet.reload_count > r0:     # this submit paid a reload
                reload_ms.append((time.perf_counter() - t) * 1e3)
            i += 1
        if plane.active:
            plane.step()
            fleet.enforce_residency()   # pull quiesced ns back to budget
            max_resident = max(max_resident, fleet.resident_count)
        elif i < args.requests:
            time.sleep(max(0.0, min(arrivals[i] - (time.monotonic() - start),
                                    0.01)))
    window_s = max(t_.finished_at for t_ in tickets) - start
    lat = [((tickets[j].finished_at - start) - arrivals[j]) * 1e3
           for j in range(args.requests)]
    is_hot = [picks[j] in hot for j in range(args.requests)]
    lat_hot = [lat[j] for j in range(args.requests) if is_hot[j]]
    lat_cold = [lat[j] for j in range(args.requests) if not is_hot[j]]
    assert all(t_.result.reason == "certified" for t_ in tickets)
    # the budget is enforced as soon as namespaces quiesce; the transient
    # peak (cold tickets in flight pin their namespaces) is reported
    fleet.enforce_residency()
    assert fleet.resident_count <= args.resident, \
        f"residency budget violated: {fleet.resident_count} > {args.resident}"

    st = plane.stats

    def _entry(mode, lats, n_req):
        # _summary's row count would shadow the corpus-size ID field "n",
        # so it goes first and the identity fields win
        return {**_summary(lats), "bench": "fleet", "mode": mode,
                "Q": args.q, "n": args.n, "d": args.d, "k": args.k,
                "namespaces": args.namespaces, "resident": args.resident,
                "requests": n_req, "qps": round(n_req / window_s, 2)}

    out = {
        "bench": "fleet",
        "schema_version": 1,
        "config": {"namespaces": args.namespaces,
                   "resident": args.resident, "n": args.n, "d": args.d,
                   "q": args.q, "k": args.k, "requests": args.requests,
                   "hot_frac": args.hot_frac, "load": args.load,
                   "service_ms": round(t_service * 1e3, 3),
                   "build_s": round(build_s, 1),
                   "smoke": bool(args.smoke)},
        "entries": [
            _entry("all", lat, args.requests),
            _entry("hot", lat_hot, len(lat_hot)),
            _entry("cold", lat_cold, len(lat_cold)),
        ],
        "reload": {**_summary(reload_ms),
                   "count": len(reload_ms),
                   "total_reloads": fleet.reload_count,
                   "probe_reload_ms": round(probe_reload_ms, 3),
                   "bit_identical_after_reload": bit_identical},
        "residency": {"max_resident_seen": max_resident,
                      "budget": args.resident,
                      "evictions": fleet.eviction_count,
                      "final": fleet.stats()},
        "cold_over_hot_p99": (
            round(_pct(lat_cold, 99) / max(_pct(lat_hot, 99), 1e-9), 2)
            if lat_cold and lat_hot else None),
        "plane": {"submitted": st.plane_submitted,
                  "epochs": st.plane_epochs,
                  "shed": st.plane_shed},
        "wall_s": round(time.perf_counter() - t0, 1),
    }
    print(json.dumps(out, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1)
        print(f"[bench_fleet] wrote {args.out}")
    if not args.root:
        shutil.rmtree(root, ignore_errors=True)
    return out


if __name__ == "__main__":
    main()
