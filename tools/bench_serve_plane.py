"""Open-loop load generator for the request plane (DESIGN.md §7.5).

Poisson arrivals of query batches with mixed wall-clock deadlines are
offered — at the SAME rate — to two serving disciplines over one index:

  * **blocking baseline**: FIFO ``Index.query`` run-to-certification calls,
    the pre-PR-5 serving surface. Under overload the queue grows and tail
    latency explodes (one hard query gates everyone).
  * **request plane**: ``RequestPlane.submit`` with per-request deadlines;
    the scheduler coalesces concurrent tickets into shared race batches and
    returns certified prefixes at expiry.

Latency is measured finish − *intended arrival* (open loop: arrivals do not
wait for the server), so queueing delay is charged honestly to both sides.
Emits p50/p95/p99 + shed/deadline-exit rates as JSON (BENCH_serve_plane.json
is the committed evidence; CI runs ``--smoke`` and uploads the artifact):

    PYTHONPATH=src python tools/bench_serve_plane.py --smoke
    PYTHONPATH=src python tools/bench_serve_plane.py \
        --n 4096 --d 2048 --requests 40 --load 1.3 \
        --out BENCH_serve_plane.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.api import Deadline, Index
from repro.api.stream import percentile as _pct
from repro.configs.base import BMOConfig
from repro.data.synthetic import make_knn_benchmark_data
from repro.serve.plane import PlaneConfig, RequestPlane


def _summary(lat_ms):
    if not lat_ms:       # e.g. --unbounded-frac 1.0 leaves no bounded class
        return {"p50_ms": None, "p95_ms": None, "p99_ms": None,
                "mean_ms": None, "n": 0}
    return {"p50_ms": round(_pct(lat_ms, 50), 3),
            "p95_ms": round(_pct(lat_ms, 95), 3),
            "p99_ms": round(_pct(lat_ms, 99), 3),
            "mean_ms": round(float(np.mean(lat_ms)), 3),
            "n": len(lat_ms)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=2048)
    ap.add_argument("--q", type=int, default=4, help="queries per request")
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--load", type=float, default=3.0,
                    help="offered load as a multiple of the blocking "
                         "baseline's measured capacity (sustained "
                         "overload: the FIFO baseline's tail grows with "
                         "the backlog, the plane's deadline exits do not)")
    ap.add_argument("--deadline-frac", type=float, default=0.5,
                    help="per-request deadline as a fraction of the "
                         "blocking baseline's mean service time")
    ap.add_argument("--unbounded-frac", type=float, default=0.25,
                    help="fraction of requests submitted WITHOUT a "
                         "deadline (mixed traffic)")
    ap.add_argument("--smoke", action="store_true",
                    help="small preset for CI (<~2 min)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--audit-rate", type=float, default=0.0,
                    help="shadow δ-audit sampling rate on the plane side "
                         "(the oracle runs AFTER the timed window; the "
                         "JSON gains an 'audit' section)")
    ap.add_argument("--audit-dir", default="", metavar="DIR",
                    help="flight-recorder bundle directory for audited "
                         "mismatches")
    ap.add_argument("--health-dump", default="", metavar="PATH",
                    help="write the health/SLO snapshot here on exit")
    ap.add_argument("--out", default="")
    ap.add_argument("--metrics-dump", default="", metavar="PATH",
                    help="write the obs metrics registry here on exit "
                         "(.json = JSON snapshot, else Prometheus text)")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="write the raw trace-event dump here on exit "
                         "(render/convert with tools/trace_view.py)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.n, args.d, args.requests = 1024, 1024, 20

    t0 = time.perf_counter()
    corpus, _ = make_knn_benchmark_data("dense", args.n, args.d, 2,
                                        seed=args.seed)
    cfg = BMOConfig(k=args.k, delta=0.05, block=min(128, args.d),
                    batch_arms=32, metric="l2")
    index = Index.build(corpus, cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed + 1)
    reqs = [corpus[rng.integers(0, args.n, args.q)]
            + 0.05 * rng.normal(size=(args.q, args.d)).astype(np.float32)
            for _ in range(args.requests)]
    reqs = [r.astype(np.float32) for r in reqs]

    # -- measure the blocking baseline's service time (warm) ----------------
    index.query(reqs[0], jax.random.PRNGKey(1), cache="bypass")   # compile
    t = time.perf_counter()
    for i in range(3):
        index.query(reqs[i % len(reqs)], jax.random.PRNGKey(2 + i),
                    cache="bypass")
    t_service = (time.perf_counter() - t) / 3
    lam = args.load / t_service                     # arrivals per second
    arrivals = np.cumsum(rng.exponential(1.0 / lam, args.requests))
    deadline_ms = args.deadline_frac * t_service * 1e3
    bounded = rng.random(args.requests) >= args.unbounded_frac
    print(f"[bench_serve_plane] n={args.n} d={args.d} Q={args.q} "
          f"k={args.k}: blocking service {t_service * 1e3:.1f} ms, "
          f"offered load {args.load}x ({lam:.1f} req/s), "
          f"deadline {deadline_ms:.1f} ms on {bounded.mean():.0%} of "
          f"{args.requests} requests")

    # -- blocking baseline: FIFO run-to-certification -----------------------
    lat_base = []
    now = 0.0
    for i, r in enumerate(reqs):
        start = max(now, arrivals[i])
        t = time.perf_counter()
        index.query(r, jax.random.PRNGKey(100 + i), cache="bypass")
        now = start + (time.perf_counter() - t)
        lat_base.append((now - arrivals[i]) * 1e3)

    # -- request plane: open-loop submit + cooperative scheduler ------------
    plane = RequestPlane(index, PlaneConfig(
        max_group_queries=max(args.q * 8, 16),
        audit_rate=args.audit_rate,
        audit_reservoir=max(256, args.requests),
        audit_dir=args.audit_dir or None))
    # warm the pow2 group-size specializations outside the timed window
    for size in {args.q, 2 * args.q, 4 * args.q, 8 * args.q}:
        warm = [plane.submit(reqs[0] + j, rng=jax.random.PRNGKey(7 + j),
                             cache="bypass")
                for j in range(max(1, size // args.q))]
        plane.drain()
        del warm
    plane.query(reqs[0], rng=jax.random.PRNGKey(6), cache="bypass",
                deadline=Deadline(ms=deadline_ms))

    tickets = [None] * args.requests
    start = time.monotonic()
    i = 0
    while i < args.requests or plane.active:
        now = time.monotonic() - start
        while i < args.requests and arrivals[i] <= now:
            kw = ({"deadline": Deadline(ms=deadline_ms)} if bounded[i]
                  else {})
            tickets[i] = plane.submit(
                reqs[i], rng=jax.random.PRNGKey(200 + i), cache="bypass",
                **kw)
            i += 1
        if plane.active:
            plane.step()
        elif i < args.requests:
            time.sleep(max(0.0, min(arrivals[i] - (time.monotonic() - start),
                                    0.01)))
    end_times = [(t_.finished_at - start) for t_ in tickets]
    lat_plane = [(end_times[j] - arrivals[j]) * 1e3
                 for j in range(args.requests)]
    lat_plane_bounded = [lat_plane[j] for j in range(args.requests)
                         if bounded[j]]
    lat_base_bounded = [lat_base[j] for j in range(args.requests)
                        if bounded[j]]

    # -- post-drain shadow audit (UNTIMED: the oracle runs after the
    # latency window closes, so it cannot contaminate the measurement) -----
    audit = None
    if plane.auditor is not None:
        flushed = plane.audit_flush()
        a = plane.auditor.summary()
        audited_recall = (1.0 - a["mismatch_rows"] / a["sampled_rows"]
                          if a["sampled_rows"] else None)
        audit = {
            "rate": args.audit_rate,
            "flushed_tickets": flushed,
            "sampled_rows": a["sampled_rows"],
            "mismatch_rows": a["mismatch_rows"],
            "audited_recall": (round(audited_recall, 6)
                               if audited_recall is not None else None),
            "err_upper": round(a["err_upper"], 6),
            "method": a["method"],
            "delta": cfg.delta,
            "skipped": a["skipped"],
            "bundles": a["bundles"],
        }
        print(f"[bench_serve_plane] audit: {a['sampled_rows']} rows, "
              f"{a['mismatch_rows']} mismatches, "
              f"err_upper={a['err_upper']:.4g} vs delta={cfg.delta}")
    st = plane.stats

    reasons = [t_.result.reason for t_ in tickets]
    certified = [int(np.min(t_.result.certified_count)) for t_ in tickets]
    out = {
        "schema_version": 4,      # v4: optional "audit" section (PR 8)
        "config": {"n": args.n, "d": args.d, "q": args.q, "k": args.k,
                   "requests": args.requests, "load": args.load,
                   "deadline_ms": round(deadline_ms, 3),
                   "bounded_frac": round(float(bounded.mean()), 3),
                   "service_ms": round(t_service * 1e3, 3),
                   "smoke": bool(args.smoke)},
        "baseline": {**_summary(lat_base),
                     "bounded": _summary(lat_base_bounded)},
        "plane": {**_summary(lat_plane),
                  "bounded": _summary(lat_plane_bounded),
                  "shed_rate": round(st.plane_shed
                                     / max(st.plane_submitted, 1), 3),
                  "deadline_exit_rate": round(
                      reasons.count("deadline") / len(reasons), 3),
                  "certified_rate": round(
                      reasons.count("certified") / len(reasons), 3),
                  "min_certified_prefix": int(np.min(certified)),
                  "epochs": st.plane_epochs},
        "speedup_p99_bounded": (
            round(_pct(lat_base_bounded, 99)
                  / max(_pct(lat_plane_bounded, 99), 1e-9), 2)
            if lat_base_bounded and lat_plane_bounded else None),
        "wall_s": round(time.perf_counter() - t0, 1),
    }
    if audit is not None:
        out["audit"] = audit
    print(json.dumps(out, indent=1))
    if args.health_dump:
        from repro.obs import dump_health
        doc = dump_health(args.health_dump, plane=plane)
        print(f"[bench_serve_plane] wrote {args.health_dump} "
              f"(ok={doc['ok']})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1)
        print(f"[bench_serve_plane] wrote {args.out}")
    if args.metrics_dump or args.trace:
        from repro.obs import dump_events, dump_metrics, get_obs
        obs = get_obs()
        if args.metrics_dump:
            dump_metrics(args.metrics_dump, obs)
            print(f"[bench_serve_plane] wrote {args.metrics_dump}")
        if args.trace:
            dump_events(args.trace, obs)
            print(f"[bench_serve_plane] wrote {args.trace} "
                  f"({obs.events.total} events, {obs.events.drops} dropped)")
    return out


if __name__ == "__main__":
    main()
