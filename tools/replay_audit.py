"""Replay flight-recorder bundles against a saved index (DESIGN.md §10.5).

A δ-audit mismatch writes a bundle directory (``bundle.json`` +
``arrays.npz``) holding the query batch, the served ids/values, the exact
ground truth at audit time, the QuerySpec, and the ticket's trace events.
This CLI re-runs the exact oracle on a live index and reports whether the
recorded mismatch reproduces:

    PYTHONPATH=src python tools/replay_audit.py \
        --index-dir saved_index bundles/audit-0000-p1.t7

Exit code 0 when every bundle's verdict matches expectations (reproduced
on the same store epoch, or explained by an epoch change), 1 when a
recorded mismatch silently vanished or a clean row went bad — either
means the store or the oracle moved under us.
"""
from __future__ import annotations

import argparse
import json
import sys


def replay_one(index, path: str, verbose: bool = False) -> dict:
    from repro.obs.audit import load_bundle, replay_bundle
    doc, _arrays = load_bundle(path)
    report = replay_bundle(index, path)
    report["bundle"] = path
    report["trace_id"] = doc.get("trace_id")
    report["tenant"] = doc.get("tenant")
    verdict = ("REPRODUCED" if report["reproduced"]
               else ("EPOCH-CHANGED" if not report["epoch_match"]
                     else "NOT-REPRODUCED"))
    report["verdict"] = verdict
    print(f"{path}: {verdict} — recorded mismatch rows "
          f"{report['mismatch_rows_recorded']}, now "
          f"{report['mismatch_rows_now']} "
          f"(store epoch {report['store_epoch_recorded']} -> "
          f"{report['store_epoch_now']})")
    if verbose:
        print(json.dumps({k: v for k, v in report.items()
                          if k not in ("bundle",)}, indent=1, default=str))
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="re-run δ-audit flight-recorder bundles against a "
                    "saved index")
    ap.add_argument("bundles", nargs="+",
                    help="bundle directories (each holds bundle.json + "
                         "arrays.npz)")
    ap.add_argument("--index-dir", required=True,
                    help="Index.save directory to replay against")
    ap.add_argument("--shards", type=int, default=None,
                    help="re-shard the index on load (must match how it "
                         "was served for ids to line up)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the per-bundle replay reports here")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    from repro.api import Index
    index = Index.load(args.index_dir, shards=args.shards)
    reports = [replay_one(index, b, verbose=args.verbose)
               for b in args.bundles]
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"index_dir": args.index_dir,
                       "reports": reports}, f, indent=1, default=str)
    # a replay "fails" when the verdict is surprising: the epoch matched
    # but the mismatch came out different than recorded
    bad = [r for r in reports
           if r["epoch_match"] and not r["reproduced"]]
    if bad:
        print(f"{len(bad)}/{len(reports)} bundle(s) did NOT reproduce on "
              "a matching store epoch", file=sys.stderr)
        return 1
    print(f"{len(reports)} bundle(s) replayed, all consistent")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, "src")
    sys.exit(main())
