"""Fast smoke entry for the index-serving benchmark (<60 s on CPU):
a scaled-down fig8 run plus a mutation round-trip, for CI and pre-commit —
all through the unified ``repro.api`` surface.

    PYTHONPATH=src python tools/bench_index.py
    # sharded smoke (needs N visible devices):
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python tools/bench_index.py --shards 4
    # LIVE elastic re-shard under load (DESIGN.md §6.3): qps at S=4, then
    # Index.reshard(2) on the serving handle, then qps vs a fresh S=2 build:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python tools/bench_index.py --shards 4 --live-reshard 2
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.api import Index
from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle
from repro.data.synthetic import make_knn_benchmark_data


def _timed(fn):
    fn()                                   # warm
    t0 = time.perf_counter()
    r = fn()
    jax.block_until_ready(r.values)
    return r, time.perf_counter() - t0


def _timed_pct(fn, Q: int, reps: int = 5):
    """Timed reps with per-query latency percentiles through the obs
    histogram substrate (the same quantile estimator serving reports):
    returns (last result, median wall seconds, {p50,p95,p99} ms)."""
    from repro.obs import ObsContext
    fn()                                   # warm
    hist = ObsContext("bench", enabled=False).registry.histogram(
        "repro_bench_query_ms", "per-query bench latency (ms)")
    walls = []
    r = None
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        r = fn()
        jax.block_until_ready(r.values)
        wall = time.perf_counter() - t0
        walls.append(wall)
        hist.observe(wall * 1e3 / Q)
    pct = {f"latency_p{p}_ms": hist.quantile(p / 100.0)
           for p in (50, 95, 99)}
    return r, float(np.median(walls)), pct


def _row_acc(handle: Index, res, exact_idx, Q: int) -> float:
    """Exact-set accuracy through the handle's build-row map (global slot
    ids → original corpus rows)."""
    row_of = np.full(handle.capacity, -1)
    bg = handle.build_gids
    keep = bg >= 0
    row_of[bg[keep]] = np.nonzero(keep)[0]
    rows = row_of[np.asarray(res.indices)]
    return float(np.mean([set(rows[i].tolist())
                          == set(np.asarray(exact_idx[i]).tolist())
                          for i in range(Q)]))


def main_sharded(shards: int, live_reshard: int = 0, n: int = 1024,
                 d: int = 1024, Q: int = 16, k: int = 5, out: str = ""):
    """Sharded smoke: parity + qps vs the single-shard fused driver, a
    mutation round-trip through global ids, and (with ``--live-reshard S'``)
    a live elastic re-shard under query load benchmarked against a freshly
    built S' index (acceptance bar: within 10%)."""
    t_start = time.perf_counter()
    corpus, queries = make_knn_benchmark_data("dense", n, d, Q, seed=8)
    cfg = BMOConfig(k=k, delta=0.01, block=128, batch_arms=32,
                    pulls_per_round=2, metric="l2")
    ex = oracle.exact_knn(corpus, queries, k, "l2")

    single = Index.build(corpus, cfg, jax.random.PRNGKey(0))
    base, t_single = _timed(
        lambda: single.query(queries, jax.random.PRNGKey(1), cache="bypass"))
    handle = Index.build(corpus, cfg, jax.random.PRNGKey(0), shards=shards)
    res, t_shard = _timed(
        lambda: handle.query(queries, jax.random.PRNGKey(1), cache="bypass"))
    from repro.index.placement import balance
    acc = _row_acc(handle, res, ex.indices, Q)
    print(f"single-shard fused: {Q / t_single:8.1f} qps  "
          f"acc={_row_acc(single, base, ex.indices, Q):.3f}")
    print(f"sharded (S={shards}):  {Q / t_shard:8.1f} qps  acc={acc:.3f}  "
          f"balance={balance(handle.store.live_per_shard):.2f}  "
          f"shard_ops={[int(v) for v in res.shard_coord_ops]}")
    assert acc == 1.0

    entries = []
    if live_reshard:
        # live elastic re-shard UNDER LOAD: the same handle keeps serving —
        # queries before, the admin swap, queries after; no checkpoint.
        for _ in range(3):                 # load before the swap
            handle.query(queries, jax.random.PRNGKey(2), cache="bypass")
        t0 = time.perf_counter()
        handle.reshard(live_reshard)
        t_swap = time.perf_counter() - t0
        after, t_after, pct_after = _timed_pct(lambda: handle.query(
            queries, jax.random.PRNGKey(3), cache="bypass"), Q, reps=3)
        acc_after = _row_acc(handle, after, ex.indices, Q)
        fresh = Index.build(corpus, cfg, jax.random.PRNGKey(0),
                            shards=live_reshard)
        fres, t_fresh = _timed(lambda: fresh.query(
            queries, jax.random.PRNGKey(3), cache="bypass"))
        ratio = t_fresh / t_after
        print(f"live reshard S={shards}->S'={live_reshard}: swap {t_swap:.2f}s, "
              f"{Q / t_after:8.1f} qps after (acc={acc_after:.3f})")
        print(f"fresh S'={live_reshard} build:  {Q / t_fresh:8.1f} qps  "
              f"-> live/fresh qps ratio {ratio:.2f} (bar: >= 0.9)")
        assert acc_after == 1.0
        assert ratio >= 0.9, (
            f"live-resharded index serves at {ratio:.2f}x of a fresh "
            f"S={live_reshard} build (want >= 0.9)")
        entries.append({
            "bench": "live_reshard",
            "shards_from": shards, "shards_to": live_reshard,
            "Q": Q, "n": n, "d": d, "k": k,
            "swap_seconds": t_swap,
            "qps_live": Q / t_after, "qps_fresh": Q / t_fresh,
            "qps_ratio_live_vs_fresh": ratio,
            "acc": acc_after,
            **pct_after,                             # p50/p95/p99 per query
            "serve_stats": handle.stats.as_dict(),   # typed ServeStats
        })

    # mutation smoke over global ids: delete q0's true NN, insert a closer
    # point (least-loaded routing), compact with the handle's remap
    gids = handle.build_gids
    nn0 = int(np.asarray(ex.indices[0])[0])
    handle.delete([gids[nn0]])
    ins = handle.insert(queries[:1], payload=np.asarray([1], np.int32))
    r2 = handle.query(queries[:1], jax.random.PRNGKey(2), cache="bypass")
    assert int(np.asarray(r2.indices[0])[0]) == int(ins[0])
    # (skip nn0: the insert may have reused its freed slot)
    handle.delete(gids[[r for r in range(n // 2 - 16, n)
                        if r != nn0 and gids[r] >= 0]])
    old_ids = handle.maybe_compact(threshold=0.4)
    assert old_ids is not None
    r3 = handle.query(queries[:1], jax.random.PRNGKey(3), cache="bypass")
    assert int(handle.payload[int(np.asarray(r3.indices[0])[0])]) == 1
    print(f"sharded mutation round-trip OK (insert/delete/compact), "
          f"total {time.perf_counter() - t_start:.1f}s")
    if out and entries:
        with open(out, "w") as f:
            json.dump({"bench": "bench_index_sharded",
                       "backend": jax.default_backend(),
                       "devices": jax.device_count(),
                       "entries": entries}, f, indent=1)
        print(f"wrote {out} ({len(entries)} entries)")


def main_tune(shards: int = 1, n: int = 1024, d: int = 1024, Q: int = 8,
              k: int = 5, reps: int = 3, out: str = "BENCH_autotune.json"):
    """Autotune evidence run (fig8 smoke shape): default-config qps vs
    ``Index.tune()``'d qps on the same handle, exact accuracy asserted on
    both sides. Entries MERGE into ``out`` keyed by shard count, so the
    single-shard and sharded runs share one evidence file:

        PYTHONPATH=src python tools/bench_index.py --tune
        XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
            PYTHONPATH=src python tools/bench_index.py --tune --shards 4
    """
    shards = max(shards, 1)
    corpus, queries = make_knn_benchmark_data("dense", n, d, Q, seed=8)
    cfg = BMOConfig(k=k, delta=0.01, block=128, batch_arms=32,
                    pulls_per_round=2, metric="l2")
    ex = oracle.exact_knn(corpus, queries, k, "l2")
    handle = Index.build(corpus, cfg, jax.random.PRNGKey(0), shards=shards)

    def run():
        return handle.query(queries, jax.random.PRNGKey(1), cache="bypass")

    res_d, t_default, pct_d = _timed_pct(run, Q, reps=reps)
    acc_default = _row_acc(handle, res_d, ex.indices, Q)
    assert acc_default == 1.0, f"default acc {acc_default} != 1.0"

    t0 = time.perf_counter()
    report = handle.tune(rng=jax.random.PRNGKey(7))
    t_tune = time.perf_counter() - t0

    res_t, t_tuned, pct_t = _timed_pct(run, Q, reps=reps)
    acc_tuned = _row_acc(handle, res_t, ex.indices, Q)
    assert acc_tuned == 1.0, f"tuned acc {acc_tuned} != 1.0"

    speedup = t_default / t_tuned
    print(f"default (S={shards}): {Q / t_default:8.1f} qps  "
          f"acc={acc_default:.3f}  p95={pct_d['latency_p95_ms']:.1f}ms")
    print(f"tuned   (S={shards}): {Q / t_tuned:8.1f} qps  "
          f"acc={acc_tuned:.3f}  p95={pct_t['latency_p95_ms']:.1f}ms  "
          f"speedup={speedup:.2f}x  (tune pass {t_tune:.1f}s, "
          f"{report['raced']}/{report['grid_size']} raced)")
    assert speedup >= 1.15, (
        f"tuned config is only {speedup:.2f}x the defaults (bar: 1.15x)")

    entry = {
        "bench": "autotune", "shards": shards,
        "n": n, "d": d, "Q": Q, "k": k, "reps": reps,
        "qps_default": Q / t_default, "qps_tuned": Q / t_tuned,
        "speedup": speedup, "acc_default": acc_default,
        "acc_tuned": acc_tuned, "tune_seconds": t_tune,
        "default": {f"default_{kk}": v for kk, v in pct_d.items()},
        **pct_t,                                  # tuned p50/p95/p99
        "signature": report["signature"],
        "tuned_config": report["config"],
        "grid_size": report["grid_size"], "raced": report["raced"],
    }
    doc = {"bench": "bench_autotune", "backend": jax.default_backend(),
           "devices": jax.device_count(), "entries": []}
    if out and os.path.exists(out):
        with open(out) as f:
            doc = json.load(f)
    doc["entries"] = [e for e in doc["entries"]
                      if e.get("shards") != shards] + [entry]
    doc["entries"].sort(key=lambda e: e["shards"])
    if out:
        with open(out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {out} ({len(doc['entries'])} entries)")


def main(n: int = 1024, d: int = 1024, Q: int = 16, k: int = 5):
    t_start = time.perf_counter()
    corpus, queries = make_knn_benchmark_data("dense", n, d, Q, seed=8)
    cfg = BMOConfig(k=k, delta=0.01, block=128, batch_arms=32,
                    pulls_per_round=2, metric="l2")
    ex = oracle.exact_knn(corpus, queries, k, "l2")

    def timed_knn():
        r = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(0))
        jax.block_until_ready(r.values)
        return r

    timed_knn()
    t0 = time.perf_counter()
    base = timed_knn()
    t_base = time.perf_counter() - t0
    handle = Index.build(corpus, cfg, jax.random.PRNGKey(0),
                         payload=np.arange(n, dtype=np.int32))
    batched, t_batch = _timed(
        lambda: handle.query(queries, jax.random.PRNGKey(1), cache="bypass"))

    def acc(idx):
        return float(np.mean([set(np.asarray(idx[i]).tolist())
                              == set(np.asarray(ex.indices[i]).tolist())
                              for i in range(Q)]))

    print(f"per-query lax.map: {Q / t_base:8.1f} qps  acc={acc(base.indices):.3f}")
    print(f"batched index:     {Q / t_batch:8.1f} qps  acc={acc(batched.indices):.3f}"
          f"  speedup={t_base / t_batch:.2f}x")

    # mutation smoke: delete the true NN of query 0, insert a closer point
    nn0 = int(np.asarray(ex.indices[0])[0])
    handle.delete([nn0])
    slots = handle.insert(queries[:1], payload=np.asarray([-7], np.int32))
    res = handle.query(queries[:1], jax.random.PRNGKey(2), cache="bypass")
    top = int(np.asarray(res.indices[0])[0])
    assert top == int(slots[0]), (top, slots)
    handle.compact()
    res = handle.query(queries[:1], jax.random.PRNGKey(3), cache="bypass")
    # the payload rides the compaction remap inside the handle
    assert int(handle.payload[int(np.asarray(res.indices[0])[0])]) == -7
    print(f"mutation round-trip OK (insert/delete/compact), "
          f"total {time.perf_counter() - t_start:.1f}s")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=0,
                    help=">1: run the sharded smoke instead (needs that many "
                         "visible devices)")
    ap.add_argument("--live-reshard", type=int, default=0,
                    help="with --shards: live-reshard the serving handle to "
                         "this shard count under load and compare qps "
                         "against a fresh build at that count")
    ap.add_argument("--out", default="",
                    help="JSON output path for the live-reshard entry "
                         "(ServeStats schema; '' disables)")
    ap.add_argument("--tune", action="store_true",
                    help="autotune evidence run: default vs Index.tune()'d "
                         "qps at the fig8 smoke shape (merges an entry "
                         "into --tune-out per shard count)")
    ap.add_argument("--tune-out", default="BENCH_autotune.json",
                    help="merge target for --tune entries")
    args = ap.parse_args()
    if args.tune:
        main_tune(shards=args.shards, out=args.tune_out)
    elif args.shards > 1:
        main_sharded(args.shards, live_reshard=args.live_reshard,
                     out=args.out)
    else:
        main()
