"""Fast smoke entry for the index-serving benchmark (<60 s on CPU):
a scaled-down fig8 run plus a mutation round-trip, for CI and pre-commit.

    PYTHONPATH=src python tools/bench_index.py
    # sharded smoke (needs N visible devices):
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python tools/bench_index.py --shards 4
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle
from repro.data.synthetic import make_knn_benchmark_data
from repro.index import build_index, compact, delete, index_knn, insert


def main_sharded(shards: int, n: int = 1024, d: int = 1024, Q: int = 16,
                 k: int = 5):
    """Sharded smoke: parity + qps vs the single-shard fused driver, plus a
    mutation round-trip through global ids (DESIGN.md §5)."""
    from repro.index import (build_sharded_index, sharded_delete,
                             sharded_insert, sharded_maybe_compact)
    from repro.index.placement import balance
    t_start = time.perf_counter()
    corpus, queries = make_knn_benchmark_data("dense", n, d, Q, seed=8)
    cfg = BMOConfig(k=k, delta=0.01, block=128, batch_arms=32,
                    pulls_per_round=2, metric="l2")
    ex = oracle.exact_knn(corpus, queries, k, "l2")

    def timed(fn):
        fn()                                   # warm
        t0 = time.perf_counter()
        r = fn()
        jax.block_until_ready(r.values)
        return r, time.perf_counter() - t0

    single = build_index(corpus, cfg, jax.random.PRNGKey(0))
    base, t_single = timed(
        lambda: index_knn(single, queries, jax.random.PRNGKey(1)))
    store, gids = build_sharded_index(corpus, cfg, jax.random.PRNGKey(0),
                                      shards=shards)
    res, t_shard = timed(
        lambda: index_knn(store, queries, jax.random.PRNGKey(1)))
    row_of = np.full(store.capacity, -1)
    row_of[gids] = np.arange(len(gids))

    def acc(idx, rows=False):
        got = row_of[np.asarray(idx)] if rows else np.asarray(idx)
        return float(np.mean([set(got[i].tolist())
                              == set(np.asarray(ex.indices[i]).tolist())
                              for i in range(Q)]))

    print(f"single-shard fused: {Q / t_single:8.1f} qps  "
          f"acc={acc(base.indices):.3f}")
    print(f"sharded (S={shards}):  {Q / t_shard:8.1f} qps  "
          f"acc={acc(res.indices, rows=True):.3f}  "
          f"balance={balance(store.live_per_shard):.2f}  "
          f"shard_ops={np.asarray(res.shard_coord_ops).astype(int).tolist()}")
    assert acc(res.indices, rows=True) == 1.0

    # mutation smoke over global ids: delete q0's true NN, insert a closer
    # point (least-loaded routing), compact with the returned remap
    nn0 = int(np.asarray(ex.indices[0])[0])
    store = sharded_delete(store, [gids[nn0]])
    store, slots, _ = sharded_insert(store, queries[:1])
    r2 = index_knn(store, queries[:1], jax.random.PRNGKey(2))
    assert int(np.asarray(r2.indices[0])[0]) == int(slots[0])
    # (skip nn0: the insert may have reused its freed slot)
    store = sharded_delete(
        store, gids[[r for r in range(n // 2 - 16, n) if r != nn0]])
    store, old_ids = sharded_maybe_compact(store, threshold=0.4)
    assert old_ids is not None
    r3 = index_knn(store, queries[:1], jax.random.PRNGKey(3))
    assert int(old_ids[int(np.asarray(r3.indices[0])[0])]) == int(slots[0])
    print(f"sharded mutation round-trip OK (insert/delete/compact), "
          f"total {time.perf_counter() - t_start:.1f}s")


def main(n: int = 1024, d: int = 1024, Q: int = 16, k: int = 5):
    t_start = time.perf_counter()
    corpus, queries = make_knn_benchmark_data("dense", n, d, Q, seed=8)
    cfg = BMOConfig(k=k, delta=0.01, block=128, batch_arms=32,
                    pulls_per_round=2, metric="l2")
    ex = oracle.exact_knn(corpus, queries, k, "l2")

    def timed(fn):
        fn()                                   # warm
        t0 = time.perf_counter()
        r = fn()
        jax.block_until_ready(r.values)
        return r, time.perf_counter() - t0

    base, t_base = timed(
        lambda: bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(0)))
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    batched, t_batch = timed(
        lambda: index_knn(store, queries, jax.random.PRNGKey(1)))

    def acc(idx):
        return float(np.mean([set(np.asarray(idx[i]).tolist())
                              == set(np.asarray(ex.indices[i]).tolist())
                              for i in range(Q)]))

    print(f"per-query lax.map: {Q / t_base:8.1f} qps  acc={acc(base.indices):.3f}")
    print(f"batched index:     {Q / t_batch:8.1f} qps  acc={acc(batched.indices):.3f}"
          f"  speedup={t_base / t_batch:.2f}x")

    # mutation smoke: delete the true NN of query 0, insert a closer point
    nn0 = int(np.asarray(ex.indices[0])[0])
    store = delete(store, [nn0])
    store, slots = insert(store, queries[:1])
    res = index_knn(store, queries[:1], jax.random.PRNGKey(2))
    top = int(np.asarray(res.indices[0])[0])
    assert top == int(slots[0]), (top, slots)
    store, old_ids = compact(store)
    res = index_knn(store, queries[:1], jax.random.PRNGKey(3))
    assert int(old_ids[int(np.asarray(res.indices[0])[0])]) == int(slots[0])
    print(f"mutation round-trip OK (insert/delete/compact), "
          f"total {time.perf_counter() - t_start:.1f}s")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=0,
                    help=">1: run the sharded smoke instead (needs that many "
                         "visible devices)")
    args = ap.parse_args()
    if args.shards > 1:
        main_sharded(args.shards)
    else:
        main()
