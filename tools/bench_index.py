"""Fast smoke entry for the index-serving benchmark (<60 s on CPU):
a scaled-down fig8 run plus a mutation round-trip, for CI and pre-commit.

    PYTHONPATH=src python tools/bench_index.py
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle
from repro.data.synthetic import make_knn_benchmark_data
from repro.index import build_index, compact, delete, index_knn, insert


def main(n: int = 1024, d: int = 1024, Q: int = 16, k: int = 5):
    t_start = time.perf_counter()
    corpus, queries = make_knn_benchmark_data("dense", n, d, Q, seed=8)
    cfg = BMOConfig(k=k, delta=0.01, block=128, batch_arms=32,
                    pulls_per_round=2, metric="l2")
    ex = oracle.exact_knn(corpus, queries, k, "l2")

    def timed(fn):
        fn()                                   # warm
        t0 = time.perf_counter()
        r = fn()
        jax.block_until_ready(r.values)
        return r, time.perf_counter() - t0

    base, t_base = timed(
        lambda: bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(0)))
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    batched, t_batch = timed(
        lambda: index_knn(store, queries, jax.random.PRNGKey(1)))

    def acc(idx):
        return float(np.mean([set(np.asarray(idx[i]).tolist())
                              == set(np.asarray(ex.indices[i]).tolist())
                              for i in range(Q)]))

    print(f"per-query lax.map: {Q / t_base:8.1f} qps  acc={acc(base.indices):.3f}")
    print(f"batched index:     {Q / t_batch:8.1f} qps  acc={acc(batched.indices):.3f}"
          f"  speedup={t_base / t_batch:.2f}x")

    # mutation smoke: delete the true NN of query 0, insert a closer point
    nn0 = int(np.asarray(ex.indices[0])[0])
    store = delete(store, [nn0])
    store, slots = insert(store, queries[:1])
    res = index_knn(store, queries[:1], jax.random.PRNGKey(2))
    top = int(np.asarray(res.indices[0])[0])
    assert top == int(slots[0]), (top, slots)
    store, old_ids = compact(store)
    res = index_knn(store, queries[:1], jax.random.PRNGKey(3))
    assert int(old_ids[int(np.asarray(res.indices[0])[0])]) == int(slots[0])
    print(f"mutation round-trip OK (insert/delete/compact), "
          f"total {time.perf_counter() - t_start:.1f}s")


if __name__ == "__main__":
    main()
