#!/usr/bin/env python
"""repro_lint — run the repo's invariant rule catalog (DESIGN.md §12).

Usage:
    python tools/repro_lint.py [paths...]         # default: src/repro
    python tools/repro_lint.py --json report.json # machine-readable report
    python tools/repro_lint.py --ledger           # print the δ-split table
    python tools/repro_lint.py --baseline-update  # refreeze the ratchet

Exit codes: 0 clean (new findings == 0), 1 new findings, 2 usage /
unparseable-file errors. Pre-existing findings frozen in the committed
baseline (tools/lint_baseline.json) report as [baselined] and do not
fail the run — the ratchet only stops NEW debt.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))

from repro.analysis import (LintEngine, baseline_from, default_rules,
                            load_baseline, save_baseline)

DEFAULT_BASELINE = os.path.join(_REPO, "tools", "lint_baseline.json")


def iter_files(paths):
    for p in paths:
        p = os.path.abspath(p)
        if os.path.isfile(p):
            yield p, os.path.relpath(p, _REPO).replace(os.sep, "/")
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fname in sorted(filenames):
                if fname.endswith(".py"):
                    full = os.path.join(dirpath, fname)
                    yield full, os.path.relpath(
                        full, _REPO).replace(os.sep, "/")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro_lint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*",
                    default=[os.path.join(_REPO, "src", "repro")])
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="ratchet baseline JSON (default: %(default)s)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding as new")
    ap.add_argument("--baseline-update", action="store_true",
                    help="refreeze the baseline from this run's findings")
    ap.add_argument("--json", metavar="FILE",
                    help="write the machine-readable report ('-' = stdout)")
    ap.add_argument("--ledger", action="store_true",
                    help="print the delta-split ledger table")
    args = ap.parse_args(argv)

    baseline = {}
    if not args.no_baseline and not args.baseline_update \
            and os.path.exists(args.baseline):
        try:
            baseline = load_baseline(args.baseline)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2

    engine = LintEngine(default_rules(), root=_REPO)
    report = engine.run(iter_files(args.paths), baseline)

    if args.baseline_update:
        save_baseline(args.baseline, baseline_from(report.findings))
        print(f"baseline refrozen: {len(report.findings)} finding(s) -> "
              f"{args.baseline}")
        return 0

    if args.json:
        doc = json.dumps(report.to_dict(), indent=1)
        if args.json == "-":
            print(doc)
        else:
            with open(args.json, "w", encoding="utf-8") as fh:
                fh.write(doc + "\n")

    for f, status in zip(report.findings, report.statuses()):
        print(f.render(status))
    for fp in report.stale:
        print(f"warning: stale baseline entry (fixed? shrink with "
              f"--baseline-update): {fp}")
    for err in report.errors:
        print(f"error: {err}", file=sys.stderr)

    if args.ledger:
        print("\ndelta-split ledger (sanctioned split sites):")
        for row in report.ledger:
            print(f"  {row['helper']:12s} {row['path']}:{row['line']} "
                  f"in {row['function']}")

    c = report.to_dict()["counts"]
    print(f"\n{c['total']} finding(s): {c['new']} new, "
          f"{c['baselined']} baselined, {c['suppressed']} suppressed, "
          f"{c['stale']} stale baseline entr(y/ies)")
    if report.errors:
        return 2
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
