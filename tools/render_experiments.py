"""Regenerate the generated sections of EXPERIMENTS.md from
results/dryrun.jsonl (roofline table + perf-variant table).

  PYTHONPATH=src:. python tools/render_experiments.py
"""
from __future__ import annotations

import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.roofline_table import load, markdown_table

ROOT = os.path.join(os.path.dirname(__file__), "..")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")


def perf_variant_table(rows) -> str:
    """All non-baseline variants + their baselines, grouped by cell."""
    cells = {}
    for (a, s, m, v), r in rows.items():
        if r.get("status") != "ok":
            continue
        cells.setdefault((a, s, m), {})[v] = r
    out = ["| cell | variant | t_comp | t_mem | t_coll | bottleneck | peak GiB | step = max(terms) |\n",
           "|---|---|---|---|---|---|---|---|\n"]
    for (a, s, m), variants in cells.items():
        if len(variants) < 2 and "baseline" in variants:
            continue
        for v, r in variants.items():
            step = max(r["t_compute"], r["t_memory"], r["t_collective"])
            out.append(f"| {a} × {s} × {m} | {v} | {r['t_compute']:.3g} | "
                       f"{r['t_memory']:.3g} | {r['t_collective']:.3g} | "
                       f"{r['bottleneck']} | "
                       f"{r['peak_memory_per_chip'] / 2**30:.1f} | {step:.3g} |\n")
    return "".join(out)


def main():
    rows = load(os.path.join(ROOT, "results", "dryrun.jsonl"))
    base = {k: v for k, v in rows.items() if k[3] == "baseline"}
    table = markdown_table(base, mesh="single")
    text = open(EXP).read()
    text = re.sub(
        r"<!-- ROOFLINE_TABLE_SINGLE -->.*?(?=\n### |\Z)",
        "<!-- ROOFLINE_TABLE_SINGLE -->\n" + table + "\n",
        text, flags=re.S)
    text = re.sub(
        r"<!-- PERF_VARIANTS -->.*?(?=\n### |\n## |\Z)",
        "<!-- PERF_VARIANTS -->\n" + perf_variant_table(rows) + "\n",
        text, flags=re.S)
    open(EXP, "w").write(text)
    print("rendered", EXP)


if __name__ == "__main__":
    main()
