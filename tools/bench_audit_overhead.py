"""Measure the serving-side overhead of the shadow δ-auditor
(DESIGN.md §10.6 — the PR 8 acceptance gate: ≤ 2% qps at audit_rate=0.05).

What the auditor charges the serving path is ONLY ``offer()``: an RNG
draw plus array copies into a bounded reservoir. The oracle itself runs
off-path (``audit_flush`` after the timed window here; idle plane steps
in production). This bench isolates that charge the same way the PR 6
tracing-overhead bench did:

  * ONE process, ONE index, ONE jit cache — both arms race identical
    query batches through identical ``RequestPlane``s, differing only in
    ``audit_rate`` (0.05 vs 0.0).
  * paired A/B rounds with ALTERNATING order (A,B then B,A), so drift
    (thermal, allocator) cancels instead of biasing one arm.
  * the reported statistic is the MEDIAN over rounds of the per-round
    qps ratio — robust to a straggler round.

    PYTHONPATH=src python tools/bench_audit_overhead.py --smoke
    PYTHONPATH=src python tools/bench_audit_overhead.py \
        --out BENCH_audit_overhead.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.api import Index
from repro.configs.base import BMOConfig
from repro.data.synthetic import make_knn_benchmark_data
from repro.serve.plane import PlaneConfig, RequestPlane


def _run_round(plane, reqs, seed0):
    """Submit + drain every request batch; returns elapsed seconds."""
    t = time.perf_counter()
    for i, r in enumerate(reqs):
        plane.submit(r, rng=jax.random.PRNGKey(seed0 + i), cache="bypass")
    plane.drain()
    return time.perf_counter() - t


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=2048)
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--requests", type=int, default=24,
                    help="request batches per round per arm")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--audit-rate", type=float, default=0.05)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    if args.smoke:
        args.n, args.d, args.requests, args.rounds = 1024, 1024, 10, 4

    t0 = time.perf_counter()
    corpus, _ = make_knn_benchmark_data("dense", args.n, args.d, 2,
                                        seed=args.seed)
    cfg = BMOConfig(k=args.k, delta=0.05, block=min(128, args.d),
                    batch_arms=32, metric="l2")
    index = Index.build(corpus, cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed + 1)
    reqs = [(corpus[rng.integers(0, args.n, args.q)]
             + 0.05 * rng.normal(size=(args.q, args.d))).astype(np.float32)
            for _ in range(args.requests)]

    mk = lambda rate: RequestPlane(index, PlaneConfig(
        audit_rate=rate, audit_reservoir=args.requests * args.rounds + 8))
    audited, plain = mk(args.audit_rate), mk(0.0)

    # warm both arms with one FULL untimed round each: the scheduler
    # coalesces concurrent tickets into larger group sizes than any single
    # submit reaches, and those pow2 specializations must compile before
    # either arm's clock starts (they share one jit cache anyway)
    for p in (audited, plain):
        _run_round(p, reqs, seed0=1)

    n_queries = args.requests * args.q
    qps_a, qps_p, ratios = [], [], []
    for r in range(args.rounds):
        pair = [(audited, qps_a), (plain, qps_p)]
        if r % 2:                           # alternate order per round
            pair.reverse()
        for plane, sink in pair:
            dt = _run_round(plane, reqs, seed0=1000 * (r + 1))
            sink.append(n_queries / dt)
        ratios.append(qps_a[-1] / qps_p[-1])

    # the oracle bill is paid here, after every timed window closed
    t_flush = time.perf_counter()
    flushed = audited.audit_flush()
    flush_s = time.perf_counter() - t_flush
    a = audited.auditor.summary()

    overhead = 1.0 - float(np.median(ratios))
    out = {
        "schema_version": 1,
        "config": {"n": args.n, "d": args.d, "q": args.q, "k": args.k,
                   "requests": args.requests, "rounds": args.rounds,
                   "audit_rate": args.audit_rate,
                   "smoke": bool(args.smoke)},
        "qps_audited_median": round(float(np.median(qps_a)), 2),
        "qps_plain_median": round(float(np.median(qps_p)), 2),
        "qps_ratio_per_round": [round(x, 4) for x in ratios],
        "qps_ratio_median": round(float(np.median(ratios)), 4),
        "overhead_frac": round(overhead, 4),
        "budget_frac": 0.02,
        "within_budget": bool(overhead <= 0.02),
        "audit": {"flushed_tickets": flushed,
                  "sampled_rows": a["sampled_rows"],
                  "mismatch_rows": a["mismatch_rows"],
                  "err_upper": round(a["err_upper"], 6),
                  "offpath_flush_s": round(flush_s, 3)},
        "wall_s": round(time.perf_counter() - t0, 1),
    }
    print(json.dumps(out, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1)
        print(f"[bench_audit_overhead] wrote {args.out}")
    return out


if __name__ == "__main__":
    main()
