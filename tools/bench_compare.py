"""Diff a fresh bench JSON against a committed baseline (CI perf gate).

Every bench in this repo emits ``{"bench": ..., "entries": [...]}`` with
identity fields (mode / Q / n / d / R / shards / …) and measurement
fields (qps*, acc*, latency percentiles). This tool matches entries
between a fresh run and a committed ``BENCH_*.json`` baseline on their
shared identity fields and enforces:

  * every ``qps*`` field: fresh >= --tol × baseline (qps tolerance band —
    CI machines are noisy, so the default band is wide; the gate exists
    to catch structural regressions, not 10% jitter),
  * every ``acc*`` field: fresh >= baseline - 1e-6 (exactness never
    regresses, no tolerance),
  * at least one entry pair must match (a baseline that matches nothing
    is a broken gate, not a pass).

Exit status: 0 clean, 1 regression / no matches, 2 usage.

    PYTHONPATH=src python tools/bench_compare.py fresh.json baseline.json
    PYTHONPATH=src python tools/bench_compare.py fresh.json baseline.json \\
        --tol 0.5
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Tuple

#: fields that IDENTIFY an entry (matched on equality when present in both)
ID_FIELDS = ("bench", "mode", "Q", "n", "d", "k", "R", "shards",
             "shards_from", "shards_to")


def _identity(entry: dict) -> tuple:
    return tuple((f, entry[f]) for f in ID_FIELDS if f in entry)


def _load(path: str) -> List[dict]:
    with open(path) as f:
        doc = json.load(f)
    entries = doc.get("entries", [])
    for e in entries:
        e.setdefault("bench", doc.get("bench"))
    return entries


def compare(fresh: List[dict], baseline: List[dict], *,
            tol: float = 0.5) -> Tuple[bool, List[str]]:
    """Returns (ok, report rows). ``tol`` is the minimum fresh/baseline
    qps ratio tolerated."""
    base_by_id = {_identity(e): e for e in baseline}
    rows, ok, matched = [], True, 0
    for e in fresh:
        b = base_by_id.get(_identity(e))
        if b is None:
            continue
        matched += 1
        ident = " ".join(f"{k}={v}" for k, v in _identity(e))
        for field in sorted(set(e) & set(b)):
            fv, bv = e[field], b[field]
            if not isinstance(fv, (int, float)) or \
                    not isinstance(bv, (int, float)):
                continue
            if field.startswith("qps"):
                ratio = fv / bv if bv else float("inf")
                bad = ratio < tol
                ok &= not bad
                rows.append(
                    f"{'FAIL' if bad else ' ok '} [{ident}] {field}: "
                    f"{fv:.1f} vs baseline {bv:.1f} "
                    f"(x{ratio:.2f}, floor x{tol:.2f})")
            elif field.startswith("acc"):
                bad = fv < bv - 1e-6
                ok &= not bad
                rows.append(
                    f"{'FAIL' if bad else ' ok '} [{ident}] {field}: "
                    f"{fv:.4f} vs baseline {bv:.4f} (no tolerance)")
    if matched == 0:
        ok = False
        rows.append("FAIL no fresh entry matched any baseline entry — "
                    "identity fields drifted or wrong baseline file")
    return ok, rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff fresh bench JSON vs a committed baseline")
    ap.add_argument("fresh", help="freshly produced BENCH JSON")
    ap.add_argument("baseline", help="committed baseline BENCH JSON")
    ap.add_argument("--tol", type=float, default=0.5,
                    help="minimum tolerated fresh/baseline qps ratio "
                         "(default 0.5: flag >2x slowdowns, ignore jitter)")
    args = ap.parse_args(argv)
    try:
        fresh = _load(args.fresh)
        baseline = _load(args.baseline)
    except (OSError, ValueError) as e:
        print(f"bench_compare: cannot load inputs: {e}", file=sys.stderr)
        return 2
    ok, rows = compare(fresh, baseline, tol=args.tol)
    for row in rows:
        print(row)
    print(f"bench_compare: {'CLEAN' if ok else 'REGRESSION'} "
          f"({args.fresh} vs {args.baseline}, tol {args.tol})")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
