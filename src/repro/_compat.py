"""Compatibility shims for older jax releases (this tree targets the
jax.make_mesh(axis_types=...) API from jax >= 0.6; the pinned toolchain may
ship an older jax where Auto axis types are implicit and the kwarg does not
exist yet).

Installed once from ``repro/__init__.py``:
  * ``jax.sharding.AxisType`` — enum stub when absent (Auto semantics are the
    old default, so dropping the annotation is behaviour-preserving),
  * ``jax.make_mesh`` — wrapper that swallows ``axis_types`` when the
    installed signature predates it,
  * ``jax.shard_map`` — aliased from ``jax.experimental.shard_map`` with
    ``check_vma`` mapped onto the old ``check_rep`` knob,
  * ``pallas.tpu.CompilerParams`` — aliased from the pre-rename
    ``TPUCompilerParams``.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax


def install() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        orig = jax.make_mesh

        @functools.wraps(orig)
        def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
            del axis_types  # pre-0.6 meshes are Auto along every axis
            return orig(axis_shapes, axis_names, **kw)

        jax.make_mesh = make_mesh

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
            if check_vma is not None:
                kw.setdefault("check_rep", check_vma)
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

        jax.shard_map = shard_map

    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:  # pragma: no cover
        pltpu = None
    if (pltpu is not None and not hasattr(pltpu, "CompilerParams")
            and hasattr(pltpu, "TPUCompilerParams")):
        pltpu.CompilerParams = pltpu.TPUCompilerParams
