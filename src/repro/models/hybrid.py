"""zamba2-2.7b: Mamba2 backbone + a *shared* attention+MLP block applied every
``attn_every`` layers (weights reused across applications, zamba-style; each
application keeps its own KV cache)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import common as cm
from repro.models import ssm
from repro.models.transformer import _remat
from repro.sharding.spec import ParamSpec


class Zamba2:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        assert cfg.attn_every > 0 and cfg.n_layers % cfg.attn_every == 0
        self.groups = cfg.n_layers // cfg.attn_every
        self.per_group = cfg.attn_every

    def param_specs(self, dtype=jnp.float32):
        cfg = self.cfg
        mamba_layer = {
            "ln": cm.rmsnorm_spec(cfg.d_model, dtype),
            "mamba": ssm.mamba2_specs(cfg, dtype),
        }
        shared = {
            "ln1": cm.rmsnorm_spec(cfg.d_model, dtype),
            "attn": cm.attention_specs(cfg, dtype),
            "ln2": cm.rmsnorm_spec(cfg.d_model, dtype),
            "mlp": cm.mlp_specs(cfg, dtype),
        }
        return {
            "embed": cm.embed_specs(cfg, dtype),
            "layers": cm.stack_tree(mamba_layer, cfg.n_layers),
            "shared": shared,
            "final_norm": cm.rmsnorm_spec(cfg.d_model, dtype),
        }

    def cache_specs(self, batch_size: int, max_seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        g = self.groups
        kv_shape = (g, batch_size, max_seq, cfg.n_kv_heads, cfg.head_dim_)
        axes = ("layers", "batch", "kv_len", "kv_heads", "head_dim")
        return {
            "mamba": ssm.mamba2_state_specs(cfg, cfg.n_layers, batch_size, dtype),
            "k": ParamSpec(kv_shape, dtype, axes, init="zeros"),
            "v": ParamSpec(kv_shape, dtype, axes, init="zeros"),
            "index": ParamSpec((), jnp.int32, (), init="zeros"),
        }

    def _forward(self, params, x, positions, cache, cache_index, compute_dtype, remat):
        cfg = self.cfg
        g, pg = self.groups, self.per_group
        reshape_g = lambda t: t.reshape((g, pg) + t.shape[1:])
        layers_g = jax.tree_util.tree_map(reshape_g, params["layers"])

        def mamba_body(carry, scanned):
            x = carry
            if cache is None:
                lp, ls = scanned, None
            else:
                lp, ls = scanned
            h = cm.rmsnorm(x, lp["ln"], cfg.norm_eps)
            out, ns = ssm.mamba2_block(cfg, lp["mamba"], h, state=ls,
                                       compute_dtype=compute_dtype)
            return x + out, ns

        mamba_body = _remat(mamba_body, remat)
        sp = params["shared"]

        def group_body(carry, scanned):
            x = carry
            if cache is None:
                glayers, gkv = scanned, None
                x, _ = jax.lax.scan(mamba_body, x, glayers)
            else:
                glayers, gms, gkv = scanned
                x, nms = jax.lax.scan(mamba_body, x, (glayers, gms))
            h = cm.rmsnorm(x, sp["ln1"], cfg.norm_eps)
            attn_out, new_kv = cm.gqa_attention(
                cfg, sp["attn"], h, positions, cache_kv=gkv,
                cache_index=cache_index, compute_dtype=compute_dtype)
            x = x + attn_out
            h = cm.rmsnorm(x, sp["ln2"], cfg.norm_eps)
            x = x + cm.mlp(cfg, sp["mlp"], h, compute_dtype)
            if cache is None:
                return x, None
            return x, (nms, new_kv)

        group_body = _remat(group_body, remat)
        if cache is None:
            x, _ = jax.lax.scan(group_body, x, layers_g)
            return x, None
        mamba_g = jax.tree_util.tree_map(reshape_g, cache["mamba"])
        x, (new_ms, new_kv) = jax.lax.scan(
            group_body, x, (layers_g, mamba_g, (cache["k"], cache["v"])))
        unshape = lambda t: t.reshape((g * pg,) + t.shape[2:])
        new_cache = {
            "mamba": jax.tree_util.tree_map(unshape, new_ms),
            "k": new_kv[0], "v": new_kv[1],
        }
        return x, new_cache

    def apply(self, params, batch, *, remat="full", compute_dtype=jnp.bfloat16,
              cache=None, cache_index=0):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = cm.shard_act(cm.embed(params["embed"], tokens, compute_dtype))
        positions = batch.get(
            "positions", jnp.broadcast_to(jnp.arange(S)[None], (B, S)) + cache_index)
        x, new_cache = self._forward(params, x, positions, cache, cache_index,
                                     compute_dtype, remat)
        x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = cm.lm_head(params["embed"], x, compute_dtype)
        if new_cache is not None:
            new_cache["index"] = cache["index"] + S
        return logits, new_cache

    def decode_step(self, params, cache, tokens, *, compute_dtype=jnp.bfloat16):
        B = tokens.shape[0]
        positions = jnp.broadcast_to(cache["index"][None, None], (B, 1))
        return self.apply(params, {"tokens": tokens, "positions": positions},
                          remat="none", compute_dtype=compute_dtype, cache=cache,
                          cache_index=cache["index"])

    def prefill(self, params, batch, cache, *, remat="none", compute_dtype=jnp.bfloat16):
        return self.apply(params, batch, remat=remat, compute_dtype=compute_dtype,
                          cache=cache, cache_index=0)

    def input_specs(self, shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
