"""Shared model-layer library: norms, RoPE/M-RoPE, GQA/MQA/MLA attention,
KV caches, MLP flavours.  Everything is a pure function over explicit params;
parameter structure is declared via ParamSpec trees (see repro.sharding.spec).

Dtype policy: params live in ``param_dtype``; matmuls run in bf16 ("compute
dtype"), softmax / norms / router / residual accumulation in fp32.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.sharding.context import shard_act  # re-export for model modules
from repro.sharding.spec import ParamSpec

# ---------------------------------------------------------------------------
# Param-spec builders
# ---------------------------------------------------------------------------


def dense_spec(shape, axes, dtype, init="fanin", scale=None) -> ParamSpec:
    return ParamSpec(tuple(shape), dtype, tuple(axes), init=init, scale=scale)


def stack(spec: ParamSpec, n_layers: int) -> ParamSpec:
    """Add a leading stacked-layers dim (scanned over)."""
    return ParamSpec(
        (n_layers,) + spec.shape, spec.dtype, ("layers",) + spec.axes,
        init=spec.init, scale=spec.scale,
    )


def stack_tree(tree, n_layers: int):
    return jax.tree_util.tree_map(
        lambda s: stack(s, n_layers), tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_spec(d: int, dtype=jnp.float32) -> ParamSpec:
    return ParamSpec((d,), dtype, ("act_embed",), init="ones")


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def _rope_cos_sin(positions: jax.Array, rot_dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, rot_dim//2), fp32."""
    half = rot_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rot_dim: Optional[int] = None) -> jax.Array:
    """x (B, S, H, D); positions (B, S). Rotates the first rot_dim dims."""
    d = x.shape[-1]
    rot = rot_dim if rot_dim is not None else d
    cos, sin = _rope_cos_sin(positions, rot, theta)      # (B, S, rot/2)
    cos = cos[..., None, :]                               # (B, S, 1, rot/2)
    sin = sin[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1) if rot < d else out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE. x (B,S,H,D); positions3 (3,B,S);
    ``sections`` split D//2 into (temporal, h, w) frequency bands."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    cos_parts, sin_parts = [], []
    start = 0
    freqs_all = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    for sec_i, sec in enumerate(sections):
        pos = positions3[sec_i].astype(jnp.float32)       # (B, S)
        f = freqs_all[start:start + sec]
        ang = pos[..., None] * f                          # (B, S, sec)
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        start += sec
    cos = jnp.concatenate(cos_parts, axis=-1)[..., None, :]  # (B,S,1,half)
    sin = jnp.concatenate(sin_parts, axis=-1)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(seq_len: int, d: int) -> np.ndarray:
    pos = np.arange(seq_len)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return emb.astype(np.float32)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA), chunked for long sequences
# ---------------------------------------------------------------------------


def attention_specs(cfg: ModelConfig, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    spec = {
        "wq": dense_spec((d, h, hd), ("embed", "heads", "head_dim"), dtype),
        "wk": dense_spec((d, kv, hd), ("embed", "kv_heads", "head_dim"), dtype),
        "wv": dense_spec((d, kv, hd), ("embed", "kv_heads", "head_dim"), dtype),
        "wo": dense_spec((h, hd, d), ("heads", "head_dim", "embed"), dtype),
    }
    if cfg.qkv_bias:
        spec["bq"] = ParamSpec((h, hd), dtype, ("heads", "head_dim"), init="zeros")
        spec["bk"] = ParamSpec((kv, hd), dtype, ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = ParamSpec((kv, hd), dtype, ("kv_heads", "head_dim"), init="zeros")
    return spec


def _sdpa(q, k, v, *, causal: bool, q_offset, kv_valid_len=None):
    """q (B,Sq,H,D), k/v (B,Sk,KV,D) -> (B,Sq,H,D). fp32 softmax.

    ``q_offset``: absolute position of q[0] (for causal masking vs cache).
    ``kv_valid_len``: mask out kv positions >= this (decode with cache).
    """
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(D)
    Sk = k.shape[1]
    kv_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        q_pos = jnp.arange(Sq) + q_offset
        mask = kv_pos[None, :] <= q_pos[:, None]
    if kv_valid_len is not None:
        mask = mask & (kv_pos[None, :] < kv_valid_len)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])


def _sdpa_flash(q, k, v, *, causal: bool, q_offset, kv_valid_len=None,
                kv_chunk: int = 1024):
    """Online-softmax (flash-style) attention: scans KV chunks carrying
    (running max, normalizer, accumulator); the (Sq, Sk) score matrix is
    never materialized. Matches _sdpa numerically (fp32 softmax)."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    Dv = v.shape[-1]
    if Sk % kv_chunk != 0:
        kv_chunk = Sk
    nk = Sk // kv_chunk
    qg = q.reshape(B, Sq, KV, G, D)
    q_pos = jnp.arange(Sq) + q_offset

    ks = k.reshape(B, nk, kv_chunk, KV, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kv_chunk, KV, Dv).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint  # flash backward: recompute s/p per chunk, save only carries
    def body(carry, inp):
        m, l, acc = carry
        kc, vc, ci = inp
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc,
                       preferred_element_type=jnp.float32) / np.sqrt(D)
        kv_pos = jnp.arange(kv_chunk) + ci * kv_chunk
        mask = jnp.ones((Sq, kv_chunk), bool)
        if causal:
            mask = kv_pos[None, :] <= q_pos[:, None]
        if kv_valid_len is not None:
            mask = mask & (kv_pos[None, :] < kv_valid_len)
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(q.dtype), vc).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (ks, vs, jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv).astype(q.dtype)


def sdpa(q, k, v, *, causal: bool, q_offset=0, kv_valid_len=None,
         chunk: int = 0, flash_threshold: int = 2048):
    """Scaled dot-product attention. Long sequences use q-chunking (outer
    scan) + flash-style online softmax over KV chunks so the score matrix
    never materializes; short ones take the direct path.

    Decode (Sq ≤ 8) always takes the direct path: the (Sq, Sk) scores are
    tiny, and the flash chunk reshape fights the sharded KV cache layout
    (SPMD would all-gather the cache per chunk — §Perf iteration)."""
    Sq, Sk = q.shape[1], k.shape[1]
    use_flash = Sk > flash_threshold and Sq > 8

    def one(qc, off):
        if use_flash:
            return _sdpa_flash(qc, k, v, causal=causal, q_offset=off,
                               kv_valid_len=kv_valid_len)
        return _sdpa(qc, k, v, causal=causal, q_offset=off,
                     kv_valid_len=kv_valid_len)

    if chunk <= 0 or Sq <= chunk:
        return one(q, q_offset)
    assert Sq % chunk == 0, (Sq, chunk)
    n_chunks = Sq // chunk

    def body(carry, qc_i):
        qc, i = qc_i
        return carry, one(qc, q_offset + i * chunk)

    qs = q.reshape(q.shape[0], n_chunks, chunk, q.shape[2], q.shape[3]).transpose(1, 0, 2, 3, 4)
    _, outs = jax.lax.scan(body, None, (qs, jnp.arange(n_chunks)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(q.shape[:3] + (v.shape[-1],))


def quantize_kv(t: jax.Array):
    """(B,S,H,D) bf16 -> (int8 values, (B,S,H) bf16 scales)."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]).astype(dtype)


def gqa_attention(cfg: ModelConfig, p: dict, x: jax.Array, positions, *,
                  cache_kv=None, cache_index=None, causal=True,
                  positions3=None, compute_dtype=jnp.bfloat16):
    """Full GQA attention layer. Returns (out, new_kv) where new_kv is the
    (k, v) pair to store in the cache (or None when cache_kv is None).
    With cfg.kv_quant the cache entries are (int8 values, bf16 scales) —
    halves the decode-path HBM read volume (§Perf)."""
    xc = x.astype(compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", xc, p["wq"].astype(compute_dtype))
    k = jnp.einsum("bsd,dhk->bshk", xc, p["wk"].astype(compute_dtype))
    v = jnp.einsum("bsd,dhk->bshk", xc, p["wv"].astype(compute_dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(compute_dtype)
        k = k + p["bk"].astype(compute_dtype)
        v = v + p["bv"].astype(compute_dtype)
    if cfg.rope_type == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_type == "mrope":
        q = apply_mrope(q, positions3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions3, cfg.rope_theta, cfg.mrope_sections)

    if cache_kv is not None and cfg.kv_quant:
        (ckq, cks), (cvq, cvs) = cache_kv
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        ckq = jax.lax.dynamic_update_slice(ckq, kq, (0, cache_index, 0, 0))
        cks = jax.lax.dynamic_update_slice(cks, ks, (0, cache_index, 0))
        cvq = jax.lax.dynamic_update_slice(cvq, vq, (0, cache_index, 0, 0))
        cvs = jax.lax.dynamic_update_slice(cvs, vs, (0, cache_index, 0))
        valid = cache_index + k.shape[1]
        out = sdpa(q, dequantize_kv(ckq, cks, compute_dtype),
                   dequantize_kv(cvq, cvs, compute_dtype),
                   causal=causal, q_offset=cache_index, kv_valid_len=valid,
                   chunk=cfg.attn_chunk if q.shape[1] > cfg.attn_chunk else 0)
        new_kv = ((ckq, cks), (cvq, cvs))
    elif cache_kv is not None:
        ck, cv = cache_kv
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_index, 0, 0))
        valid = cache_index + k.shape[1]
        out = sdpa(q, ck.astype(compute_dtype), cv.astype(compute_dtype),
                   causal=causal, q_offset=cache_index, kv_valid_len=valid,
                   chunk=cfg.attn_chunk if q.shape[1] > cfg.attn_chunk else 0)
        new_kv = (ck, cv)
    elif cfg.attn_impl == "pallas":
        # fused flash-attention kernel (TPU target; interpret on CPU) —
        # the §Roofline fix: scores/softmax/accumulator stay in VMEM
        from repro.kernels.flash_attn import flash_attention_pallas
        G = q.shape[2] // k.shape[2]
        out = flash_attention_pallas(
            q.transpose(0, 2, 1, 3),
            jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1),
            jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1),
            causal=causal, q_offset=0,
            interpret=jax.default_backend() == "cpu",
        ).transpose(0, 2, 1, 3)
        new_kv = None
    else:
        out = sdpa(q, k, v, causal=causal, q_offset=0,
                   chunk=cfg.attn_chunk if q.shape[1] > cfg.attn_chunk else 0)
        new_kv = None
    proj = jnp.einsum("bshk,hkd->bsd", out.astype(compute_dtype),
                      p["wo"].astype(compute_dtype))
    return proj.astype(x.dtype), new_kv


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, dtype, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    if cfg.mlp_act == "swiglu":
        return {
            "wi_gate": dense_spec((d, f), ("embed", "mlp"), dtype),
            "wi_up": dense_spec((d, f), ("embed", "mlp"), dtype),
            "wo": dense_spec((f, d), ("mlp", "embed"), dtype),
        }
    return {
        "wi": dense_spec((d, f), ("embed", "mlp"), dtype),
        "wo": dense_spec((f, d), ("mlp", "embed"), dtype),
    }


def mlp(cfg: ModelConfig, p: dict, x: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    xc = x.astype(compute_dtype)
    if cfg.mlp_act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", xc, p["wi_gate"].astype(compute_dtype))
        u = jnp.einsum("bsd,df->bsf", xc, p["wi_up"].astype(compute_dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * u
    else:
        h = jnp.einsum("bsd,df->bsf", xc, p["wi"].astype(compute_dtype))
        if cfg.mlp_act == "sq_relu":
            h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(compute_dtype)
        else:  # gelu
            h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(compute_dtype)
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(compute_dtype))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embed_specs(cfg: ModelConfig, dtype) -> dict:
    spec = {"tok": dense_spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                              dtype, init="embed")}
    if not cfg.tie_embeddings:
        spec["head"] = dense_spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dtype)
    return spec


def embed(p: dict, tokens: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0).astype(compute_dtype)


def lm_head(p: dict, x: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    w = p.get("head")
    if w is None:
        w = p["tok"].T
    logits = jnp.einsum("bsd,dv->bsv", x.astype(compute_dtype), w.astype(compute_dtype))
    return logits
