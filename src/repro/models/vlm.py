"""qwen2-vl-2b backbone: dense decoder LM with M-RoPE (3D rotary sections for
temporal/height/width position ids). The vision tower is a STUB per the
assignment: ``input_specs`` provides precomputed patch embeddings merged into
the token stream, plus the (3, B, S) position ids M-RoPE consumes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import common as cm
from repro.models.transformer import DenseLM, _remat


class VLM(DenseLM):
    def _layer(self, lp, x, positions3, cache_kv, cache_index, compute_dtype):
        cfg = self.cfg
        h = cm.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        attn_out, new_kv = cm.gqa_attention(
            cfg, lp["attn"], h, None, cache_kv=cache_kv, cache_index=cache_index,
            causal=True, positions3=positions3, compute_dtype=compute_dtype)
        x = x + attn_out
        h = cm.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + cm.mlp(cfg, lp["mlp"], h, compute_dtype)
        return x, new_kv

    def apply(self, params, batch, *, remat: str = "full",
              compute_dtype=jnp.bfloat16, cache=None, cache_index=0):
        """batch: {"embeds": (B,S,d) float stub embeddings, "positions3":
        (3,B,S) int32}. Token ids are already folded into ``embeds``."""
        cfg = self.cfg
        x = cm.shard_act(batch["embeds"].astype(compute_dtype))
        B, S = x.shape[:2]
        positions3 = batch.get("positions3")
        if positions3 is None:
            p = jnp.broadcast_to(jnp.arange(S)[None], (B, S)) + cache_index
            positions3 = jnp.broadcast_to(p[None], (3, B, S))

        def body(carry, scanned):
            x = carry
            if cache is None:
                lp = scanned
                x, _ = self._layer(lp, x, positions3, None, cache_index, compute_dtype)
                return x, None
            lp, (ck, cv) = scanned
            x, new_kv = self._layer(lp, x, positions3, (ck, cv), cache_index,
                                    compute_dtype)
            return x, new_kv

        body = _remat(body, remat)
        if cache is None:
            x, _ = jax.lax.scan(body, x, params["layers"])
            new_cache = None
        else:
            x, new_kv = jax.lax.scan(body, x, (params["layers"], (cache["k"], cache["v"])))
            new_cache = {"k": new_kv[0], "v": new_kv[1], "index": cache["index"] + S}
        x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = cm.lm_head(params["embed"], x, compute_dtype)
        return logits, new_cache

    def decode_step(self, params, cache, batch, *, compute_dtype=jnp.bfloat16):
        """batch: {"embeds": (B,1,d)} — text-mode decode: all 3 position
        streams equal the current index."""
        if isinstance(batch, dict):
            embeds = batch["embeds"]
        else:  # token array fallback: embed through the table
            embeds = jnp.take(params["embed"]["tok"], batch, axis=0)
        B = embeds.shape[0]
        pos = jnp.broadcast_to(cache["index"][None, None, None], (3, B, 1))
        logits, new_cache = self.apply(
            params, {"embeds": embeds, "positions3": pos}, remat="none",
            compute_dtype=compute_dtype, cache=cache, cache_index=cache["index"])
        return logits, new_cache

    def input_specs(self, shape: ShapeConfig):
        B, S, d = shape.global_batch, shape.seq_len, self.cfg.d_model
        f32, i32 = jnp.float32, jnp.int32
        if shape.kind == "train":
            return {"embeds": jax.ShapeDtypeStruct((B, S, d), jnp.bfloat16),
                    "positions3": jax.ShapeDtypeStruct((3, B, S), i32),
                    "labels": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "prefill":
            return {"embeds": jax.ShapeDtypeStruct((B, S, d), jnp.bfloat16),
                    "positions3": jax.ShapeDtypeStruct((3, B, S), i32)}
        return {"embeds": jax.ShapeDtypeStruct((B, 1, d), jnp.bfloat16)}
