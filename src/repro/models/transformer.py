"""Dense decoder-only transformer LM (llama3 / qwen2.5 / granite / nemotron
families) with scan-over-layers, optional remat, and KV-cache decode."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import common as cm
from repro.sharding.spec import ParamSpec


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "full"


class DenseLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameters ---------------------------------------------------------
    def param_specs(self, dtype=jnp.float32):
        cfg = self.cfg
        layer = {
            "ln1": cm.rmsnorm_spec(cfg.d_model, dtype),
            "attn": cm.attention_specs(cfg, dtype),
            "ln2": cm.rmsnorm_spec(cfg.d_model, dtype),
            "mlp": cm.mlp_specs(cfg, dtype),
        }
        return {
            "embed": cm.embed_specs(cfg, dtype),
            "layers": cm.stack_tree(layer, cfg.n_layers),
            "final_norm": cm.rmsnorm_spec(cfg.d_model, dtype),
        }

    # -- layer body ---------------------------------------------------------
    def _layer(self, lp, x, positions, cache_kv, cache_index, compute_dtype):
        cfg = self.cfg
        h = cm.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        attn_out, new_kv = cm.gqa_attention(
            cfg, lp["attn"], h, positions, cache_kv=cache_kv,
            cache_index=cache_index, causal=True, compute_dtype=compute_dtype)
        x = x + attn_out
        h = cm.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + cm.mlp(cfg, lp["mlp"], h, compute_dtype)
        return x, new_kv

    # -- forward ------------------------------------------------------------
    def apply(self, params, batch, *, remat: str = "full",
              compute_dtype=jnp.bfloat16, cache=None, cache_index=0,
              return_hidden: bool = False):
        """batch: {"tokens": (B, S)}. Returns (logits, new_cache|None) or,
        with return_hidden, (logits, new_cache, final_hidden (B, S, d)) —
        used by the kNN-LM retrieval hook."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = cm.shard_act(cm.embed(params["embed"], tokens, compute_dtype))
        positions = batch.get(
            "positions", jnp.broadcast_to(jnp.arange(S)[None], (B, S)) + cache_index)

        def body(carry, scanned):
            x = carry
            if cache is None:
                lp = scanned
                x, _ = self._layer(lp, x, positions, None, cache_index, compute_dtype)
                return x, None
            lp, kv = scanned
            x, new_kv = self._layer(lp, x, positions, kv, cache_index, compute_dtype)
            return x, new_kv

        body = _remat(body, remat)
        if cache is None:
            x, _ = jax.lax.scan(body, x, params["layers"])
            new_cache = None
        elif cfg.kv_quant:
            kv_in = ((cache["k_q"], cache["k_s"]), (cache["v_q"], cache["v_s"]))
            x, new_kv = jax.lax.scan(body, x, (params["layers"], kv_in))
            (kq, ks), (vq, vs) = new_kv
            new_cache = {"k_q": kq, "k_s": ks, "v_q": vq, "v_s": vs,
                         "index": cache["index"] + S}
        else:
            x, new_kv = jax.lax.scan(body, x, (params["layers"], (cache["k"], cache["v"])))
            new_cache = {"k": new_kv[0], "v": new_kv[1], "index": cache["index"] + S}
        x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = cm.lm_head(params["embed"], x, compute_dtype)
        if return_hidden:
            return logits, new_cache, x
        return logits, new_cache

    # -- serving ------------------------------------------------------------
    def cache_specs(self, batch_size: int, max_seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        kv_shape = (cfg.n_layers, batch_size, max_seq, cfg.n_kv_heads, cfg.head_dim_)
        axes = ("layers", "batch", "kv_len", "kv_heads", "head_dim")
        if cfg.kv_quant:
            s_shape, s_axes = kv_shape[:-1], axes[:-1]
            return {
                "k_q": ParamSpec(kv_shape, jnp.int8, axes, init="zeros"),
                "k_s": ParamSpec(s_shape, jnp.bfloat16, s_axes, init="ones"),
                "v_q": ParamSpec(kv_shape, jnp.int8, axes, init="zeros"),
                "v_s": ParamSpec(s_shape, jnp.bfloat16, s_axes, init="ones"),
                "index": ParamSpec((), jnp.int32, (), init="zeros"),
            }
        return {
            "k": ParamSpec(kv_shape, dtype, axes, init="zeros"),
            "v": ParamSpec(kv_shape, dtype, axes, init="zeros"),
            "index": ParamSpec((), jnp.int32, (), init="zeros"),
        }

    def decode_step(self, params, cache, tokens, *, compute_dtype=jnp.bfloat16,
                    return_hidden: bool = False):
        """tokens (B, 1); cache index = current length. Returns (logits, cache)."""
        B = tokens.shape[0]
        positions = jnp.broadcast_to(cache["index"][None, None], (B, 1))
        return self.apply(
            params, {"tokens": tokens, "positions": positions}, remat="none",
            compute_dtype=compute_dtype, cache=cache, cache_index=cache["index"],
            return_hidden=return_hidden)

    def prefill(self, params, batch, cache, *, remat="none", compute_dtype=jnp.bfloat16):
        return self.apply(params, batch, remat=remat, compute_dtype=compute_dtype,
                          cache=cache, cache_index=0)

    # -- abstract inputs ----------------------------------------------------
    def input_specs(self, shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        # decode: one new token against a cache of length S
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
