"""whisper-base: encoder-decoder transformer. The conv frontend is a STUB —
``input_specs`` provides post-conv mel-frame embeddings (B, S_enc, d). The
encoder is bidirectional with sinusoidal positions; the decoder is causal with
learned positions, self-attention KV cache and cross-attention onto cached
encoder projections. Decoder length = seq_len // dec_seq_div."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import common as cm
from repro.models.transformer import _remat
from repro.sharding.spec import ParamSpec


class Whisper:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def param_specs(self, dtype=jnp.float32):
        cfg = self.cfg
        d = cfg.d_model
        attn = lambda: cm.attention_specs(cfg, dtype)
        mlp = lambda: cm.mlp_specs(cfg, dtype)
        enc_layer = {"ln1": cm.rmsnorm_spec(d, dtype), "attn": attn(),
                     "ln2": cm.rmsnorm_spec(d, dtype), "mlp": mlp()}
        dec_layer = {"ln1": cm.rmsnorm_spec(d, dtype), "self_attn": attn(),
                     "ln_x": cm.rmsnorm_spec(d, dtype), "cross_attn": attn(),
                     "ln2": cm.rmsnorm_spec(d, dtype), "mlp": mlp()}
        return {
            "embed": cm.embed_specs(cfg, dtype),
            "dec_pos": cm.dense_spec((8192, d), (None, "embed"), dtype, init="embed"),
            "enc_layers": cm.stack_tree(enc_layer, cfg.enc_layers),
            "dec_layers": cm.stack_tree(dec_layer, cfg.dec_layers),
            "enc_norm": cm.rmsnorm_spec(d, dtype),
            "dec_norm": cm.rmsnorm_spec(d, dtype),
        }

    # -- encoder ------------------------------------------------------------
    def encode(self, params, frames, *, remat="full", compute_dtype=jnp.bfloat16):
        cfg = self.cfg
        B, S, d = frames.shape
        pos = jnp.asarray(cm.sinusoidal_embedding(S, d))
        x = cm.shard_act(frames.astype(compute_dtype) + pos[None].astype(compute_dtype))
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(x, lp):
            h = cm.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            a, _ = cm.gqa_attention(cfg, lp["attn"], h, positions, causal=False,
                                    compute_dtype=compute_dtype)
            x = x + a
            h = cm.rmsnorm(x, lp["ln2"], cfg.norm_eps)
            return x + cm.mlp(cfg, lp["mlp"], h, compute_dtype), None

        body = _remat(body, remat)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return cm.rmsnorm(x, params["enc_norm"], cfg.norm_eps)

    # -- decoder ------------------------------------------------------------
    def _cross_kv(self, params, enc_out, compute_dtype):
        """Precompute per-layer cross K/V from encoder output:
        (L, B, S_enc, KV, hd) each."""
        cfg = self.cfg

        def body(_, lp):
            ca = lp["cross_attn"]
            k = jnp.einsum("bsd,dhk->bshk", enc_out.astype(compute_dtype),
                           ca["wk"].astype(compute_dtype))
            v = jnp.einsum("bsd,dhk->bshk", enc_out.astype(compute_dtype),
                           ca["wv"].astype(compute_dtype))
            return None, (k, v)

        _, (ks, vs) = jax.lax.scan(body, None, params["dec_layers"])
        return ks, vs

    def decode(self, params, tokens, cross_k, cross_v, *, cache=None,
               cache_index=0, remat="full", compute_dtype=jnp.bfloat16):
        cfg = self.cfg
        B, S = tokens.shape
        x = cm.embed(params["embed"], tokens, compute_dtype)
        pos_ids = jnp.arange(S) + cache_index
        x = x + jnp.take(params["dec_pos"], pos_ids, axis=0)[None].astype(compute_dtype)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S)) + cache_index

        def body(carry, scanned):
            x = carry
            if cache is None:
                lp, (ck_x, cv_x) = scanned
                self_kv = None
            else:
                lp, (ck_x, cv_x), (sk, sv) = scanned
                self_kv = (sk, sv)
            h = cm.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            a, new_kv = cm.gqa_attention(cfg, lp["self_attn"], h, positions,
                                         cache_kv=self_kv, cache_index=cache_index,
                                         causal=True, compute_dtype=compute_dtype)
            x = x + a
            # cross attention (no rope, pre-projected kv)
            h = cm.rmsnorm(x, lp["ln_x"], cfg.norm_eps)
            ca = lp["cross_attn"]
            q = jnp.einsum("bsd,dhk->bshk", h.astype(compute_dtype),
                           ca["wq"].astype(compute_dtype))
            attn = cm.sdpa(q, ck_x.astype(compute_dtype), cv_x.astype(compute_dtype),
                           causal=False,
                           chunk=cfg.attn_chunk if S > cfg.attn_chunk else 0)
            xo = jnp.einsum("bshk,hkd->bsd", attn.astype(compute_dtype),
                            ca["wo"].astype(compute_dtype))
            x = x + xo.astype(x.dtype)
            h = cm.rmsnorm(x, lp["ln2"], cfg.norm_eps)
            x = x + cm.mlp(cfg, lp["mlp"], h, compute_dtype)
            return x, new_kv

        body = _remat(body, remat)
        if cache is None:
            x, _ = jax.lax.scan(body, x, (params["dec_layers"], (cross_k, cross_v)))
            new_cache = None
        else:
            x, new_kv = jax.lax.scan(
                body, x, (params["dec_layers"], (cross_k, cross_v),
                          (cache["k"], cache["v"])))
            new_cache = {"k": new_kv[0], "v": new_kv[1],
                         "cross_k": cross_k, "cross_v": cross_v,
                         "index": cache["index"] + S}
        x = cm.rmsnorm(x, params["dec_norm"], cfg.norm_eps)
        logits = cm.lm_head(params["embed"], x, compute_dtype)
        return logits, new_cache

    # -- unified API ----------------------------------------------------------
    def apply(self, params, batch, *, remat="full", compute_dtype=jnp.bfloat16,
              cache=None, cache_index=0):
        enc_out = self.encode(params, batch["frames"], remat=remat,
                              compute_dtype=compute_dtype)
        ck, cv = self._cross_kv(params, enc_out, compute_dtype)
        return self.decode(params, batch["tokens"], ck, cv, cache=cache,
                           cache_index=cache_index, remat=remat,
                           compute_dtype=compute_dtype)

    def cache_specs(self, batch_size: int, max_seq: int, dtype=jnp.bfloat16):
        """max_seq = encoder length; decoder cache = max_seq // dec_seq_div."""
        cfg = self.cfg
        dec_len = max(max_seq // cfg.dec_seq_div, 8)
        L = cfg.dec_layers
        kv = lambda s: ParamSpec((L, batch_size, s, cfg.n_kv_heads, cfg.head_dim_),
                                 dtype, ("layers", "batch", "kv_len", "kv_heads",
                                         "head_dim"), init="zeros")
        return {"k": kv(dec_len), "v": kv(dec_len),
                "cross_k": kv(max_seq), "cross_v": kv(max_seq),
                "index": ParamSpec((), jnp.int32, (), init="zeros")}

    def prefill(self, params, batch, cache, *, remat="none", compute_dtype=jnp.bfloat16):
        enc_out = self.encode(params, batch["frames"], remat=remat,
                              compute_dtype=compute_dtype)
        ck, cv = self._cross_kv(params, enc_out, compute_dtype)
        return self.decode(params, batch["tokens"], ck, cv,
                           cache={"k": cache["k"], "v": cache["v"], "index": cache["index"]},
                           cache_index=0, remat=remat, compute_dtype=compute_dtype)

    def decode_step(self, params, cache, tokens, *, compute_dtype=jnp.bfloat16):
        logits, new_cache = self.decode(
            params, tokens, cache["cross_k"], cache["cross_v"],
            cache={"k": cache["k"], "v": cache["v"], "index": cache["index"]},
            cache_index=cache["index"], remat="none", compute_dtype=compute_dtype)
        return logits, new_cache

    def input_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        B, S, d = shape.global_batch, shape.seq_len, cfg.d_model
        dec_len = max(S // cfg.dec_seq_div, 8)
        bf, i32 = jnp.bfloat16, jnp.int32
        if shape.kind == "train":
            return {"frames": jax.ShapeDtypeStruct((B, S, d), bf),
                    "tokens": jax.ShapeDtypeStruct((B, dec_len), i32),
                    "labels": jax.ShapeDtypeStruct((B, dec_len), i32)}
        if shape.kind == "prefill":
            return {"frames": jax.ShapeDtypeStruct((B, S, d), bf),
                    "tokens": jax.ShapeDtypeStruct((B, dec_len), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
