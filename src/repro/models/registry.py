"""family → model class dispatch."""
from __future__ import annotations

from repro.configs.base import ModelConfig

MODEL_FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


def build_model(cfg: ModelConfig):
    if cfg.family == "dense":
        from repro.models.transformer import DenseLM
        return DenseLM(cfg)
    if cfg.family == "moe":
        from repro.models.moe import MoELM
        return MoELM(cfg)
    if cfg.family == "ssm":
        from repro.models.ssm import XLSTM
        return XLSTM(cfg)
    if cfg.family == "hybrid":
        from repro.models.hybrid import Zamba2
        return Zamba2(cfg)
    if cfg.family == "vlm":
        from repro.models.vlm import VLM
        return VLM(cfg)
    if cfg.family == "audio":
        from repro.models.audio import Whisper
        return Whisper(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
