"""Mixture-of-Experts LMs: dbrx-132b (GQA + 16e top-4) and deepseek-v3-671b
(MLA + 1 shared + 256 routed top-8 + optional MTP head).

The MoE FFN uses a sort-based capacity dispatch:

  tokens -> router top-k -> argsort by expert -> fixed-capacity (E, C, d)
  buffer -> [optional expert-parallel all_to_all over the "model" axis via
  shard_map] -> batched expert matmuls -> all_to_all back -> weighted combine.

With ``ep_axis=None`` everything stays local (single-device smoke tests); with
``ep_axis="model"`` each device owns E/m experts and tokens are exchanged with
two all_to_alls, which is what shows up in the dry-run collective analysis.

MLA follows DeepSeek-V2/V3: queries/keys/values factored through low-rank
projections; the KV cache stores only the compressed c_kv (rank 512) plus the
shared RoPE key (64), and the decode path uses the *absorbed-matmul* form so
the full per-head K/V are never materialized at decode time.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import common as cm
from repro.models.transformer import _remat
from repro.sharding.spec import ParamSpec
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# MoE FFN
# ---------------------------------------------------------------------------


def moe_specs(cfg: ModelConfig, dtype) -> dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    spec = {
        "router": cm.dense_spec((d, E), ("embed", None), dtype, init="normal", scale=0.006),
        "wi_gate": cm.dense_spec((E, d, f), ("experts", "embed", "expert_mlp"), dtype),
        "wi_up": cm.dense_spec((E, d, f), ("experts", "embed", "expert_mlp"), dtype),
        "wo": cm.dense_spec((E, f, d), ("experts", "expert_mlp", "embed"), dtype),
    }
    if cfg.n_shared_experts > 0:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        spec["shared"] = cm.mlp_specs(cfg, dtype, d_ff=fs)
    return spec


def _capacity(n_tokens: int, k: int, E: int, factor: float = 1.25, floor: int = 4) -> int:
    cap = int(math.ceil(n_tokens * k / E * factor))
    return max(cap, floor)


def _dispatch_indices(expert_ids: jax.Array, E: int, cap: int):
    """expert_ids (N,) -> (dest slot in (E*cap) buffer or E*cap for dropped,
    sort order, keep mask).  Pure local ops (argsort + searchsorted)."""
    N = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)
    sorted_e = expert_ids[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos = jnp.arange(N) - seg_start[sorted_e]
    keep = pos < cap
    dest = jnp.where(keep, sorted_e * cap + pos, E * cap)
    return dest, order, keep


def _expert_ffn(cfg: ModelConfig, p: dict, buf: jax.Array, compute_dtype) -> jax.Array:
    """buf (E_loc, C, d) -> (E_loc, C, d) through per-expert swiglu."""
    g = jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"].astype(compute_dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["wi_up"].astype(compute_dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(compute_dtype))


def _moe_local(cfg: ModelConfig, p: dict, x2d: jax.Array, *,
               ep_axis: Optional[str], compute_dtype) -> tuple:
    """x2d (T, d) local tokens. Runs router + dispatch (+ a2a when ep_axis).
    Long token streams are processed in ``moe_seq_chunk`` slices so the
    (E, capacity, d) dispatch buffer stays O(chunk·k·d) instead of
    O(T·k·d) — the difference between fitting HBM or not at 32k prefill."""
    T, d = x2d.shape
    chunk = cfg.moe_seq_chunk
    if chunk and T > chunk and T % chunk == 0:
        nchunks = T // chunk

        @jax.checkpoint
        def chunk_body(carry, xc):
            o, a = _moe_local(cfg, p, xc, ep_axis=ep_axis,
                              compute_dtype=compute_dtype)
            return carry, (o, a)

        _, (outs, auxs) = jax.lax.scan(
            chunk_body, None, x2d.reshape(nchunks, chunk, d))
        return outs.reshape(T, d), jnp.mean(auxs)
    E, k = cfg.n_experts, cfg.n_experts_active
    logits = jnp.einsum("td,de->te", x2d.astype(compute_dtype),
                        p["router"].astype(compute_dtype)).astype(jnp.float32)
    if getattr(cfg, "router_type", "softmax") == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        top_w, top_i = jax.lax.top_k(scores, k)
        top_w = top_w / (jnp.sum(top_w, -1, keepdims=True) + 1e-9)
    else:
        top_w, top_i = jax.lax.top_k(logits, k)
        top_w = jax.nn.softmax(top_w, axis=-1)

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))

    cap = _capacity(T, k, E, factor=getattr(cfg, "moe_capacity_factor", 1.25))
    flat_e = top_i.reshape(-1)                      # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = top_w.reshape(-1)
    dest, order, keep = _dispatch_indices(flat_e, E, cap)
    src_tok = flat_t[order]
    vals = x2d[src_tok] * keep[:, None].astype(x2d.dtype)
    buf = jnp.zeros((E * cap + 1, d), x2d.dtype).at[dest].add(vals)
    buf = buf[:-1].reshape(E, cap, d)

    if ep_axis is None:
        out_buf = _expert_ffn(cfg, p, buf, compute_dtype)
    else:
        m = jax.lax.psum(1, ep_axis)
        e_loc = E // m
        b = buf.reshape(m, e_loc, cap, d)
        b = jax.lax.all_to_all(b, ep_axis, split_axis=0, concat_axis=0)
        b = b.transpose(1, 0, 2, 3).reshape(e_loc, m * cap, d)
        ob = _expert_ffn(cfg, p, b, compute_dtype)
        ob = ob.reshape(e_loc, m, cap, d).transpose(1, 0, 2, 3)
        ob = jax.lax.all_to_all(ob, ep_axis, split_axis=0, concat_axis=0)
        out_buf = ob.reshape(E, cap, d)

    flat_out = out_buf.reshape(E * cap, d)
    padded = jnp.concatenate([flat_out, jnp.zeros((1, d), flat_out.dtype)], axis=0)
    y = padded[dest] * (keep[:, None] * flat_w[order][:, None]).astype(flat_out.dtype)
    out = jnp.zeros((T, d), x2d.dtype).at[src_tok].add(y)
    return out, aux


def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array, *, mesh=None,
              ep: bool = False, dp_spec=P(), compute_dtype=jnp.bfloat16):
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape

    if not ep or mesh is None:
        out, aux = _moe_local(cfg, p, x.reshape(B * S, d),
                              ep_axis=None, compute_dtype=compute_dtype)
        out = out.reshape(B, S, d)
    else:
        def fn(xl, router, wig, wiu, wo):
            pl = {"router": router, "wi_gate": wig, "wi_up": wiu, "wo": wo}
            Bl, Sl, dl = xl.shape
            o, a = _moe_local(cfg, pl, xl.reshape(Bl * Sl, dl),
                              ep_axis="model", compute_dtype=compute_dtype)
            # aux as (1,) per shard: concatenated over dp, averaged outside
            return o.reshape(Bl, Sl, dl), jax.lax.pmean(a, "model")[None]

        in_specs = (P(dp_spec, None, None), P(), P("model", None, None),
                    P("model", None, None), P("model", None, None))
        out_specs = (P(dp_spec, None, None), P(dp_spec))
        out, aux = jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])
        aux = jnp.mean(aux)

    if cfg.n_shared_experts > 0:
        shared_cfg = cfg  # swiglu shared expert
        out = out + cm.mlp(shared_cfg, p["shared"], x, compute_dtype)
    return out, aux


# ---------------------------------------------------------------------------
# MLA attention (deepseek-v3)
# ---------------------------------------------------------------------------


def mla_specs(cfg: ModelConfig, dtype) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": cm.dense_spec((d, qr), ("embed", "qk_rank"), dtype),
        "q_norm": cm.rmsnorm_spec(qr, dtype),
        "wq_b": cm.dense_spec((qr, H, nd + rd), ("qk_rank", "heads", "head_dim"), dtype),
        "wkv_a": cm.dense_spec((d, kvr + rd), ("embed", "kv_rank"), dtype),
        "kv_norm": cm.rmsnorm_spec(kvr, dtype),
        "wk_b": cm.dense_spec((kvr, H, nd), ("kv_rank", "heads", "head_dim"), dtype),
        "wv_b": cm.dense_spec((kvr, H, vd), ("kv_rank", "heads", "head_dim"), dtype),
        "wo": cm.dense_spec((H, vd, d), ("heads", "head_dim", "embed"), dtype),
    }


def mla_attention(cfg: ModelConfig, p: dict, x: jax.Array, positions, *,
                  cache=None, cache_index=0, compute_dtype=jnp.bfloat16,
                  absorbed: bool = False):
    """Returns (out, new_cache_entry). Cache holds (c_kv (B,S,kvr), k_rope
    (B,S,1,rd)). ``absorbed``: decode-optimized path (no K/V expansion)."""
    B, S, d = x.shape
    H = cfg.n_heads
    nd, rd, vd, kvr = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    xc = x.astype(compute_dtype)

    q_lat = jnp.einsum("bsd,dr->bsr", xc, p["wq_a"].astype(compute_dtype))
    q_lat = cm.rmsnorm(q_lat, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["wq_b"].astype(compute_dtype))
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = cm.apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = jnp.einsum("bsd,dr->bsr", xc, p["wkv_a"].astype(compute_dtype))
    c_kv, k_rope = kv_a[..., :kvr], kv_a[..., kvr:]
    c_kv = cm.rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = cm.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,rd)

    if cache is not None:
        cc, cr = cache
        cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype), (0, cache_index, 0))
        cr = jax.lax.dynamic_update_slice(cr, k_rope.astype(cr.dtype), (0, cache_index, 0, 0))
        c_all, r_all = cc.astype(compute_dtype), cr.astype(compute_dtype)
        valid = cache_index + S
        new_entry = (cc, cr)
    else:
        c_all, r_all = c_kv, k_rope
        valid = None
        new_entry = None

    scale = 1.0 / np.sqrt(nd + rd)
    Sk = c_all.shape[1]
    kv_pos = jnp.arange(Sk)
    q_pos = jnp.arange(S) + cache_index
    mask = kv_pos[None, :] <= q_pos[:, None]
    if valid is not None:
        mask = mask & (kv_pos[None, :] < valid)

    if absorbed:
        # score = q_nope^T (W_uk c) + q_rope^T k_rope  — absorb W_uk into q.
        q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, p["wk_b"].astype(compute_dtype))
        s_nope = jnp.einsum("bshr,btr->bhst", q_abs, c_all,
                            preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bshr,btzr->bhst", q_rope, r_all,
                            preferred_element_type=jnp.float32)
        scores = (s_nope + s_rope) * scale
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(compute_dtype)
        ctx = jnp.einsum("bhst,btr->bshr", probs, c_all)     # (B,S,H,kvr)
        out_h = jnp.einsum("bshr,rhv->bshv", ctx, p["wv_b"].astype(compute_dtype))
    else:
        k_nope = jnp.einsum("btr,rhn->bthn", c_all, p["wk_b"].astype(compute_dtype))
        v = jnp.einsum("btr,rhv->bthv", c_all, p["wv_b"].astype(compute_dtype))
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            r_all, (B, Sk, 1, rd)).repeat(H, axis=2)], axis=-1)
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        # sdpa scales by 1/sqrt(nd+rd) internally, which is the MLA scale
        out_h = cm.sdpa(qfull, k, v, causal=True, q_offset=cache_index,
                        kv_valid_len=valid,
                        chunk=cfg.attn_chunk if S > cfg.attn_chunk else 0)
    out = jnp.einsum("bshv,hvd->bsd", out_h.astype(compute_dtype),
                     p["wo"].astype(compute_dtype))
    return out.astype(x.dtype), new_entry


# ---------------------------------------------------------------------------
# The MoE LM (dbrx / deepseek-v3)
# ---------------------------------------------------------------------------


class MoELM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def _attn_specs(self, dtype):
        return (mla_specs(self.cfg, dtype) if self.cfg.use_mla
                else cm.attention_specs(self.cfg, dtype))

    def param_specs(self, dtype=jnp.float32):
        cfg = self.cfg
        moe_layer = {
            "ln1": cm.rmsnorm_spec(cfg.d_model, dtype),
            "attn": self._attn_specs(dtype),
            "ln2": cm.rmsnorm_spec(cfg.d_model, dtype),
            "moe": moe_specs(cfg, dtype),
        }
        spec = {
            "embed": cm.embed_specs(cfg, dtype),
            "layers": cm.stack_tree(moe_layer, cfg.n_layers - cfg.first_dense_layers),
            "final_norm": cm.rmsnorm_spec(cfg.d_model, dtype),
        }
        if cfg.first_dense_layers > 0:
            dense_layer = {
                "ln1": cm.rmsnorm_spec(cfg.d_model, dtype),
                "attn": self._attn_specs(dtype),
                "ln2": cm.rmsnorm_spec(cfg.d_model, dtype),
                "mlp": cm.mlp_specs(cfg, dtype),
            }
            spec["dense_layers"] = cm.stack_tree(dense_layer, cfg.first_dense_layers)
        if cfg.mtp_depth > 0:
            spec["mtp"] = {
                "proj": cm.dense_spec((2 * cfg.d_model, cfg.d_model), ("embed", None), dtype),
                "ln": cm.rmsnorm_spec(cfg.d_model, dtype),
                "layer": {
                    "ln1": cm.rmsnorm_spec(cfg.d_model, dtype),
                    "attn": self._attn_specs(dtype),
                    "ln2": cm.rmsnorm_spec(cfg.d_model, dtype),
                    "mlp": cm.mlp_specs(cfg, dtype, d_ff=cfg.moe_d_ff * 4 if cfg.moe_d_ff else cfg.d_ff),
                },
            }
        return spec

    def _attn(self, lp, x, positions, cache_entry, cache_index, compute_dtype, absorbed):
        cfg = self.cfg
        if cfg.use_mla:
            return mla_attention(cfg, lp, x, positions, cache=cache_entry,
                                 cache_index=cache_index, compute_dtype=compute_dtype,
                                 absorbed=absorbed)
        return cm.gqa_attention(cfg, lp, x, positions, cache_kv=cache_entry,
                                cache_index=cache_index, compute_dtype=compute_dtype)

    def apply(self, params, batch, *, remat="full", compute_dtype=jnp.bfloat16,
              cache=None, cache_index=0, mesh=None, ep=False, dp_spec=P(),
              absorbed=False):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = cm.shard_act(cm.embed(params["embed"], tokens, compute_dtype))
        positions = batch.get(
            "positions", jnp.broadcast_to(jnp.arange(S)[None], (B, S)) + cache_index)
        aux_total = jnp.zeros((), jnp.float32)

        def dense_body(carry, scanned):
            x = carry[0]
            if cache is None:
                lp, ce = scanned, None
            else:
                lp, ce = scanned
            h = cm.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            a, new_ce = self._attn(lp["attn"], h, positions, ce, cache_index,
                                   compute_dtype, absorbed)
            x = x + a
            h = cm.rmsnorm(x, lp["ln2"], cfg.norm_eps)
            x = x + cm.mlp(cfg, lp["mlp"], h, compute_dtype)
            return (x,), new_ce

        def moe_body(carry, scanned):
            x, aux = carry
            if cache is None:
                lp, ce = scanned, None
            else:
                lp, ce = scanned
            h = cm.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            a, new_ce = self._attn(lp["attn"], h, positions, ce, cache_index,
                                   compute_dtype, absorbed)
            x = x + a
            h = cm.rmsnorm(x, lp["ln2"], cfg.norm_eps)
            mo, aux_l = moe_apply(cfg, lp["moe"], h, mesh=mesh, ep=ep,
                                  dp_spec=dp_spec, compute_dtype=compute_dtype)
            return (x + mo, aux + aux_l), new_ce

        dense_body_r, moe_body_r = _remat(dense_body, remat), _remat(moe_body, remat)

        n_dense = cfg.first_dense_layers
        new_cache = None
        if cache is not None:
            dense_c = jax.tree_util.tree_map(lambda a: a[:n_dense], cache["kv"]) if n_dense else None
            moe_c = jax.tree_util.tree_map(lambda a: a[n_dense:], cache["kv"])
        if n_dense > 0:
            if cache is None:
                (x,), _ = jax.lax.scan(dense_body, (x,), params["dense_layers"])
                # note: remat applied only to moe stack for dense-first layers simplicity
            else:
                (x,), dense_new = jax.lax.scan(dense_body, (x,), (params["dense_layers"], dense_c))
        if cache is None:
            (x, aux_total), _ = jax.lax.scan(moe_body_r, (x, aux_total), params["layers"])
        else:
            (x, aux_total), moe_new = jax.lax.scan(
                moe_body_r, (x, aux_total), (params["layers"], moe_c))
            if n_dense > 0:
                new_kv = jax.tree_util.tree_map(
                    lambda a, b: jnp.concatenate([a, b], axis=0), dense_new, moe_new)
            else:
                new_kv = moe_new
            new_cache = {"kv": new_kv, "index": cache["index"] + S}

        x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = cm.lm_head(params["embed"], x, compute_dtype)

        mtp_logits = None
        if cfg.mtp_depth > 0 and cache is None:
            # Multi-token prediction (deepseek-v3): predict t+2 by combining
            # the trunk hidden state with the embedding of the next token.
            mp = params["mtp"]
            nxt = jnp.concatenate([x[:, 1:], jnp.zeros_like(x[:, :1])], axis=1)
            h = jnp.concatenate([cm.rmsnorm(x, mp["ln"], cfg.norm_eps), nxt], axis=-1)
            h = jnp.einsum("bse,ed->bsd", h.astype(compute_dtype),
                           mp["proj"].astype(compute_dtype))
            lp = mp["layer"]
            hh = cm.rmsnorm(h, lp["ln1"], cfg.norm_eps)
            a, _ = self._attn(lp["attn"], hh, positions, None, 0, compute_dtype, False)
            h = h + a
            hh = cm.rmsnorm(h, lp["ln2"], cfg.norm_eps)
            h = h + cm.mlp(cfg, lp["mlp"], hh, compute_dtype)
            mtp_logits = cm.lm_head(params["embed"], h, compute_dtype)

        return logits, {"cache": new_cache, "aux_loss": aux_total, "mtp_logits": mtp_logits}

    # -- serving ------------------------------------------------------------
    def cache_specs(self, batch_size: int, max_seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        L = cfg.n_layers
        if cfg.use_mla:
            kv = {
                "c_kv": ParamSpec((L, batch_size, max_seq, cfg.kv_lora_rank), dtype,
                                  ("layers", "batch", "kv_len", "kv_rank"), init="zeros"),
                "k_rope": ParamSpec((L, batch_size, max_seq, 1, cfg.qk_rope_dim), dtype,
                                    ("layers", "batch", "kv_len", None, "head_dim"), init="zeros"),
            }
        else:
            shape = (L, batch_size, max_seq, cfg.n_kv_heads, cfg.head_dim_)
            axes = ("layers", "batch", "kv_len", "kv_heads", "head_dim")
            kv = {"k": ParamSpec(shape, dtype, axes, init="zeros"),
                  "v": ParamSpec(shape, dtype, axes, init="zeros")}
        return {"kv": kv, "index": ParamSpec((), jnp.int32, (), init="zeros")}

    def _cache_tuple(self, cache):
        kv = cache["kv"]
        return (kv["c_kv"], kv["k_rope"]) if self.cfg.use_mla else (kv["k"], kv["v"])

    def decode_step(self, params, cache, tokens, *, compute_dtype=jnp.bfloat16,
                    mesh=None, ep=False, dp_spec=P()):
        B = tokens.shape[0]
        positions = jnp.broadcast_to(cache["index"][None, None], (B, 1))
        kv_tuple = self._cache_tuple(cache)
        cfg = self.cfg
        cache_in = {"kv": kv_tuple, "index": cache["index"]}
        logits, extras = self.apply(
            params, {"tokens": tokens, "positions": positions}, remat="none",
            compute_dtype=compute_dtype, cache=cache_in, cache_index=cache["index"],
            mesh=mesh, ep=ep, dp_spec=dp_spec, absorbed=cfg.use_mla)
        nk = extras["cache"]["kv"]
        if cfg.use_mla:
            new_kv = {"c_kv": nk[0], "k_rope": nk[1]}
        else:
            new_kv = {"k": nk[0], "v": nk[1]}
        return logits, {"kv": new_kv, "index": extras["cache"]["index"]}

    def prefill(self, params, batch, cache, *, remat="none", compute_dtype=jnp.bfloat16,
                mesh=None, ep=False, dp_spec=P()):
        cache_in = {"kv": self._cache_tuple(cache), "index": cache["index"]}
        logits, extras = self.apply(
            params, batch, remat=remat, compute_dtype=compute_dtype, cache=cache_in,
            cache_index=0, mesh=mesh, ep=ep, dp_spec=dp_spec)
        nk = extras["cache"]["kv"]
        new_kv = ({"c_kv": nk[0], "k_rope": nk[1]} if self.cfg.use_mla
                  else {"k": nk[0], "v": nk[1]})
        return logits, {"kv": new_kv, "index": extras["cache"]["index"]}

    def input_specs(self, shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
