"""SSM-family blocks and models.

* Mamba2 (SSD) block — chunk-parallel scan (quadratic intra-chunk term +
  recurrent inter-chunk state), O(1)-state decode. Used by zamba2 (hybrid.py).
* xLSTM — mLSTM (matrix memory, exp gating, stabilizer state) and sLSTM
  (scalar memory with per-head recurrence) blocks; xlstm-350m model.

Recurrences are computed with time-chunked scans wrapped in jax.checkpoint so
activation memory is O(S/chunk) states + one chunk of intermediates.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import common as cm
from repro.sharding.spec import ParamSpec


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def causal_conv1d(x: jax.Array, w: jax.Array, b: Optional[jax.Array],
                  state: Optional[jax.Array] = None):
    """Depthwise causal conv. x (B,S,C), w (k,C). state (B,k-1,C) holds the
    previous inputs for decode. Returns (y, new_state)."""
    k = w.shape[0]
    B, S, C = x.shape
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(w[i] * jax.lax.dynamic_slice_in_dim(xp, i, S, 1) for i in range(k))
    if b is not None:
        y = y + b
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return y, new_state


def chunked_scan(step_fn, init, xs, chunk: int):
    """scan(step_fn, init, xs) with xs time-major, rematerialized per chunk."""
    S = jax.tree_util.tree_leaves(xs)[0].shape[0]
    if S % chunk != 0:
        chunk = S  # fall back to a single chunk for odd smoke-test lengths
    nc = S // chunk
    xs_r = jax.tree_util.tree_map(
        lambda a: a.reshape((nc, chunk) + a.shape[1:]), xs)

    @jax.checkpoint
    def outer(carry, xc):
        return jax.lax.scan(step_fn, carry, xc)

    carry, ys = jax.lax.scan(outer, init, xs_r)
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape((S,) + a.shape[2:]), ys)
    return carry, ys


# ---------------------------------------------------------------------------
# Mamba2 / SSD
# ---------------------------------------------------------------------------


def mamba2_specs(cfg: ModelConfig, dtype) -> dict:
    D = cfg.d_model
    din = cfg.ssm_expand * D
    H = din // cfg.ssm_head_dim
    ds, k = cfg.ssm_state, cfg.ssm_conv
    return {
        "wz": cm.dense_spec((D, din), ("embed", "mlp"), dtype),
        "wx": cm.dense_spec((D, din), ("embed", "mlp"), dtype),
        "wB": cm.dense_spec((D, ds), ("embed", "ssm_state"), dtype),
        "wC": cm.dense_spec((D, ds), ("embed", "ssm_state"), dtype),
        "wdt": cm.dense_spec((D, H), ("embed", "ssm_heads"), dtype),
        "conv_x": ParamSpec((k, din), dtype, ("conv", "mlp"), init="fanin"),
        "conv_B": ParamSpec((k, ds), dtype, ("conv", "ssm_state"), init="fanin"),
        "conv_C": ParamSpec((k, ds), dtype, ("conv", "ssm_state"), init="fanin"),
        "A_log": ParamSpec((H,), jnp.float32, ("ssm_heads",), init="scalar", scale=0.0),
        "D_skip": ParamSpec((H,), jnp.float32, ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((H,), jnp.float32, ("ssm_heads",), init="zeros"),
        "gnorm": cm.rmsnorm_spec(din, dtype),
        "wo": cm.dense_spec((din, D), ("mlp", "embed"), dtype),
    }


def _ssd_chunk(x, dt, a, Bm, Cm, h0):
    """One SSD chunk. x (B,Q,H,p), dt/a (B,Q,H), Bm/Cm (B,Q,s),
    h0 (B,H,p,s) -> (y (B,Q,H,p), h_new)."""
    l = jnp.cumsum(a, axis=1)                                   # (B,Q,H) fp32
    dtx = (x * dt[..., None]).astype(jnp.float32)
    diff = l[:, :, None, :] - l[:, None, :, :]                  # (B,Qi,Qj,H)
    Q = x.shape[1]
    causal = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])[None, :, :, None]
    M = jnp.where(causal, jnp.exp(jnp.where(causal, diff, -jnp.inf)), 0.0)
    CB = jnp.einsum("bis,bjs->bij", Cm.astype(jnp.float32), Bm.astype(jnp.float32))
    W = M * CB[:, :, :, None]                                   # (B,Qi,Qj,H)
    y_intra = jnp.einsum("bijh,bjhp->bihp", W, dtx)
    y_inter = jnp.einsum("bis,bhps->bihp", Cm.astype(jnp.float32), h0) \
        * jnp.exp(l)[..., None]
    decay_to_end = jnp.exp(l[:, -1:, :] - l)                    # (B,Q,H)
    h_new = h0 * jnp.exp(l[:, -1])[:, :, None, None] + jnp.einsum(
        "bjhp,bjs->bhps", dtx * decay_to_end[..., None], Bm.astype(jnp.float32))
    return (y_intra + y_inter), h_new


def ssd_scan(x, dt, A_log, Bm, Cm, h0, chunk: int = 256):
    """Chunk-parallel SSD. x (B,S,H,p); dt (B,S,H); Bm/Cm (B,S,s);
    h0 (B,H,p,s). Returns (y, h_final)."""
    B, S, H, p = x.shape
    if S % chunk != 0:
        chunk = S
    nc = S // chunk
    a = (-jnp.exp(A_log.astype(jnp.float32)))[None, None, :] * dt  # (B,S,H)

    def r(t):
        return t.reshape((t.shape[0], nc, chunk) + t.shape[2:]).swapaxes(0, 1)

    xs = (r(x), r(dt), r(a), r(Bm), r(Cm))

    @jax.checkpoint
    def body(h, inp):
        xc, dtc, ac, bc, cc = inp
        y, h_new = _ssd_chunk(xc, dtc, ac, bc, cc, h)
        return h_new, y

    h_final, ys = jax.lax.scan(body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, S, H, p)
    return y, h_final


def mamba2_block(cfg: ModelConfig, p: dict, x: jax.Array, *,
                 state=None, compute_dtype=jnp.bfloat16):
    """x (B,S,D). state: None (train) or dict(conv_x/B/C, h) for decode.
    Returns (y (B,S,D), new_state)."""
    B, S, D = x.shape
    din = cfg.ssm_expand * D
    H = din // cfg.ssm_head_dim
    hd, ds = cfg.ssm_head_dim, cfg.ssm_state
    xc = x.astype(compute_dtype)

    z = jnp.einsum("bsd,de->bse", xc, p["wz"].astype(compute_dtype))
    u = jnp.einsum("bsd,de->bse", xc, p["wx"].astype(compute_dtype))
    Bm = jnp.einsum("bsd,dn->bsn", xc, p["wB"].astype(compute_dtype))
    Cm = jnp.einsum("bsd,dn->bsn", xc, p["wC"].astype(compute_dtype))
    dt = jnp.einsum("bsd,dh->bsh", xc, p["wdt"].astype(compute_dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    st = state or {}
    u, cs_x = causal_conv1d(u, p["conv_x"].astype(compute_dtype), None, st.get("conv_x"))
    Bm, cs_B = causal_conv1d(Bm, p["conv_B"].astype(compute_dtype), None, st.get("conv_B"))
    Cm, cs_C = causal_conv1d(Cm, p["conv_C"].astype(compute_dtype), None, st.get("conv_C"))
    u = jax.nn.silu(u.astype(jnp.float32)).astype(compute_dtype)
    Bm = jax.nn.silu(Bm.astype(jnp.float32)).astype(compute_dtype)
    Cm = jax.nn.silu(Cm.astype(jnp.float32)).astype(compute_dtype)

    uh = u.reshape(B, S, H, hd)
    h0 = st.get("h")
    if h0 is None:
        h0 = jnp.zeros((B, H, hd, ds), jnp.float32)
    if S == 1:  # decode: recurrent update
        a = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt[:, 0]      # (B,H)
        h_new = h0 * jnp.exp(a)[:, :, None, None] + jnp.einsum(
            "bhp,bn,bh->bhpn", uh[:, 0].astype(jnp.float32),
            Bm[:, 0].astype(jnp.float32), dt[:, 0])
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h_new)
        y = y[:, None]  # (B,1,H,hd)
        h_final = h_new
    else:
        y, h_final = ssd_scan(uh, dt, p["A_log"], Bm, Cm, h0)
        y = y.reshape(B, S, H, hd)
    y = y + uh.astype(jnp.float32) * p["D_skip"][None, None, :, None]
    y = y.reshape(B, S, din).astype(compute_dtype)
    y = cm.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(compute_dtype),
                   p["gnorm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(compute_dtype))
    new_state = {"conv_x": cs_x, "conv_B": cs_B, "conv_C": cs_C, "h": h_final}
    return out.astype(x.dtype), new_state


def mamba2_state_specs(cfg: ModelConfig, n_layers: int, batch: int, dtype=jnp.bfloat16):
    D = cfg.d_model
    din = cfg.ssm_expand * D
    H = din // cfg.ssm_head_dim
    k = cfg.ssm_conv
    L = n_layers
    return {
        "conv_x": ParamSpec((L, batch, k - 1, din), dtype,
                            ("layers", "batch", "conv", "mlp"), init="zeros"),
        "conv_B": ParamSpec((L, batch, k - 1, cfg.ssm_state), dtype,
                            ("layers", "batch", "conv", "ssm_state"), init="zeros"),
        "conv_C": ParamSpec((L, batch, k - 1, cfg.ssm_state), dtype,
                            ("layers", "batch", "conv", "ssm_state"), init="zeros"),
        "h": ParamSpec((L, batch, H, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32,
                       ("layers", "batch", "ssm_heads", "head_dim", "ssm_state"),
                       init="zeros"),
    }


# ---------------------------------------------------------------------------
# xLSTM: mLSTM block
# ---------------------------------------------------------------------------


def mlstm_specs(cfg: ModelConfig, dtype) -> dict:
    D = cfg.d_model
    din = 2 * D
    H = cfg.n_heads
    k = cfg.ssm_conv
    return {
        "ln": cm.rmsnorm_spec(D, dtype),
        "wu": cm.dense_spec((D, din), ("embed", "mlp"), dtype),
        "wzg": cm.dense_spec((D, din), ("embed", "mlp"), dtype),
        "conv": ParamSpec((k, din), dtype, ("conv", "mlp"), init="fanin"),
        "wq": cm.dense_spec((din, din), ("mlp", None), dtype),
        "wk": cm.dense_spec((din, din), ("mlp", None), dtype),
        "wv": cm.dense_spec((din, din), ("mlp", None), dtype),
        "wi": cm.dense_spec((din, H), ("mlp", "ssm_heads"), dtype),
        "wf": cm.dense_spec((din, H), ("mlp", "ssm_heads"), dtype),
        "bi": ParamSpec((H,), jnp.float32, ("ssm_heads",), init="zeros"),
        "bf": ParamSpec((H,), jnp.float32, ("ssm_heads",), init="scalar", scale=3.0),
        "gnorm": cm.rmsnorm_spec(din, dtype),
        "wo": cm.dense_spec((din, D), ("mlp", "embed"), dtype),
    }


def _mlstm_step(carry, inp):
    """carry: (C (B,H,dk,dv), n (B,H,dk), m (B,H)); inp: per-step tensors."""
    C, n, m = carry
    q, k, v, it, ft = inp          # q/k/v (B,H,dk|dv), it/ft (B,H) fp32
    dk = q.shape[-1]
    m_new = jnp.maximum(ft + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(ft + m - m_new)
    ks = k.astype(jnp.float32) / np.sqrt(dk)
    C = f_p[..., None, None] * C + i_p[..., None, None] * (
        ks[..., :, None] * v.astype(jnp.float32)[..., None, :])
    n = f_p[..., None] * n + i_p[..., None] * ks
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhk,bhkv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)), 1.0)
    h = num / den[..., None]
    return (C, n, m_new), h.astype(jnp.bfloat16)  # stacked output: half bytes


def mlstm_block(cfg: ModelConfig, p: dict, x: jax.Array, *, state=None,
                compute_dtype=jnp.bfloat16, chunk: int = 256):
    B, S, D = x.shape
    din = 2 * D
    H = cfg.n_heads
    dk = din // H
    xn = cm.rmsnorm(x, p["ln"], cfg.norm_eps).astype(compute_dtype)
    u = jnp.einsum("bsd,de->bse", xn, p["wu"].astype(compute_dtype))
    zg = jnp.einsum("bsd,de->bse", xn, p["wzg"].astype(compute_dtype))
    st = state or {}
    uc, conv_state = causal_conv1d(u, p["conv"].astype(compute_dtype), None, st.get("conv"))
    uc = jax.nn.silu(uc.astype(jnp.float32)).astype(compute_dtype)
    q = jnp.einsum("bse,ef->bsf", uc, p["wq"].astype(compute_dtype)).reshape(B, S, H, dk)
    k = jnp.einsum("bse,ef->bsf", uc, p["wk"].astype(compute_dtype)).reshape(B, S, H, dk)
    v = jnp.einsum("bse,ef->bsf", u, p["wv"].astype(compute_dtype)).reshape(B, S, H, dk)
    it = jnp.einsum("bse,eh->bsh", uc, p["wi"].astype(compute_dtype)).astype(jnp.float32) + p["bi"]
    ft = jnp.einsum("bse,eh->bsh", uc, p["wf"].astype(compute_dtype)).astype(jnp.float32)
    ft = -jax.nn.softplus(-(ft + p["bf"]))       # log sigmoid of forget preact

    C0 = st.get("C", jnp.zeros((B, H, dk, dk), jnp.float32))
    n0 = st.get("n", jnp.zeros((B, H, dk), jnp.float32))
    m0 = st.get("m", jnp.full((B, H), -1e30, jnp.float32))

    tm = lambda t: jnp.swapaxes(t, 0, 1)         # (B,S,...) -> (S,B,...)
    (Cf, nf, mf), hs = chunked_scan(
        _mlstm_step, (C0, n0, m0), (tm(q), tm(k), tm(v), tm(it), tm(ft)), chunk)
    h = jnp.swapaxes(hs, 0, 1).reshape(B, S, din).astype(compute_dtype)
    h = cm.rmsnorm(h, p["gnorm"], cfg.norm_eps)
    h = h * jax.nn.silu(zg.astype(jnp.float32)).astype(compute_dtype)
    out = jnp.einsum("bse,ed->bsd", h, p["wo"].astype(compute_dtype))
    new_state = {"conv": conv_state, "C": Cf, "n": nf, "m": mf}
    return x + out.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# xLSTM: sLSTM block
# ---------------------------------------------------------------------------


def slstm_specs(cfg: ModelConfig, dtype) -> dict:
    D = cfg.d_model
    H = cfg.n_heads
    dh = D // H
    f_up = max(((int(D * 4 / 3) + 127) // 128) * 128, 16)  # lane-aligned 4/3 proj
    return {
        "ln": cm.rmsnorm_spec(D, dtype),
        "wg": cm.dense_spec((D, 4 * D), ("embed", "mlp"), dtype),       # z,i,f,o
        "rg": cm.dense_spec((H, dh, 4 * dh), ("ssm_heads", "head_dim", None), dtype),
        "bg": ParamSpec((4 * D,), jnp.float32, ("mlp",), init="zeros"),
        "gnorm": cm.rmsnorm_spec(D, dtype),
        "up": cm.dense_spec((D, f_up), ("embed", "mlp"), dtype),
        "down": cm.dense_spec((f_up, D), ("mlp", "embed"), dtype),
    }


def _slstm_step(carry, inp, *, rg, H, dh):
    c, n, h, m = carry                      # (B,H,dh) each; m (B,H,dh)
    wx = inp                                # (B, 4D) fp32 projected input
    B = wx.shape[0]
    rec = jnp.einsum("bhd,hdk->bhk", h, rg.astype(h.dtype))  # (B,H,4dh)
    g = wx.reshape(B, H, 4 * dh) + rec
    zt, it, ft, ot = jnp.split(g, 4, axis=-1)
    z = jnp.tanh(zt)
    o = jax.nn.sigmoid(ot)
    logf = -jax.nn.softplus(-ft)
    m_new = jnp.maximum(logf + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(logf + m - m_new)
    c = f_p * c + i_p * z
    n = f_p * n + i_p
    h_new = o * c / jnp.maximum(n, 1.0)
    return (c, n, h_new, m_new), h_new.astype(jnp.bfloat16)


def slstm_block(cfg: ModelConfig, p: dict, x: jax.Array, *, state=None,
                compute_dtype=jnp.bfloat16, chunk: int = 256):
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    xn = cm.rmsnorm(x, p["ln"], cfg.norm_eps).astype(compute_dtype)
    wx = (jnp.einsum("bsd,dg->bsg", xn, p["wg"].astype(compute_dtype))
          .astype(jnp.float32) + p["bg"])
    st = state or {}
    c0 = st.get("c", jnp.zeros((B, H, dh), jnp.float32))
    n0 = st.get("n", jnp.zeros((B, H, dh), jnp.float32))
    h0 = st.get("h", jnp.zeros((B, H, dh), jnp.float32))
    m0 = st.get("m", jnp.full((B, H, dh), -1e30, jnp.float32))

    import functools
    step = functools.partial(_slstm_step, rg=p["rg"].astype(jnp.float32), H=H, dh=dh)
    (cf, nf, hf, mf), hs = chunked_scan(
        step, (c0, n0, h0, m0), jnp.swapaxes(wx, 0, 1), chunk)
    h = jnp.swapaxes(hs, 0, 1).reshape(B, S, D).astype(compute_dtype)
    h = cm.rmsnorm(h, p["gnorm"], cfg.norm_eps)
    up = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, p["up"].astype(compute_dtype))
                     .astype(jnp.float32), approximate=True).astype(compute_dtype)
    out = jnp.einsum("bsf,fd->bsd", up, p["down"].astype(compute_dtype))
    new_state = {"c": cf, "n": nf, "h": hf, "m": mf}
    return x + out.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# xLSTM model (alternating mLSTM / sLSTM stacks)
# ---------------------------------------------------------------------------


class XLSTM:
    """xlstm-350m: n_layers blocks; every ``slstm_every``-th block is sLSTM
    (rest mLSTM). Homogeneous scan per kind: we scan the mLSTM stack and the
    sLSTM stack separately, interleaved by groups (like zamba2's layout)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        se = cfg.slstm_every or 0
        self.n_slstm = cfg.n_layers // se if se else 0
        self.n_mlstm = cfg.n_layers - self.n_slstm
        # groups of (mlstm_per_group mLSTM layers, then 1 sLSTM)
        self.groups = max(self.n_slstm, 1)
        assert self.n_mlstm % self.groups == 0, (self.n_mlstm, self.groups)
        self.m_per_group = self.n_mlstm // self.groups

    def param_specs(self, dtype=jnp.float32):
        cfg = self.cfg
        spec = {
            "embed": cm.embed_specs(cfg, dtype),
            "mlstm": cm.stack_tree(mlstm_specs(cfg, dtype), self.n_mlstm),
            "final_norm": cm.rmsnorm_spec(cfg.d_model, dtype),
        }
        if self.n_slstm:
            spec["slstm"] = cm.stack_tree(slstm_specs(cfg, dtype), self.n_slstm)
        return spec

    def _mlstm_state_specs(self, batch, dtype=jnp.float32):
        cfg = self.cfg
        din = 2 * cfg.d_model
        H = cfg.n_heads
        dk = din // H
        L = self.n_mlstm
        k = cfg.ssm_conv
        return {
            "conv": ParamSpec((L, batch, k - 1, din), dtype,
                              ("layers", "batch", "conv", "mlp"), init="zeros"),
            "C": ParamSpec((L, batch, H, dk, dk), jnp.float32,
                           ("layers", "batch", "ssm_heads", "head_dim", None), init="zeros"),
            "n": ParamSpec((L, batch, H, dk), jnp.float32,
                           ("layers", "batch", "ssm_heads", "head_dim"), init="zeros"),
            "m": ParamSpec((L, batch, H), jnp.float32,
                           ("layers", "batch", "ssm_heads"), init="scalar", scale=-1e30),
        }

    def _slstm_state_specs(self, batch, dtype=jnp.float32):
        cfg = self.cfg
        H = cfg.n_heads
        dh = cfg.d_model // H
        L = self.n_slstm
        mk = lambda shape, axes: ParamSpec(shape, jnp.float32, axes, init="zeros")
        ax = ("layers", "batch", "ssm_heads", "head_dim")
        return {
            "c": mk((L, batch, H, dh), ax), "n": mk((L, batch, H, dh), ax),
            "h": mk((L, batch, H, dh), ax),
            "m": ParamSpec((L, batch, H, dh), jnp.float32, ax, init="scalar", scale=-1e30),
        }

    def cache_specs(self, batch_size: int, max_seq: int, dtype=jnp.bfloat16):
        spec = {"m_state": self._mlstm_state_specs(batch_size),
                "index": ParamSpec((), jnp.int32, (), init="zeros")}
        if self.n_slstm:
            spec["s_state"] = self._slstm_state_specs(batch_size)
        return spec

    def _forward(self, params, x, state, compute_dtype):
        """x (B,S,D); state: None or dict of stacked states. Returns
        (x, new_state)."""
        cfg = self.cfg

        def m_body(carry, scanned):
            x = carry
            if state is None:
                lp, ls = scanned, None
            else:
                lp, ls = scanned
            x, ns = mlstm_block(cfg, lp, x, state=ls, compute_dtype=compute_dtype)
            return x, ns

        def s_body(carry, scanned):
            x = carry
            if state is None:
                lp, ls = scanned, None
            else:
                lp, ls = scanned
            x, ns = slstm_block(cfg, lp, x, state=ls, compute_dtype=compute_dtype)
            return x, ns

        g, mpg = self.groups, self.m_per_group
        reshape_g = lambda t: t.reshape((g, mpg) + t.shape[1:])
        m_params = jax.tree_util.tree_map(reshape_g, params["mlstm"])
        if state is not None:
            m_state = jax.tree_util.tree_map(reshape_g, state["m_state"])

        new_m_states, new_s_states = [], []
        for gi in range(g):
            mp = jax.tree_util.tree_map(lambda t: t[gi], m_params)
            if state is None:
                x, _ = jax.lax.scan(m_body, x, mp)
            else:
                ms = jax.tree_util.tree_map(lambda t: t[gi], m_state)
                x, nms = jax.lax.scan(m_body, x, (mp, ms))
                new_m_states.append(nms)
            if self.n_slstm:
                sp = jax.tree_util.tree_map(lambda t: t[gi], params["slstm"])
                if state is None:
                    x, _ = s_body(x, sp)
                else:
                    ss = jax.tree_util.tree_map(lambda t: t[gi], state["s_state"])
                    x, nss = s_body(x, (sp, ss))
                    new_s_states.append(nss)
        new_state = None
        if state is not None:
            new_state = {
                "m_state": jax.tree_util.tree_map(
                    lambda *xs: jnp.concatenate(xs, axis=0), *new_m_states),
            }
            if self.n_slstm:
                new_state["s_state"] = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs, axis=0), *new_s_states)
        return x, new_state

    def apply(self, params, batch, *, remat="full", compute_dtype=jnp.bfloat16,
              cache=None, cache_index=0):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = cm.shard_act(cm.embed(params["embed"], tokens, compute_dtype))
        state = None
        if cache is not None:
            state = {k: v for k, v in cache.items() if k != "index"}
        x, new_state = self._forward(params, x, state, compute_dtype)
        x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = cm.lm_head(params["embed"], x, compute_dtype)
        new_cache = None
        if cache is not None:
            new_cache = dict(new_state)
            new_cache["index"] = cache["index"] + tokens.shape[1]
        return logits, new_cache

    def decode_step(self, params, cache, tokens, *, compute_dtype=jnp.bfloat16):
        return self.apply(params, {"tokens": tokens}, remat="none",
                          compute_dtype=compute_dtype, cache=cache,
                          cache_index=cache["index"])

    def prefill(self, params, batch, cache, *, remat="none", compute_dtype=jnp.bfloat16):
        return self.apply(params, batch, remat=remat, compute_dtype=compute_dtype,
                          cache=cache, cache_index=0)

    def input_specs(self, shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
