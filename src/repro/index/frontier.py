"""Survivor-compacted racing frontier (DESIGN.md §4.2).

The PR-1 batched driver keeps (Q, n) state for the whole race: every round's
CI radii, top-k selection and acceptance masks traverse all n arms even when
all but a handful are long rejected — the per-round cost is flat in how hard
the instance actually is. The paper's O((n+d)·log²) bound only materializes
if per-round work tracks the *surviving* arms.

This module keeps the racing state in *bucketed dense buffers* instead:
after each epoch the still-alive entries (accepted + candidates) are
gathered to the front and the buffer width W shrinks along a power-of-two
schedule n → n/2 → n/4 → … (each width is one extra XLA specialization of
the epoch step — a bounded, ~log₂(n)-sized compile cache, amortized across
the index's serving lifetime). All bookkeeping from then on is O(Q·W).

Invariant (tested): compaction only ever drops rejected or padding entries
and preserves per-entry statistics exactly, so the race's accept/reject
decisions are *identical* with and without compaction. The CI variance pool
is defined over survivors (not all alive arms as in the PR-1 driver)
precisely so this invariance holds — see ``batched_race`` for the radius.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.datasets import next_pow2


class FrontierState(NamedTuple):
    """Bucketed racing state: (Q, W) buffers over the survivor frontier.

    ``ids`` maps buffer positions to original arm/slot ids; ``valid`` marks
    real entries (padding and — after compaction — nothing else is invalid;
    dead/tombstoned slots enter as invalid + rejected). Per-query scalars
    mirror the PR-1 ``BatchedRaceState``.
    """
    ids: jax.Array        # (Q, W) int32 arm/slot ids
    mean: jax.Array       # (Q, W) running θ̂
    count: jax.Array      # (Q, W) pulls so far
    m2: jax.Array         # (Q, W) Welford M2
    prior: jax.Array      # (Q, W) warm-start variance prior (gathered)
    exact: jax.Array      # (Q, W) bool — mean is exact, CI = 0
    accepted: jax.Array   # (Q, W) bool
    rejected: jax.Array   # (Q, W) bool
    valid: jax.Array      # (Q, W) bool — False for padding entries
    coord_ops: jax.Array  # (Q,) coordinate-op counter
    n_exact: jax.Array    # (Q,) int32 arms exactly evaluated — a running
                          # counter, NOT derived from the buffers: compaction
                          # may drop exact-then-rejected entries
    rounds: jax.Array     # (Q,) int32 equivalent pull-rounds while active
    done: jax.Array       # (Q,) bool
    rng: jax.Array

    @property
    def width(self) -> int:
        return self.ids.shape[1]


def survivors(st: FrontierState) -> jax.Array:
    """(Q, W) bool — entries the race still owes work or an answer for."""
    return st.valid & ~st.rejected


@functools.partial(jax.jit, static_argnames=("W_new",))
def compact_frontier(st: FrontierState, *, W_new: int) -> FrontierState:
    """Gather each query's surviving entries into the first ``W_new``
    positions and drop the rest of the buffer.

    Priority: accepted < candidate < (rejected | padding), stably — so a
    finished query's k accepted arms survive any truncation, and for active
    queries the caller guarantees W_new ≥ survivor count (nothing live is
    ever dropped). Statistics ride along untouched.
    """
    key = jnp.where(st.accepted, 0, jnp.where(survivors(st), 1, 2))
    order = jnp.argsort(key, axis=1)[:, :W_new]
    take = lambda a: jnp.take_along_axis(a, order, axis=1)
    return st._replace(
        ids=take(st.ids), mean=take(st.mean), count=take(st.count),
        m2=take(st.m2), prior=take(st.prior), exact=take(st.exact),
        accepted=take(st.accepted), rejected=take(st.rejected),
        valid=take(st.valid) & ~take(st.rejected),
    )


def bucket_width(need: int, *, floor: int, current: int) -> int:
    """Next buffer width: power-of-two cover of ``need`` (the max survivor
    count over still-active queries), floored to keep selection/acceptance
    shapes sane, and never growing back above ``current``."""
    w = max(next_pow2(max(int(need), 1)), floor)
    return min(w, current)


def floor_width(cfg, n: int, *, B0: int = 0) -> int:
    """Smallest bucket width the shrink schedule may reach for an
    ``n``-wide frontier. ``cfg.frontier_floor`` (a ``repro.tune`` knob)
    overrides the derived default of max(racing batch, 2k, 32); either
    way the result is pow2-quantized and capped at ``n`` so the compile
    cache stays on the n → n/2 → … chain."""
    if not B0:
        B0 = min(cfg.batch_arms, n)
    base = cfg.frontier_floor if cfg.frontier_floor > 0 \
        else max(B0, 2 * cfg.k, 32)
    return min(n, bucket_width(base, floor=1, current=n))


def pow2_floor(m: int) -> int:
    """Largest power of two ≤ max(m, 1). The epoch drivers quantize the
    adaptive rounds-per-launch multiplier through this so T = R·P (a
    static jit arg of the fused step) takes values only on a ~log-sized
    chain — one warm race precompiles every specialization mid-traffic
    requests can reach (guarded by the repro_xla_compiles_total test)."""
    return 1 << (max(int(m), 1).bit_length() - 1)
