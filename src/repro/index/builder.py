"""One-time index preprocessing (DESIGN.md §3.1): corpus → IndexStore.

Everything the per-call ``bmo_nn.knn`` path recomputes per query batch is
done once here and amortized across the index's lifetime:

  * dense:   blocked/padded corpus layout,
  * rotated: the §IV-B Hadamard rotation is *cached* — the sign vector and
    the pre-rotated corpus are stored, so serving only rotates the (Q, d)
    query batch (O(Q d log d)) instead of corpus + queries every call,
  * sparse:  padded-CSR layout (§IV-A box),
  * per-arm block statistics (mean/variance of each row's block values),
    the warm-start priors for the racing CIs.

Persistence goes through checkpoint/manager.py's atomic save, so an index
directory is bit-compatible with the training checkpoints' tooling.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BMOConfig
from repro.core.datasets import SparseDataset, next_pow2
from repro.index.store import IndexStore
from repro.utils import get_logger

log = get_logger("repro.index")


def _row_block_stats(x: jax.Array, block: int, metric: str):
    """Per-arm variance across blocks of the row's block values — the
    query-independent component of the pull-value variance (the pull is the
    block mean of |x_t − q_t|^p; its spread across blocks is bounded below
    by the spread of the row's own block energies)."""
    n, d_pad = x.shape
    xb = x.reshape(n, d_pad // block, block)
    v = jnp.mean(jnp.abs(xb) if metric == "l1" else xb * xb, axis=-1)  # (n, nb)
    return jnp.var(v, axis=-1)


def _sparse_prior(values: jax.Array, nnz: jax.Array, d: int):
    """Eq. 12 pull values are (tot/2d)·(1+…)·|v|: scale the per-row value
    variance by the squared support mass so empty/light rows start tight."""
    m = values.shape[1]
    mask = jnp.arange(m)[None, :] < nnz[:, None]
    cnt = jnp.maximum(nnz.astype(jnp.float32), 1.0)
    mean = jnp.sum(jnp.abs(values) * mask, 1) / cnt
    var = jnp.sum(jnp.square(jnp.abs(values) - mean[:, None]) * mask, 1) / cnt
    scale = (nnz.astype(jnp.float32) / d) ** 2
    return var * scale


def build_index(corpus, cfg: BMOConfig, rng: jax.Array, *,
                capacity: Optional[int] = None,
                impl: str = "auto") -> IndexStore:
    """Preprocess ``corpus`` into an IndexStore ready for batched serving.

    corpus: (n, d) array (dense; also the input for the rotated/sparse boxes
    — ``cfg.rotate`` / ``cfg.sparse`` select the §IV box exactly like
    ``bmo_nn.knn``). ``capacity``: total slots (≥ n); defaults to the next
    power of two so early inserts don't force a growth.
    """
    if cfg.sparse:
        return _build_sparse(corpus, cfg, capacity)
    x = jnp.asarray(corpus, jnp.float32)
    n, d = x.shape
    kind = "rotated" if cfg.rotate else "dense"
    signs = None
    if cfg.rotate:
        assert cfg.metric == "l2", "rotation preserves only ℓ2"
        assert cfg.block & (cfg.block - 1) == 0, \
            "rotated box needs a power-of-two block"
        from repro.kernels import ops as kops
        dp = max(next_pow2(d), cfg.block)
        x = jnp.pad(x, ((0, 0), (0, dp - d)))
        signs = jax.random.rademacher(rng, (dp,), jnp.float32)
        x = kops.fwht(x * signs[None, :], impl=impl)
    # blocked layout
    pad = (-x.shape[1]) % cfg.block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        if signs is not None:  # keep signs aligned with d_pad for queries
            signs = jnp.pad(signs, (0, pad), constant_values=1.0)
    cap = capacity or next_pow2(n)
    assert cap >= n
    if cap > n:
        x = jnp.pad(x, ((0, cap - n), (0, 0)))
    alive = jnp.arange(cap) < n
    prior_var = _row_block_stats(x, cfg.block, cfg.metric)
    log.info("built %s index: n=%d cap=%d d=%d d_pad=%d block=%d",
             kind, n, cap, d, x.shape[1], cfg.block)
    return IndexStore(kind=kind, cfg=cfg, d=d, alive=alive, x=x,
                      block=cfg.block, signs=signs, prior_var=prior_var)


def _build_sparse(corpus, cfg: BMOConfig, capacity: Optional[int]) -> IndexStore:
    ds = corpus if isinstance(corpus, SparseDataset) else SparseDataset.build(
        np.asarray(corpus))
    n, m, d = ds.n, ds.m, ds.d
    cap = capacity or next_pow2(n)
    assert cap >= n
    indices = jnp.pad(ds.indices, ((0, cap - n), (0, 0)), constant_values=d)
    values = jnp.pad(ds.values, ((0, cap - n), (0, 0)))
    nnz = jnp.pad(ds.nnz, (0, cap - n))
    alive = jnp.arange(cap) < n
    prior_var = _sparse_prior(values, nnz, d)
    log.info("built sparse index: n=%d cap=%d d=%d m=%d", n, cap, d, m)
    return IndexStore(kind="sparse", cfg=cfg, d=d, alive=alive,
                      indices=indices, values=values, nnz=nnz,
                      prior_var=prior_var)


# ---------------------------------------------------------------------------
# persistence (checkpoint/manager.py)
# ---------------------------------------------------------------------------


def save_index(store: IndexStore, path: str, *, extra=None) -> None:
    """Atomic write of the store's arrays + meta (checkpoint layout).
    ``extra(tmpdir)``: optional callback staging sidecars (payload, tuned
    config) into the same all-or-nothing publish — a crash mid-save can
    never leave an index without its sidecars (or vice versa)."""
    from repro import checkpoint
    checkpoint.manager.save(path, store.arrays(), meta=store.meta(),
                            extra=extra)


def load_index(path: str) -> IndexStore:
    from repro import checkpoint
    arrays = checkpoint.manager.load_arrays(path)
    meta = checkpoint.manager.read_meta(path)
    return IndexStore.from_arrays(arrays, meta)
