"""repro.index — persistent, mutable, batched-racing BMO-NN index service.

Build once (``build_index``), serve many (``index_knn`` / ``IndexStore.query``
— cross-query batched racing), mutate online (``insert``/``delete``/
``compact``), persist through the checkpoint layer (``save_index``/
``load_index``). See DESIGN.md §3.

One index can span a mesh: ``build_sharded_index`` partitions the slot axis
across a named mesh axis (``ShardedIndexStore``), races each shard locally
and merges certified per-shard top-ks — same lifecycle (``sharded_insert``/
``sharded_delete``/``sharded_maybe_compact``), per-shard checkpoints plus a
manifest (``save_sharded_index``/``load_sharded_index``, re-shardable on
load). See DESIGN.md §5. ``index_knn`` dispatches on the store type.
"""
from repro.index.batched_race import (batched_race_topk, fused_race_topk,
                                      index_knn)
from repro.index.builder import build_index, load_index, save_index
from repro.index.frontier import FrontierState, compact_frontier
from repro.index.mutable import compact, delete, insert, maybe_compact
from repro.index.sharded import (ShardedIndexStore, ShardedKNNResult,
                                 build_sharded_index, is_sharded_index_dir,
                                 load_sharded_index, reshard,
                                 save_sharded_index, sharded_compact,
                                 sharded_delete, sharded_index_knn,
                                 sharded_insert, sharded_maybe_compact)
from repro.index.store import IndexStore

__all__ = [
    "FrontierState", "IndexStore", "ShardedIndexStore", "ShardedKNNResult",
    "batched_race_topk", "build_index", "build_sharded_index", "compact",
    "compact_frontier", "delete", "fused_race_topk", "index_knn", "insert",
    "is_sharded_index_dir", "load_index", "load_sharded_index",
    "maybe_compact", "reshard", "save_index", "save_sharded_index",
    "sharded_compact", "sharded_delete", "sharded_index_knn",
    "sharded_insert", "sharded_maybe_compact",
]
