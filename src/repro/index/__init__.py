"""repro.index — persistent, mutable, batched-racing BMO-NN index service.

.. deprecated:: PR 4
    The free-function surface below (``build_index``/``index_knn``/
    ``insert``/``sharded_*``/…) is superseded by the unified handle in
    ``repro.api`` (``Index.build/load/open`` + ``QuerySpec``; DESIGN.md §6).
    Every public *function* here still imports and works, but emits one
    ``DeprecationWarning`` per symbol per process and forwards to the same
    implementation the new API calls. The store/state *types* (IndexStore,
    ShardedIndexStore, …) are not deprecated — ``repro.api`` returns and
    accepts them.

Build once (``build_index``), serve many (``index_knn``), mutate online
(``insert``/``delete``/``compact``), persist through the checkpoint layer;
``build_sharded_index`` spans one index over a mesh (DESIGN.md §3/§5).
"""
import functools
import warnings

from repro.index import batched_race as _batched_race
from repro.index import builder as _builder
from repro.index import frontier as _frontier
from repro.index import mutable as _mutable
from repro.index import sharded as _sharded
from repro.index.frontier import FrontierState
from repro.index.sharded import ShardedIndexStore, ShardedKNNResult
from repro.index.store import IndexStore

#: symbols that already warned this process — the shim contract is ONE
#: DeprecationWarning per symbol, not one per call (tests reset this).
_DEPRECATION_WARNED = set()


def _shim(module, name: str, hint: str):
    fn = getattr(module, name)

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        if name not in _DEPRECATION_WARNED:
            _DEPRECATION_WARNED.add(name)
            warnings.warn(
                f"repro.index.{name} is deprecated; use {hint} "
                "(repro.api, DESIGN.md §6)",
                DeprecationWarning, stacklevel=2)
        return fn(*args, **kwargs)

    wrapper.__wrapped__ = fn
    return wrapper


#: (module, name, repro.api replacement) for every shimmed public function.
_SHIMS = {
    "batched_race_topk": (_batched_race, "Index.query"),
    "fused_race_topk": (_batched_race, "Index.query"),
    "index_knn": (_batched_race, "Index.query"),
    "build_index": (_builder, "Index.build"),
    "load_index": (_builder, "Index.load"),
    "save_index": (_builder, "Index.save"),
    "compact_frontier": (_frontier, "Index.query"),
    "insert": (_mutable, "Index.insert"),
    "delete": (_mutable, "Index.delete"),
    "compact": (_mutable, "Index.compact"),
    "maybe_compact": (_mutable, "Index.maybe_compact"),
    "build_sharded_index": (_sharded, "Index.build(shards=S)"),
    "is_sharded_index_dir": (_sharded, "Index.load"),
    "load_sharded_index": (_sharded, "Index.load(shards=S)"),
    "save_sharded_index": (_sharded, "Index.save"),
    "reshard": (_sharded, "Index.reshard"),
    "sharded_compact": (_sharded, "Index.compact"),
    "sharded_delete": (_sharded, "Index.delete"),
    "sharded_index_knn": (_sharded, "Index.query"),
    "sharded_insert": (_sharded, "Index.insert"),
    "sharded_maybe_compact": (_sharded, "Index.maybe_compact"),
}

for _name, (_mod, _hint) in _SHIMS.items():
    globals()[_name] = _shim(_mod, _name, _hint)
del _name, _mod, _hint

__all__ = [
    "FrontierState", "IndexStore", "ShardedIndexStore", "ShardedKNNResult",
    "batched_race_topk", "build_index", "build_sharded_index", "compact",
    "compact_frontier", "delete", "fused_race_topk", "index_knn", "insert",
    "is_sharded_index_dir", "load_index", "load_sharded_index",
    "maybe_compact", "reshard", "save_index", "save_sharded_index",
    "sharded_compact", "sharded_delete", "sharded_index_knn",
    "sharded_insert", "sharded_maybe_compact",
]
