"""repro.index — persistent, mutable, batched-racing BMO-NN index service.

Build once (``build_index``), serve many (``index_knn`` / ``IndexStore.query``
— cross-query batched racing), mutate online (``insert``/``delete``/
``compact``), persist through the checkpoint layer (``save_index``/
``load_index``). See DESIGN.md §3.
"""
from repro.index.batched_race import (batched_race_topk, fused_race_topk,
                                      index_knn)
from repro.index.builder import build_index, load_index, save_index
from repro.index.frontier import FrontierState, compact_frontier
from repro.index.mutable import compact, delete, insert, maybe_compact
from repro.index.store import IndexStore

__all__ = [
    "FrontierState", "IndexStore", "batched_race_topk", "build_index",
    "compact", "compact_frontier", "delete", "fused_race_topk", "index_knn",
    "insert", "load_index", "maybe_compact", "save_index",
]
