"""Online mutation of an IndexStore (DESIGN.md §3.3): the serving datastore
can grow during decode — true kNN-LM behaviour — without a rebuild.

  * ``insert`` writes new rows into free (tombstoned or never-used) slots,
    doubling capacity only when none are free; slot ids are returned so the
    caller can keep side payloads (e.g. next-token ids) aligned,
  * ``delete`` is an O(1) tombstone flip — dead slots enter every subsequent
    race pre-rejected (batched_race ``dead`` mask), so queries never pay for
    them beyond the mask itself,
  * ``compact`` rebuilds a dense slot layout once tombstones accumulate,
    returning the old→new slot mapping for payload reindexing.

All mutation is host-side/eager: shapes change only on growth or compaction,
so the jitted batched-race executables stay warm across steady-state
insert/delete traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.datasets import next_pow2
from repro.index.builder import _row_block_stats, _sparse_prior
from repro.index.store import IndexStore, free_slots
from repro.utils import get_logger

log = get_logger("repro.index")


def _grow(store: IndexStore, need: int) -> IndexStore:
    cap = store.capacity
    new_cap = max(2 * cap, next_pow2(cap + need))
    extra = new_cap - cap
    log.info("growing index capacity %d -> %d", cap, new_cap)
    kw = dict(alive=jnp.pad(store.alive, (0, extra)),
              prior_var=jnp.pad(store.prior_var, (0, extra)))
    if store.kind == "sparse":
        kw.update(indices=jnp.pad(store.indices, ((0, extra), (0, 0)),
                                  constant_values=store.d),
                  values=jnp.pad(store.values, ((0, extra), (0, 0))),
                  nnz=jnp.pad(store.nnz, (0, extra)))
    else:
        kw.update(x=jnp.pad(store.x, ((0, extra), (0, 0))))
    return dataclasses.replace(store, **kw)


def insert(store: IndexStore, rows) -> Tuple[IndexStore, np.ndarray]:
    """Insert (B, d) dense rows (all kinds take dense input; the rotated box
    rotates with the *cached* signs, the sparse box re-compresses). Returns
    (new store, slot ids (B,))."""
    rows = np.asarray(rows, np.float32)
    if rows.ndim == 1:
        rows = rows[None]
    bsz = rows.shape[0]
    free = free_slots(store)
    if len(free) < bsz:
        store = _grow(store, bsz - len(free))
        free = free_slots(store)
    slots = free[:bsz]
    sl = jnp.asarray(slots)
    alive = store.alive.at[sl].set(True)

    if store.kind == "sparse":
        nnz = (rows != 0).sum(axis=1).astype(np.int32)
        m_new = int(max(nnz.max(initial=0), 1))
        store = _widen_sparse(store, m_new)
        m = store.m
        idx = np.full((bsz, m), store.d, np.int32)
        val = np.zeros((bsz, m), np.float32)
        for i in range(bsz):
            nz = np.nonzero(rows[i])[0]
            idx[i, : len(nz)] = nz
            val[i, : len(nz)] = rows[i, nz]
        indices = store.indices.at[sl].set(jnp.asarray(idx))
        values = store.values.at[sl].set(jnp.asarray(val))
        nnz_arr = store.nnz.at[sl].set(jnp.asarray(nnz))
        prior = store.prior_var.at[sl].set(
            _sparse_prior(jnp.asarray(val), jnp.asarray(nnz), store.d))
        return dataclasses.replace(store, alive=alive, indices=indices,
                                   values=values, nnz=nnz_arr,
                                   prior_var=prior), slots

    x_rows = jnp.asarray(rows)
    pad = store.d_pad - x_rows.shape[1]
    if pad:
        x_rows = jnp.pad(x_rows, ((0, 0), (0, pad)))
    if store.kind == "rotated":
        from repro.kernels import ops as kops
        x_rows = kops.fwht(x_rows * store.signs[None, :])
    x = store.x.at[sl].set(x_rows)
    prior = store.prior_var.at[sl].set(
        _row_block_stats(x_rows, store.block, store.cfg.metric))
    return dataclasses.replace(store, alive=alive, x=x, prior_var=prior), slots


def _widen_sparse(store: IndexStore, m_new: int) -> IndexStore:
    if m_new <= store.m:
        return store
    extra = m_new - store.m
    log.info("widening sparse index m %d -> %d", store.m, m_new)
    return dataclasses.replace(
        store,
        indices=jnp.pad(store.indices, ((0, 0), (0, extra)),
                        constant_values=store.d),
        values=jnp.pad(store.values, ((0, 0), (0, extra))))


def delete(store: IndexStore, slot_ids) -> IndexStore:
    """Tombstone slots (O(1)); data stays until ``compact``."""
    sl = jnp.asarray(np.atleast_1d(np.asarray(slot_ids, np.int64)))
    return dataclasses.replace(store, alive=store.alive.at[sl].set(False))


def tombstone_fraction(store: IndexStore) -> float:
    """Fraction of capacity occupied by dead slots (tombstones + never-used
    tail): the state every race still pays a mask for."""
    return 1.0 - store.n_live / max(store.capacity, 1)


def maybe_compact(store: IndexStore, *, threshold: float = 0.5,
                  ) -> Tuple[IndexStore, Optional[np.ndarray]]:
    """Auto-compaction policy (ROADMAP): rebuild the dense slot layout once
    the tombstone fraction crosses ``threshold``. Returns
    ``(store, old_ids)`` — ``old_ids`` is None when no compaction ran, else
    the old→new slot map for payload reindexing (see ``compact``).

    Only worthwhile when it actually shrinks capacity: with a power-of-two
    slot layout, dropping tombstones pays off (smaller race buffers, a fresh
    jit specialization) only once live < capacity/2, so thresholds below 0.5
    would trigger rebuilds into the *same* capacity — the shrink check runs
    on plain ints BEFORE the O(capacity·d) gather, so an over-eager
    threshold costs nothing per call. Callers amortize this into mutation
    traffic (serve/engine.py folds it into the per-step index append)."""
    if (store.capacity and tombstone_fraction(store) > threshold
            and next_pow2(max(store.n_live, 1)) < store.capacity):
        return compact(store)
    return store, None


def compact(store: IndexStore) -> Tuple[IndexStore, np.ndarray]:
    """Rebuild a dense slot layout dropping tombstones. Returns (new store,
    old_ids (new_cap,)) with ``old_ids[j]`` = previous slot of new slot j
    (−1 for empty slots) — reindex side payloads with it."""
    alive_np = np.asarray(store.alive)
    live = np.nonzero(alive_np)[0]
    n = len(live)
    cap = max(next_pow2(max(n, 1)), 1)
    old_ids = np.full((cap,), -1, np.int64)
    old_ids[:n] = live
    sl = jnp.asarray(live)
    alive = jnp.arange(cap) < n
    kw = dict(alive=alive,
              prior_var=_take_pad(store.prior_var, sl, cap))
    if store.kind == "sparse":
        kw.update(indices=_take_pad(store.indices, sl, cap, fill=store.d),
                  values=_take_pad(store.values, sl, cap),
                  nnz=_take_pad(store.nnz, sl, cap))
    else:
        kw.update(x=_take_pad(store.x, sl, cap))
    log.info("compacted index: %d live slots, capacity %d -> %d",
             n, store.capacity, cap)
    return dataclasses.replace(store, **kw), old_ids


def _take_pad(arr, sl, cap: int, fill=0):
    taken = arr[sl]
    pad = cap - taken.shape[0]
    if pad:
        widths = ((0, pad),) + ((0, 0),) * (arr.ndim - 1)
        taken = jnp.pad(taken, widths, constant_values=fill)
    return taken
