"""Slot placement for the sharded index (DESIGN.md §5.1).

Global addressing is row-major over (shard, local slot): every shard owns the
same number of slots (the *stride* — per-shard capacity, kept uniform across
shards so the addressing stays a pair of integer ops on device):

    global_id = shard * stride + local_slot

The stride only ever changes on a *global* growth or compaction event, and
those return an old→new global-id map (the same contract as
``mutable.compact``) so side payloads can be reindexed.

Two placement policies cover build and steady-state insert traffic:

  * ``round_robin`` — item i goes to shard ``(start + i) % S``; perfectly
    balanced for bulk builds and deterministic (the manifest round-trip and
    re-shard paths rely on that determinism),
  * ``least_loaded`` — each item goes to the currently lightest shard
    (ties → lowest shard id); the default for online inserts, where deletes
    have made the shards uneven.
"""
from __future__ import annotations

import numpy as np

PLACEMENTS = ("round_robin", "least_loaded")


def assign_round_robin(n_items: int, n_shards: int, *, start: int = 0) -> np.ndarray:
    """(n_items,) shard ids, cycling from ``start``."""
    return ((start + np.arange(n_items)) % n_shards).astype(np.int32)


def assign_least_loaded(loads, n_items: int) -> np.ndarray:
    """(n_items,) shard ids, each item greedily routed to the lightest shard
    (``loads`` = live counts per shard; ties break toward lower shard ids)."""
    loads = np.asarray(loads, np.int64).copy()
    out = np.empty((n_items,), np.int32)
    for i in range(n_items):
        s = int(np.argmin(loads))
        out[i] = s
        loads[s] += 1
    return out


def assign(policy: str, loads, n_items: int) -> np.ndarray:
    if policy == "round_robin":
        # start the cycle at the lightest shard so repeated small batches
        # don't all pile onto shard 0
        return assign_round_robin(n_items, len(loads),
                                  start=int(np.argmin(loads)))
    if policy == "least_loaded":
        return assign_least_loaded(loads, n_items)
    raise ValueError(f"unknown placement {policy!r} (want one of {PLACEMENTS})")


# -- global ↔ (shard, local) addressing -------------------------------------


def global_id(shard, local, stride: int):
    return shard * stride + local


def shard_of(gid, stride: int):
    return gid // stride


def local_of(gid, stride: int):
    return gid % stride


def balance(live_counts) -> float:
    """max/mean load imbalance (1.0 = perfectly balanced) — surfaced by the
    sharded benches and engine stats."""
    live = np.asarray(live_counts, np.float64)
    mean = live.mean()
    return float(live.max() / mean) if mean > 0 else 1.0
