"""repro.index.sharded — one persistent racing index spanning the mesh
(DESIGN.md §5).

The paper's O((n+d)·log²(nd/δ)) bound is per machine; past one device the
slot axis of the PR-1 ``IndexStore`` is partitioned across a named mesh axis
("shards") and raced *shard-locally*:

  * **Addressing** (placement.py): every shard owns ``stride`` slots and
    ``global_id = shard · stride + local_slot`` — two integer ops on device.
    The stride is uniform across shards and changes only on global growth /
    compaction / re-shard events, each of which returns an old→new global-id
    map (the ``mutable.compact`` contract) for payload reindexing.
  * **Racing**: dense/rotated boxes run the PR-2 fused epoch race under
    ``shard_map`` — each shard keeps its own survivor frontier over its
    ``stride`` slots and certifies its own local top-k. The host epoch loop
    is shared: one fused launch per shard per epoch, shard-local survivor
    compaction at a common bucket width, and a **cross-shard pull-budget
    reallocator**: the per-epoch fused round count R scales with the global
    pull budget over the *total* surviving work, so when a shard certifies
    and goes idle its share of the budget shifts to the still-racing shards
    (Neufeld et al.-style bandit allocation across estimators). Sparse boxes
    run the per-round driver shard-locally in a single collective program.
  * **Merge**: θ is a per-coordinate average, so the global top-k is
    contained in the union of per-shard certified top-ks (the
    ``core/distributed.py`` argument). One ``all_gather`` of each shard's
    (values, global ids) over the shard axis + a replicated top-k reduce
    finishes the query. A shard with fewer than k live slots certifies its
    whole live set (the drivers' candidate-exhaustion ``done`` rule) and
    pads its contribution with +inf values.

Failure budget: shard-local races run at δ/S, so the per-interval budget is
δ′ = (δ/S)/(stride·MAX_PULLS) = δ/(n_total·MAX_PULLS) — exactly the
single-shard union bound; CI radii match the single-shard driver arm for
arm (the variance *pool* is shard-local, which only changes the empirical
shrinkage target).

Lifecycle: ``build_sharded_index`` (round-robin or least-loaded placement),
``sharded_insert`` (routed to the least-loaded shard, uniform capacity
growth), ``sharded_delete`` (tombstones), ``sharded_maybe_compact`` (global
threshold policy, per-shard rebuild, global-id remap), and persistence as
per-shard checkpoint directories plus a manifest — an index saved at S
shards reloads at S′ ≠ S (``load_sharded_index(shards=S')`` re-shards the
live rows and returns the global-id remap).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import os
import time
from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import BMOConfig
from repro.core import confidence as conf
from repro.core.bmo_nn import sparse_exact_theta
from repro.core.datasets import SparseDataset, next_pow2
from repro.index import placement as plc
from repro.index.batched_race import (_dense_exact_theta, _frontier_ci,
                                      _fused_epoch_step, _fused_init,
                                      _sparse_index_knn, batched_race_topk)
from repro.index.builder import build_index
from repro.index.frontier import (FrontierState, bucket_width,
                                  compact_frontier, floor_width, pow2_floor)
from repro.index.mutable import _take_pad, _widen_sparse
from repro.index import mutable
from repro.index.store import IndexStore
from repro.kernels import ops as kops
from repro.obs import get_obs
from repro.obs import profile as obs_profile
from repro.utils import get_logger

log = get_logger("repro.index")

AXIS = "shards"
MANIFEST = "manifest.msgpack"
INF = jnp.inf


class ShardedKNNResult(NamedTuple):
    """KNNResult-compatible (duck-typed on the serving path) plus the
    per-shard counters the engine surfaces as ``knn_shard_*`` stats."""
    indices: jax.Array          # (Q, k) GLOBAL slot ids
    values: jax.Array           # (Q, k) ascending θ
    coord_ops: jax.Array        # (Q,) summed over shards
    rounds: jax.Array           # (Q,) max over shards
    n_exact: jax.Array          # (Q,) summed over shards
    shard_coord_ops: jax.Array  # (S,) total coordinate-ops per shard
    shard_rounds: jax.Array     # (S,) max rounds per shard


@dataclasses.dataclass
class ShardedIndexStore:
    """S per-shard ``IndexStore``s with uniform capacity (the stride), one
    logical index. Immutable like IndexStore — every mutation builds a new
    instance, so engine-side cache invalidation-by-identity keeps working."""
    shards: List[IndexStore]
    placement: str = "round_robin"
    device_offset: int = 0    # first visible device of this store's mesh —
                              # read replicas (repro.api.admin) place copies
                              # of the same shards on disjoint device slices

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def stride(self) -> int:
        return self.shards[0].capacity

    @property
    def capacity(self) -> int:
        return self.n_shards * self.stride

    @property
    def n_live(self) -> int:
        return sum(s.n_live for s in self.shards)

    @property
    def kind(self) -> str:
        return self.shards[0].kind

    @property
    def cfg(self) -> BMOConfig:
        return self.shards[0].cfg

    @property
    def d(self) -> int:
        return self.shards[0].d

    @property
    def block(self) -> int:
        return self.shards[0].block

    @property
    def prior_weight(self) -> float:
        return self.shards[0].prior_weight

    @property
    def prior_var(self) -> jax.Array:
        """(capacity,) per-arm priors in global-id order (shard-major)."""
        return jnp.concatenate([s.prior_var for s in self.shards])

    @property
    def live_per_shard(self) -> List[int]:
        return [s.n_live for s in self.shards]

    @property
    def mesh(self) -> Mesh:
        """1-D mesh over the first S local devices (cached per instance)."""
        if "_mesh" not in self.__dict__:
            devs = jax.devices()
            lo, hi = self.device_offset, self.device_offset + self.n_shards
            if len(devs) < hi:
                raise RuntimeError(
                    f"{self.n_shards} index shards at device offset "
                    f"{self.device_offset} need {hi} devices but only "
                    f"{len(devs)} are visible — on CPU run under "
                    "XLA_FLAGS=--xla_force_host_platform_device_count="
                    f"{hi}")
            self._mesh = Mesh(np.asarray(devs[lo:hi]), (AXIS,))
        return self._mesh

    def device_arrays(self) -> dict:
        """Shard-stacked arrays, placed P("shards") on the mesh (cached per
        instance; mutations build new instances so this self-invalidates)."""
        if "_dev" not in self.__dict__:
            sh = NamedSharding(self.mesh, P(AXIS))
            names = (("indices", "values", "nnz") if self.kind == "sparse"
                     else ("x",)) + ("alive", "prior_var")
            self._dev = {
                name: jax.device_put(
                    jnp.stack([getattr(s, name) for s in self.shards]), sh)
                for name in names}
        return self._dev

    def prepare_queries(self, queries) -> jax.Array:
        return self.shards[0].prepare_queries(queries)

    def query(self, queries, rng, *, k=None, impl: str = "auto"):
        return sharded_index_knn(self, queries, rng, k=k, impl=impl)


# ---------------------------------------------------------------------------
# build / mutate
# ---------------------------------------------------------------------------


def build_sharded_index(corpus, cfg: BMOConfig, rng: jax.Array, *,
                        shards: int, placement: str = "round_robin",
                        capacity: Optional[int] = None, impl: str = "auto",
                        ) -> Tuple[ShardedIndexStore, np.ndarray]:
    """Partition ``corpus`` (n, d) across ``shards`` per-shard IndexStores.
    Returns ``(store, global_ids)`` with ``global_ids[i]`` the global slot of
    corpus row i — align side payloads with it. ``capacity``: total slots
    (split evenly); default next-pow2 of the heaviest shard.

    All shards share one rotation: ``build_index`` draws the §IV-B sign
    vector from ``rng`` alone, so passing the *same* key to every shard
    build caches the same rotation everywhere (queries are rotated once)."""
    corpus = np.asarray(corpus)
    n = corpus.shape[0]
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    sid = plc.assign(placement, np.zeros(shards, np.int64), n)
    rows_of = [np.nonzero(sid == s)[0] for s in range(shards)]
    per_cap = (capacity // shards if capacity
               else next_pow2(max(1, max(len(r) for r in rows_of))))
    stores = [build_index(corpus[rows], cfg, rng, capacity=per_cap, impl=impl)
              for rows in rows_of]
    if cfg.sparse:                     # uniform padded-CSR width across shards
        m_max = max(s.m for s in stores)
        stores = [_widen_sparse(s, m_max) for s in stores]
    gids = np.empty((n,), np.int64)
    for s, rows in enumerate(rows_of):
        gids[rows] = s * per_cap + np.arange(len(rows))
    log.info("built sharded %s index: n=%d shards=%d stride=%d (%s)",
             stores[0].kind, n, shards, per_cap, placement)
    return ShardedIndexStore(stores, placement), gids


def _grow_to(shard: IndexStore, cap: int) -> IndexStore:
    """Pad one shard to an exact capacity (uniform-stride growth)."""
    extra = cap - shard.capacity
    if extra <= 0:
        return shard
    kw = dict(alive=jnp.pad(shard.alive, (0, extra)),
              prior_var=jnp.pad(shard.prior_var, (0, extra)))
    if shard.kind == "sparse":
        kw.update(indices=jnp.pad(shard.indices, ((0, extra), (0, 0)),
                                  constant_values=shard.d),
                  values=jnp.pad(shard.values, ((0, extra), (0, 0))),
                  nnz=jnp.pad(shard.nnz, (0, extra)))
    else:
        kw.update(x=jnp.pad(shard.x, ((0, extra), (0, 0))))
    return dataclasses.replace(shard, **kw)


def _stride_remap(S: int, old_stride: int, new_stride: int) -> np.ndarray:
    """old→new global-id map for a stride change (compact contract:
    ``old_ids[new_gid]`` = previous gid, −1 where no slot existed)."""
    old_ids = np.full((S * new_stride,), -1, np.int64)
    keep = min(old_stride, new_stride)
    for s in range(S):
        old_ids[s * new_stride: s * new_stride + keep] = \
            s * old_stride + np.arange(keep)
    return old_ids


def sharded_insert(store: ShardedIndexStore, rows
                   ) -> Tuple[ShardedIndexStore, np.ndarray,
                              Optional[np.ndarray]]:
    """Insert (B, d) dense rows, each routed to the least-loaded shard.
    Returns ``(store, global_ids (B,), old_ids)`` — ``old_ids`` is None
    unless a shard's growth changed the stride (then it is the global
    old→new slot map; reindex payloads with it before using the new ids)."""
    rows = np.asarray(rows, np.float32)
    if rows.ndim == 1:
        rows = rows[None]
    bsz = rows.shape[0]
    S = store.n_shards
    old_stride = store.stride
    sid = plc.assign_least_loaded([s.n_live for s in store.shards], bsz)
    shards = list(store.shards)
    local_slots = np.empty((bsz,), np.int64)
    for s in set(sid.tolist()):
        mask = sid == s
        shards[s], slots = mutable.insert(shards[s], rows[mask])
        local_slots[mask] = slots
    new_stride = max(s.capacity for s in shards)
    if new_stride != old_stride:
        shards = [_grow_to(s, new_stride) for s in shards]
    if store.kind == "sparse":
        m_max = max(s.m for s in shards)
        shards = [_widen_sparse(s, m_max) for s in shards]
    gids = sid.astype(np.int64) * new_stride + local_slots
    old_ids = (None if new_stride == old_stride
               else _stride_remap(S, old_stride, new_stride))
    if old_ids is not None:
        log.info("sharded index stride grew %d -> %d (global-id remap)",
                 old_stride, new_stride)
    return dataclasses.replace(store, shards=shards), gids, old_ids


def sharded_delete(store: ShardedIndexStore, global_ids) -> ShardedIndexStore:
    """Tombstone global slots (O(1) per shard)."""
    gids = np.atleast_1d(np.asarray(global_ids, np.int64))
    stride = store.stride
    shards = list(store.shards)
    for s in np.unique(gids // stride):
        shards[s] = mutable.delete(shards[s], gids[gids // stride == s] % stride)
    return dataclasses.replace(store, shards=shards)


def tombstone_fraction(store: ShardedIndexStore) -> float:
    return 1.0 - store.n_live / max(store.capacity, 1)


def sharded_compact(store: ShardedIndexStore
                    ) -> Tuple[ShardedIndexStore, np.ndarray]:
    """Rebuild every shard's slot layout dropping tombstones, at a common
    (uniform-stride) capacity. Returns (store, old_ids) with the global
    old→new slot map (−1 for empty slots)."""
    S, old_stride = store.n_shards, store.stride
    live = [np.nonzero(np.asarray(s.alive))[0] for s in store.shards]
    new_stride = max(1, next_pow2(max(1, max(len(l) for l in live))))
    shards = []
    old_ids = np.full((S * new_stride,), -1, np.int64)
    for s, (shard, sl) in enumerate(zip(store.shards, live)):
        slj = jnp.asarray(sl)
        kw = dict(alive=jnp.arange(new_stride) < len(sl),
                  prior_var=_take_pad(shard.prior_var, slj, new_stride))
        if shard.kind == "sparse":
            kw.update(indices=_take_pad(shard.indices, slj, new_stride,
                                        fill=shard.d),
                      values=_take_pad(shard.values, slj, new_stride),
                      nnz=_take_pad(shard.nnz, slj, new_stride))
        else:
            kw.update(x=_take_pad(shard.x, slj, new_stride))
        shards.append(dataclasses.replace(shard, **kw))
        old_ids[s * new_stride: s * new_stride + len(sl)] = s * old_stride + sl
    log.info("compacted sharded index: stride %d -> %d (%d live)",
             old_stride, new_stride, store.n_live)
    return dataclasses.replace(store, shards=shards), old_ids


def sharded_maybe_compact(store: ShardedIndexStore, *,
                          threshold: float = 0.5
                          ) -> Tuple[ShardedIndexStore, Optional[np.ndarray]]:
    """Global auto-compaction policy (the ``mutable.maybe_compact`` contract
    lifted to the sharded store): rebuild only when the global tombstone
    fraction crosses ``threshold`` AND the uniform stride actually shrinks."""
    if (store.capacity and tombstone_fraction(store) > threshold
            and next_pow2(max(max(store.live_per_shard), 1)) < store.stride):
        return sharded_compact(store)
    return store, None


# ---------------------------------------------------------------------------
# persistence: per-shard checkpoints + manifest, re-shard on load
# ---------------------------------------------------------------------------


def save_sharded_index(store: ShardedIndexStore, path: str, *,
                       extra=None) -> None:
    """path/shard_%04d/ (checkpoint layout, one per shard) + path/manifest.

    The whole directory — every shard, the manifest, and any ``extra``
    sidecars — is staged in a tmp sibling and published with one rename
    (``checkpoint.manager.staged_dir``): a crash mid-save leaves the
    previous index intact, never a mix of old and new shards."""
    import msgpack
    from repro import checkpoint
    with checkpoint.manager.staged_dir(path) as tmp:
        for s, shard in enumerate(store.shards):
            checkpoint.manager.save(os.path.join(tmp, f"shard_{s:04d}"),
                                    shard.arrays(), meta=shard.meta())
        manifest = {
            "version": 1,
            "n_shards": store.n_shards,
            "stride": store.stride,
            "placement": store.placement,
            "kind": store.kind,
            "live_per_shard": store.live_per_shard,
            "capacities": [s.capacity for s in store.shards],
        }
        with open(os.path.join(tmp, MANIFEST), "wb") as f:
            f.write(msgpack.packb(manifest))
        if extra is not None:
            extra(tmp)


def is_sharded_index_dir(path: str) -> bool:
    return os.path.exists(os.path.join(path, MANIFEST))


def read_manifest(path: str) -> dict:
    import msgpack
    with open(os.path.join(path, MANIFEST), "rb") as f:
        return msgpack.unpackb(f.read())


def load_sharded_index(path: str, *, shards: Optional[int] = None
                       ) -> Tuple[ShardedIndexStore, Optional[np.ndarray]]:
    """Load a saved sharded index; ``shards=S'`` re-shards on the way in.
    Returns ``(store, old_ids)`` — ``old_ids`` is None when the shard count
    is unchanged, else the old→new global-id map (compact contract)."""
    from repro import checkpoint
    manifest = read_manifest(path)
    S0 = int(manifest["n_shards"])
    stores = []
    for s in range(S0):
        sdir = os.path.join(path, f"shard_{s:04d}")
        stores.append(IndexStore.from_arrays(
            checkpoint.manager.load_arrays(sdir),
            checkpoint.manager.read_meta(sdir)))
    store = ShardedIndexStore(stores, manifest.get("placement", "round_robin"))
    if shards is None or shards == S0:
        return store, None
    return reshard(store, shards)


def reshard(store: ShardedIndexStore, n_shards: int
            ) -> Tuple[ShardedIndexStore, np.ndarray]:
    """Redistribute the live rows of ``store`` over ``n_shards`` shards
    (round-robin in ascending old-global-id order — deterministic, so a
    S→S′→S round trip is the identity on row *data*). Per-slot arrays (rows,
    priors, padded-CSR triplets) ride along untouched: the rotation is NOT
    redrawn, so rotated stores stay query-compatible. Returns
    ``(store, old_ids)`` with the global old→new slot map."""
    S0, stride0 = store.n_shards, store.stride
    alive = np.concatenate([np.asarray(s.alive) for s in store.shards])
    old_gids = np.nonzero(alive)[0]               # ascending global-id order
    n = len(old_gids)
    sid = plc.assign_round_robin(n, n_shards)
    counts = np.bincount(sid, minlength=n_shards)
    new_stride = max(1, next_pow2(max(1, int(counts.max(initial=1)))))

    def stacked(name):
        return np.concatenate([np.asarray(getattr(s, name))
                               for s in store.shards])

    proto = store.shards[0]
    names = (("indices", "values", "nnz") if store.kind == "sparse"
             else ("x",)) + ("prior_var",)
    data = {name: stacked(name)[old_gids] for name in names}

    shards = []
    old_ids = np.full((n_shards * new_stride,), -1, np.int64)
    for t in range(n_shards):
        rows = np.nonzero(sid == t)[0]            # ascending
        kw = dict(alive=jnp.arange(new_stride) < len(rows))
        for name in names:
            taken = jnp.asarray(data[name][rows])
            fill = proto.d if name == "indices" else 0
            kw[name] = _take_pad(taken, jnp.arange(len(rows)), new_stride,
                                 fill=fill)
        shards.append(dataclasses.replace(proto, **kw))
        old_ids[t * new_stride: t * new_stride + len(rows)] = old_gids[rows]
    log.info("re-sharded index: %d shards (stride %d) -> %d shards "
             "(stride %d), %d live rows", S0, stride0, n_shards, new_stride, n)
    return ShardedIndexStore(shards, store.placement), old_ids


# ---------------------------------------------------------------------------
# racing: shard-local races + certified all-gather merge
# ---------------------------------------------------------------------------


def flat_axis_index(axes):
    """Flattened index across one or more mesh axes (row-major)."""
    if isinstance(axes, str):
        return jax.lax.axis_index(axes)
    idx = jnp.zeros((), jnp.int32)
    for ax in axes:
        idx = idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    return idx


def merge_local_topk(vals, gids, axes, k: int):
    """All-gather every shard's certified local top-k over ``axes`` and
    reduce to the global top-k (the global top-k ⊆ union of local top-ks;
    invalid local entries must arrive as +inf). vals/gids (Q, k) →
    replicated (Q, k) (indices, values ascending)."""
    Q = vals.shape[0]
    vals_all = jax.lax.all_gather(vals, axes, tiled=True)     # (D·Q, k)
    gids_all = jax.lax.all_gather(gids, axes, tiled=True)
    D = vals_all.shape[0] // Q
    v = vals_all.reshape(D, Q, k).transpose(1, 0, 2).reshape(Q, D * k)
    g = gids_all.reshape(D, Q, k).transpose(1, 0, 2).reshape(Q, D * k)
    neg, pos = jax.lax.top_k(-v, k)
    return jnp.take_along_axis(g, pos, axis=1), -neg


def guard_local_topk(indices, values, alive):
    """Mask junk entries of a shard-local top-k before the merge: a shard
    with fewer than k live slots fills its missing entries from its (dead,
    pre-rejected) padding — elimination never rejects a live arm while
    fewer than k live candidates exist, so deadness is exactly the junk
    test. Their values become +inf so the merge ignores them."""
    return jnp.where(alive[indices], values, INF)


# Why the merge needs EXACT values (DESIGN.md §5.3): certification is an
# *ordering* guarantee within a shard — an accepted arm's mean is only known
# to within its final CI, and sharding makes local races easier (fewer close
# competitors per shard), so they stop with looser estimates than the
# single-shard race would. Merging estimates across shards then misorders
# near-ties. Each shard therefore exact-evaluates its ≤ k certified winners
# before the gather — S·k·d coordinate reads per query batch, the same O(d)
# term the paper's bound already pays per query — and the merged top-k is
# exact whenever every shard's local top-k set is (w.h.p. 1 − δ).


def local_dense_race(x_loc, qs, alive, prior, rng, *, cfg: BMOConfig,
                     block: int, d: int, impl: str, eliminate: bool,
                     prior_weight: float, model_axis: Optional[str] = None):
    """One shard's per-round (PR-1) batched race over its local slots —
    also the body ``core.distributed`` wraps, where pulls are additionally
    stratified over a model (coordinate) axis and pmean-reduced."""
    n_loc, d_loc = x_loc.shape
    nb_loc = d_loc // block
    Q = qs.shape[0]
    P_ = cfg.pulls_per_round

    def pull(sel, key):
        if model_axis is not None:
            key = jax.random.fold_in(key, jax.lax.axis_index(model_axis))
        blk = jax.random.randint(key, sel.shape + (P_,), 0, nb_loc)
        vals = kops.block_pull_multi(x_loc, qs, sel, blk, block=block,
                                     metric=cfg.metric, impl=impl)
        if model_axis is not None:
            vals = jax.lax.pmean(vals, model_axis)
        return vals

    def exact(sel):
        th = _dense_exact_theta(x_loc, qs, sel, cfg.metric, d)
        if model_axis is not None:
            th = jax.lax.psum(th, model_axis)
        return th

    return batched_race_topk(
        pull, exact, n=n_loc, Q=Q,
        max_pulls=float(nb_loc), pull_cost=float(block),
        exact_cost=float(d_loc) if model_axis is not None else float(d),
        cfg=cfg, rng=rng, eliminate=eliminate,
        dead=~alive, prior_var=prior, prior_weight=prior_weight)


def _shard_delta(cfg: BMOConfig, S: int) -> BMOConfig:
    """δ/S per shard-local race ⇒ δ′ = δ/(S·stride·MAX_PULLS) per interval —
    the same union bound the single-shard driver runs at n_total slots."""
    return dataclasses.replace(cfg, delta=conf.shard_delta(cfg.delta, S))


def _squeeze(tree):
    return jax.tree_util.tree_map(lambda a: a[0], tree)


def _unsqueeze(tree):
    return jax.tree_util.tree_map(lambda a: a[None], tree)


def _finish_local(vals, gids, coord_ops, rounds, n_exact, k: int):
    """Merge + per-query/per-shard stat reduction shared by every driver."""
    merged_idx, merged_vals = merge_local_topk(vals, gids, AXIS, k)
    coord_q = jax.lax.psum(coord_ops, AXIS)
    rounds_q = jax.lax.pmax(rounds, AXIS)
    nex_q = jax.lax.psum(n_exact, AXIS)
    shard_ops = jnp.sum(coord_ops)[None]
    shard_rounds = jnp.max(rounds)[None]
    return (merged_idx, merged_vals, coord_q, rounds_q, nex_q,
            shard_ops, shard_rounds)


_OUT_SPECS = (P(), P(), P(), P(), P(), P(AXIS), P(AXIS))


@functools.lru_cache(maxsize=None)
def _rounds_dense_fn(mesh, cfg, block, d, impl, eliminate, prior_weight,
                     stride):
    def body(x, qs, alive, prior, rng):
        x, alive, prior = x[0], alive[0], prior[0]
        rng = jax.random.fold_in(rng, jax.lax.axis_index(AXIS))
        res = local_dense_race(x, qs, alive, prior, rng, cfg=cfg, block=block,
                               d=d, impl=impl, eliminate=eliminate,
                               prior_weight=prior_weight)
        exact_vals = _dense_exact_theta(x, qs, res.indices, cfg.metric, d)
        vals = guard_local_topk(res.indices, exact_vals, alive)
        gids = jax.lax.axis_index(AXIS) * stride + res.indices
        coord_ops = res.coord_ops + float(cfg.k * d)
        return _finish_local(vals, gids, coord_ops, res.rounds,
                             res.n_exact, cfg.k)

    return jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P(AXIS), P(), P(AXIS), P(AXIS), P()),
        out_specs=_OUT_SPECS, check_vma=False))


@functools.lru_cache(maxsize=None)
def _rounds_sparse_fn(mesh, cfg, d, eliminate, prior_weight, stride):
    def body(idx, val, nnz, alive, prior, qi, qv, qn, rng):
        idx, val, nnz, alive, prior = (idx[0], val[0], nnz[0], alive[0],
                                       prior[0])
        rng = jax.random.fold_in(rng, jax.lax.axis_index(AXIS))
        res = _sparse_index_knn(idx, val, nnz, alive, prior, qi, qv, qn, rng,
                                cfg=cfg, d=d, eliminate=eliminate,
                                prior_weight=prior_weight)
        ds = SparseDataset(indices=idx, values=val, nnz=nnz, d=d)
        exact_vals = jax.vmap(
            lambda qi_, qv_, s: sparse_exact_theta(ds, qi_, qv_, s)
        )(qi, qv, res.indices)
        vals = guard_local_topk(res.indices, exact_vals, alive)
        gids = jax.lax.axis_index(AXIS) * stride + res.indices
        coord_ops = res.coord_ops + jnp.sum(
            nnz[res.indices].astype(jnp.float32) + qn[:, None], axis=1)
        return _finish_local(vals, gids, coord_ops, res.rounds,
                             res.n_exact, cfg.k)

    return jax.jit(jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS),
                  P(), P(), P(), P()),
        out_specs=_OUT_SPECS, check_vma=False))


# -- epoch-fused sharded driver ---------------------------------------------

_ST_SPEC = FrontierState(*([P(AXIS)] * len(FrontierState._fields)))


@functools.lru_cache(maxsize=None)
def _fused_init_fn(mesh, cfg, block, impl, prior_weight):
    def body(x, qs, alive, prior, rng):
        rng = jax.random.fold_in(rng, jax.lax.axis_index(AXIS))
        st, pool = _fused_init(x[0], qs, alive[0], prior[0], rng, cfg=cfg,
                               block=block, impl=impl,
                               prior_weight=prior_weight)
        return _unsqueeze(st), pool[None]

    return jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P(AXIS), P(), P(AXIS), P(AXIS), P()),
        out_specs=(_ST_SPEC, P(AXIS)), check_vma=False))


@functools.lru_cache(maxsize=None)
def _fused_step_fn(mesh, cfg, block, d, impl, eliminate, prior_weight,
                   log_term, T):
    def body(x, qs, st, pool):
        st2, n_surv, done = _fused_epoch_step(
            x[0], qs, _squeeze(st), pool[0], cfg=cfg, block=block, d=d,
            impl=impl, eliminate=eliminate, prior_weight=prior_weight,
            log_term=log_term, T=T)
        return _unsqueeze(st2), n_surv[None], done[None]

    return jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P(AXIS), P(), _ST_SPEC, P(AXIS)),
        out_specs=(_ST_SPEC, P(AXIS), P(AXIS)), check_vma=False))


@functools.lru_cache(maxsize=None)
def _fused_finalize_fn(mesh, cfg, log_term, prior_weight, stride, block, d,
                       metric):
    k = cfg.k

    def body(x, qs, st, pool):
        st = _squeeze(st)
        ci = _frontier_ci(st, cfg, log_term, pool[0], prior_weight)
        # local ranking with explicit junk detection: entries picked from
        # rejected/padding (only possible when the shard has < k live slots)
        # surface as +inf values, which the merge discards
        score = jnp.where(st.accepted & st.valid, st.mean - 1e9,
                          jnp.where(st.rejected | ~st.valid, INF,
                                    st.mean - ci))
        _, pos = jax.lax.top_k(-score, k)                     # (Q, k)
        slots = jnp.take_along_axis(st.ids, pos, axis=1)
        vals = _dense_exact_theta(x[0], qs, slots, metric, d)
        ok = jnp.take_along_axis(score, pos, axis=1) < INF
        vals = jnp.where(ok, vals, INF)
        gids = jax.lax.axis_index(AXIS) * stride + slots
        coord_ops = st.coord_ops + float(k * d)
        return _finish_local(vals, gids, coord_ops, st.rounds,
                             st.n_exact, k)

    return jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P(AXIS), P(), _ST_SPEC, P(AXIS)),
        out_specs=_OUT_SPECS, check_vma=False))


@functools.partial(jax.jit, static_argnames=("W_new",))
def _compact_stacked(st: FrontierState, *, W_new: int) -> FrontierState:
    """frontier.compact_frontier vmapped over the leading shard axis of the
    (S, Q, W)-stacked per-shard state — per-shard-local gathers, no
    collectives, one shared bucket width."""
    return jax.vmap(functools.partial(compact_frontier, W_new=W_new))(st)


def _sharded_fused_race(store: ShardedIndexStore, qs, prior_st, rng, *,
                        cfg: BMOConfig, impl: str, eliminate: bool,
                        prior_weight: float):
    """The PR-2 epoch-fused race run shard-locally under shard_map, with the
    host epoch loop shared across shards (DESIGN.md §5.2). Collectives per
    query: nothing during the race (each epoch launch is shard-local), one
    all-gather of (2·k fp32+int32 per shard) at the merge."""
    S, stride, mesh = store.n_shards, store.stride, store.mesh
    dev = store.device_arrays()
    x_st, alive_st = dev["x"], dev["alive"]
    block = store.block
    Q = qs.shape[0]
    k = cfg.k
    nb = x_st.shape[2] // block
    P_ = cfg.pulls_per_round
    # δ′ at the GLOBAL slot count — identical per-arm budget to the
    # single-shard fused driver over the same corpus
    log_term = float(np.log(2.0 / conf.delta_prime(cfg.delta, S * stride, nb)))
    B0 = min(cfg.batch_arms, stride)
    R0 = max(cfg.epoch_rounds, 1)
    R_cap = max(1, -(-nb // P_))
    floor_w = floor_width(cfg, stride, B0=B0)
    max_rounds = cfg.max_rounds or int(
        2 * math.ceil(stride * nb / max(B0 * P_, 1)) + stride + 16)

    st, pool = _fused_init_fn(mesh, cfg, block, impl, prior_weight)(
        x_st, qs, alive_st, prior_st, rng)
    W0 = st.ids.shape[2]
    rounds_spent = 0
    n_surv = np.full((S, Q), stride)
    done = np.zeros((S, Q), bool)
    obs = get_obs()
    prev_coord = 0.0
    while not done.all() and rounds_spent < max_rounds:
        active = ~done
        need = int(n_surv[active].max(initial=1))
        W_new = bucket_width(need, floor=floor_w, current=st.ids.shape[2])
        if W_new < st.ids.shape[2]:
            st = _compact_stacked(st, W_new=W_new)
        # cross-shard pull-budget reallocation: the per-epoch budget is
        # S·W0·R0 pulls; R fuses enough rounds to spend it over the TOTAL
        # surviving work, so certified (idle) shards' shares flow to the
        # still-racing ones. With S=1 this is exactly the single-shard
        # adaptive rule R = R0·max(1, W0/need) (pow2-quantized so T = R·P
        # stays on the warm specialization chain).
        total_need = sum(int(n_surv[s][active[s]].max(initial=0))
                         for s in range(S))
        R = min(R0 * pow2_floor((S * W0) // max(total_need, 1)), R_cap)
        t0 = time.perf_counter()
        st, n_surv_d, done_d = _fused_step_fn(
            mesh, cfg, block, store.d, impl, eliminate, prior_weight,
            log_term, R * P_)(x_st, qs, st, pool)
        rounds_spent += R
        n_surv = np.asarray(n_surv_d)
        done = np.asarray(done_d)
        # per-epoch timing under the same histogram the anytime sessions
        # feed — repro.tune races candidate configs on this series
        coord = float(np.sum(np.asarray(st.coord_ops)))
        obs.registry.histogram(
            "repro_race_epoch_ms", "wall time of one race epoch (ms)",
            kind="sharded_fused_blocking").observe(
            (time.perf_counter() - t0) * 1e3)
        obs_profile.record_kernel_launch(
            obs, "fused_epoch_pull", launches=S,
            coord_ops=max(coord - prev_coord, 0.0), pulls=float(R))
        prev_coord = coord

    outs = _fused_finalize_fn(mesh, cfg, log_term, prior_weight, stride,
                              block, store.d, cfg.metric)(x_st, qs, st, pool)
    return ShardedKNNResult(*outs)


# ---------------------------------------------------------------------------
# front-end
# ---------------------------------------------------------------------------


def sharded_index_knn(store: ShardedIndexStore, queries, rng: jax.Array, *,
                      k=None, impl: str = "auto", eliminate: bool = True,
                      warm_start: bool = True, mode: str = "auto",
                      prior_hint=None) -> ShardedKNNResult:
    """Batched k-NN against a ShardedIndexStore: shard-local racing + the
    certified all-gather merge. Same contract as ``index_knn`` (which
    dispatches here), with GLOBAL slot ids in the result."""
    cfg = store.cfg if k is None else dataclasses.replace(store.cfg, k=k)
    n_live = store.n_live
    if cfg.k > n_live:
        raise ValueError(
            f"k={cfg.k} exceeds the index's {n_live} live slots — "
            "tombstoned slots can never be returned")
    if mode not in ("auto", "fused", "rounds"):
        raise ValueError(f"unknown mode {mode!r}")
    S, stride = store.n_shards, store.stride
    Q = (queries[0] if isinstance(queries, tuple) else
         jnp.asarray(queries)).shape[0]
    w = store.prior_weight if (warm_start or prior_hint is not None) else 0.0
    if prior_hint is not None:
        # (Q, capacity) global per-query priors → (S, Q, stride) shard-major
        prior_st = jnp.asarray(prior_hint, jnp.float32).reshape(
            Q, S, stride).transpose(1, 0, 2)
    else:
        prior_st = store.device_arrays()["prior_var"]          # (S, stride)

    if store.kind == "sparse":
        if mode == "fused":
            raise ValueError("the fused epoch driver pulls corpus blocks — "
                             "sparse boxes race on the per-round driver")
        dev = store.device_arrays()
        q_idx, q_val, q_nnz = queries
        outs = _rounds_sparse_fn(store.mesh, _shard_delta(cfg, S), store.d,
                                 eliminate, w, stride)(
            dev["indices"], dev["values"], dev["nnz"], dev["alive"], prior_st,
            jnp.asarray(q_idx), jnp.asarray(q_val), jnp.asarray(q_nnz), rng)
        return ShardedKNNResult(*outs)
    qs = store.prepare_queries(queries)
    if mode == "rounds":
        dev = store.device_arrays()
        outs = _rounds_dense_fn(store.mesh, _shard_delta(cfg, S), store.block,
                                store.d, impl, eliminate, w, stride)(
            dev["x"], qs, dev["alive"], prior_st, rng)
        return ShardedKNNResult(*outs)
    return _sharded_fused_race(store, qs, prior_st, rng, cfg=cfg, impl=impl,
                               eliminate=eliminate, prior_weight=w)
