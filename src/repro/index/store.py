"""IndexStore: the persistent, mutable corpus container behind the batched
BMO-NN index service (DESIGN.md §3).

One store owns everything the paper's Algorithm 2 recomputes per call:
  * the padded/blocked corpus layout (dense), the cached Hadamard rotation
    (sign vector + pre-rotated corpus — only *queries* are rotated at request
    time, §IV-B amortized), or the padded-CSR sparse layout (§IV-A),
  * per-arm block-statistics priors (running mean/variance of the corpus
    rows' block values) used to warm-start RaceState confidence intervals,
  * a tombstone ``alive`` mask so deletes are O(1) and inserts reuse free
    slots — dead slots enter every race pre-rejected (mutable.py).

Arrays are capacity-padded (slots ≥ live points) so that mutation does not
change traced shapes until a genuine growth, keeping the jitted batched-race
executable warm across inserts.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BMOConfig

KINDS = ("dense", "rotated", "sparse")


@dataclasses.dataclass
class IndexStore:
    kind: str                         # dense | rotated | sparse
    cfg: BMOConfig                    # racing defaults bound at build time
    d: int                            # true dimension (θ normalizer)
    alive: jax.Array                  # (cap,) bool — tombstone mask
    # --- dense / rotated layout ---
    x: Optional[jax.Array] = None     # (cap, d_pad) float32, blocked layout
    block: int = 128
    signs: Optional[jax.Array] = None # (d_pad,) ±1 — cached §IV-B rotation
    # --- sparse (padded-CSR) layout ---
    indices: Optional[jax.Array] = None  # (cap, m) int32, sorted, pad = d
    values: Optional[jax.Array] = None   # (cap, m) float32
    nnz: Optional[jax.Array] = None      # (cap,) int32
    # --- block-statistics priors (builder.py) ---
    prior_var: Optional[jax.Array] = None  # (cap,) per-arm block-value variance
    prior_weight: float = 4.0              # pseudo-observations for warm-start

    @property
    def capacity(self) -> int:
        return int(self.alive.shape[0])

    @property
    def n_live(self) -> int:
        # cached per instance: this sits on the per-decode-step serving path
        # (index_knn's k guard) and a device sync per call would serialize
        # host and device. Mutations build new instances (dataclasses.replace)
        # so the cache invalidates itself.
        if "_n_live" not in self.__dict__:
            self._n_live = int(jnp.sum(self.alive))
        return self._n_live

    @property
    def d_pad(self) -> int:
        assert self.x is not None
        return self.x.shape[1]

    @property
    def n_blocks(self) -> int:
        return self.d_pad // self.block

    @property
    def m(self) -> int:
        assert self.indices is not None
        return self.indices.shape[1]

    # -- query-side preprocessing ------------------------------------------

    def prepare_queries(self, queries) -> jax.Array:
        """Dense/rotated: pad (and rotate, using the *cached* signs) a (Q, d)
        query batch into the store's (Q, d_pad) layout."""
        assert self.kind in ("dense", "rotated")
        qs = jnp.asarray(queries, jnp.float32)
        pad = self.d_pad - qs.shape[-1]
        if pad:
            qs = jnp.pad(qs, [(0, 0)] * (qs.ndim - 1) + [(0, pad)])
        if self.kind == "rotated":
            from repro.kernels import ops as kops
            qs = kops.fwht(qs * self.signs[None, :])
        return qs

    def query(self, queries, rng: jax.Array, *, k: Optional[int] = None,
              impl: str = "auto"):
        """Batched k-NN of (Q, d) dense queries — or a (q_idx, q_val, q_nnz)
        padded triplet for the sparse box — against the live corpus.
        Returns an index.batched_race.BatchedKNNResult with slot indices."""
        from repro.index import batched_race
        return batched_race.index_knn(self, queries, rng, k=k, impl=impl)

    # -- (de)serialization --------------------------------------------------

    def arrays(self) -> dict:
        """The array pytree that checkpoint/manager.py persists."""
        out = {"alive": self.alive}
        for name in ("x", "signs", "indices", "values", "nnz", "prior_var"):
            arr = getattr(self, name)
            if arr is not None:
                out[name] = arr
        return out

    def meta(self) -> dict:
        return {
            "kind": self.kind,
            "d": self.d,
            "block": self.block,
            "prior_weight": float(self.prior_weight),
            "cfg": dataclasses.asdict(self.cfg),
        }

    @classmethod
    def from_arrays(cls, arrays: dict, meta: dict) -> "IndexStore":
        cfg = BMOConfig(**meta["cfg"])
        return cls(
            kind=meta["kind"], cfg=cfg, d=int(meta["d"]),
            alive=jnp.asarray(arrays["alive"], bool),
            x=_opt(arrays, "x", jnp.float32),
            block=int(meta["block"]),
            signs=_opt(arrays, "signs", jnp.float32),
            indices=_opt(arrays, "indices", jnp.int32),
            values=_opt(arrays, "values", jnp.float32),
            nnz=_opt(arrays, "nnz", jnp.int32),
            prior_var=_opt(arrays, "prior_var", jnp.float32),
            prior_weight=float(meta.get("prior_weight", 4.0)),
        )


def _opt(arrays: dict, name: str, dtype):
    return jnp.asarray(arrays[name], dtype) if name in arrays else None


def free_slots(store: IndexStore) -> np.ndarray:
    """Host-side list of dead slot ids (insert targets), ascending."""
    return np.nonzero(~np.asarray(store.alive))[0]
