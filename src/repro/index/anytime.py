"""Epoch-granular resumable races — the anytime engine under the request
plane (DESIGN.md §7.1).

The bandit race is naturally an *anytime* algorithm: at every epoch boundary
each query holds a partial top-k with per-arm confidence intervals. The
blocking drivers (``batched_race.py``, ``sharded.py``) run that loop to full
certification inside one call; this module re-exposes the SAME loop as a
``RaceSession`` the scheduler can drive one epoch at a time:

    sess = make_session(store, queries, rng, cfg=cfg)
    while sess.step():
        partial = sess.snapshot          # host-side anytime view
        ...                              # serve it, check deadlines, retire

Correctness of the partial view (the *certified-prefix* contract, tested):

  * After every epoch the ≤ k **accepted** arms of each query are lazily
    exact-evaluated in place (mean ← exact θ, CI ← 0; Welford pool stats
    untouched so the survivor-pooled CI variance is unchanged). Accepted
    arms are never pulled again, so this is a one-time O(k·d) cost per
    query, the same O(d) term the paper's bound already pays — and the
    sharded merge already required it (DESIGN.md §5.3).
  * ``snapshot.acc_count`` leading entries are accepted arms sorted by
    exact θ. An entry is *order-certified* at position i iff its exact θ is
    below the minimum LCB over every remaining candidate
    (``snapshot.cand_lcb_min``): w.h.p. 1 − δ no candidate — and hence no
    later-accepted arm — can end below it, so the certified prefix of any
    partial answer equals the full-certification answer's prefix.
  * A ``done`` query's accepted set IS its certificate (the acceptance rule
    already beat every candidate), so its ``cand_lcb_min`` is +inf and the
    whole prefix certifies.

Sessions exist for all four store boxes: single-shard dense/rotated (the
epoch-fused frontier driver), single-shard sparse (the per-round driver in
bounded-round chunks), and their sharded twins (shard-local state stepped
under ``shard_map``, merged on host per snapshot).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import BMOConfig
from repro.obs import get_obs, new_trace_id
from repro.obs import profile as obs_profile
from repro.core import confidence as conf
from repro.core.ucb import INF
from repro.utils.hostsync import host_fetch
from repro.index.batched_race import (BatchedRaceState, RoundsRaceFns,
                                      _dense_exact_theta, _frontier_ci,
                                      _fused_epoch_step, _fused_init,
                                      make_sparse_rounds_race)
from repro.index.frontier import (FrontierState, bucket_width,
                                  compact_frontier, floor_width, pow2_floor)
from repro.index.sharded import (AXIS, _ST_SPEC, ShardedIndexStore,
                                 _compact_stacked, _fused_init_fn,
                                 _fused_step_fn, _shard_delta, _squeeze,
                                 _unsqueeze)

_BIG = 1e9


class RaceSummary(NamedTuple):
    """Device-side anytime view of one race batch, refreshed per epoch."""
    ids: jax.Array          # (Q, k) slot ids, accepted-first then best cands
    values: jax.Array       # (Q, k) exact θ for accepted, estimates after
    ci: jax.Array           # (Q, k) CI half-widths (0 where exact)
    acc_count: jax.Array    # (Q,) leading accepted (certification-ready)
    cand_lcb_min: jax.Array  # (Q,) min LCB over remaining candidates
    done: jax.Array         # (Q,) race finished (k certified / exhausted)
    coord_ops: jax.Array    # (Q,)
    rounds: jax.Array       # (Q,)
    n_exact: jax.Array      # (Q,)


class Partial(NamedTuple):
    """Host-side (numpy) RaceSummary — sharded sessions merge S of them."""
    ids: np.ndarray
    values: np.ndarray
    ci: np.ndarray
    acc_count: np.ndarray
    cand_lcb_min: np.ndarray
    done: np.ndarray
    coord_ops: np.ndarray
    rounds: np.ndarray
    n_exact: np.ndarray


def _to_host(summ: RaceSummary) -> Partial:
    # THE per-epoch device->host boundary: one deliberate fetch of the
    # whole summary; everything downstream is host-resident numpy.
    return Partial(*host_fetch(tuple(summ)))


def _summarize(ids, mean, ci, exact, accepted, rejected, valid, done,
               coord_ops, rounds, n_exact, k: int) -> RaceSummary:
    """Rank the race state into the anytime view: accepted arms first
    (ascending exact θ), then the best candidates by current estimate.
    Junk picks (a query with < k rankable entries) surface as +inf values
    so downstream merges drop them."""
    acc = accepted & valid
    cand = valid & ~accepted & ~rejected
    score = jnp.where(acc, mean - _BIG, jnp.where(cand, mean, INF))
    _, pos = jax.lax.top_k(-score, k)
    take = lambda a: jnp.take_along_axis(a, pos, axis=1)
    picked = take(score)
    out_vals = jnp.where(picked == INF, INF, take(mean))
    out_ci = jnp.where(take(exact) | (picked == INF), 0.0, take(ci))
    # the − BIG class offset exceeds f32 resolution, so accepted picks tie
    # on score and arrive in arbitrary order — re-sort them by exact θ
    # (stable, so the candidate tail keeps its ascending-estimate order)
    order = jnp.argsort(jnp.where(take(acc), out_vals, INF), axis=1)
    reorder = lambda a: jnp.take_along_axis(a, order, axis=1)
    pos = reorder(pos)
    out_vals, out_ci = reorder(out_vals), reorder(out_ci)
    take = lambda a: jnp.take_along_axis(a, pos, axis=1)
    cand_min = jnp.min(jnp.where(cand, mean - ci, INF), axis=1)
    return RaceSummary(
        ids=take(ids),
        values=out_vals,
        ci=out_ci,
        acc_count=jnp.minimum(jnp.sum(acc, 1), k).astype(jnp.int32),
        cand_lcb_min=jnp.where(done, INF, cand_min),
        done=done,
        coord_ops=coord_ops,
        rounds=rounds,
        n_exact=n_exact,
    )


def _exactify_frontier(x, qs, st: FrontierState, *, k: int, metric: str,
                       d: int) -> FrontierState:
    """Exact-evaluate the ≤ k accepted arms that still carry estimates.
    Means and the ``exact`` flag change; Welford count/m2 stay, so the
    survivor-pooled CI variance — and hence every pending accept/reject
    decision's radius — is untouched."""
    Q = st.mean.shape[0]
    qi = jnp.arange(Q)[:, None]
    acc = st.accepted & st.valid
    sel_score = jnp.where(acc & ~st.exact, st.mean, INF)
    _, pos = jax.lax.top_k(-sel_score, k)
    need = jnp.take_along_axis(acc & ~st.exact, pos, axis=1)
    slots = jnp.where(need, jnp.take_along_axis(st.ids, pos, axis=1), 0)
    vals = jax.lax.cond(
        jnp.any(need),
        lambda s: _dense_exact_theta(x, qs, s, metric, d),
        lambda s: jnp.zeros(s.shape, jnp.float32), slots)
    cur = jnp.take_along_axis(st.mean, pos, axis=1)
    mean = st.mean.at[qi, pos].set(jnp.where(need, vals, cur))
    exact = st.exact.at[qi, pos].set(
        jnp.take_along_axis(st.exact, pos, axis=1) | need)
    return st._replace(
        mean=mean, exact=exact,
        coord_ops=st.coord_ops + jnp.sum(need, 1) * float(d),
        n_exact=st.n_exact + jnp.sum(need, 1, dtype=jnp.int32))


def _rounds_partial(fns: RoundsRaceFns, st: BatchedRaceState, k: int,
                    gid_base=0):
    """Exactify accepted arms of the per-round driver's state (via the
    box's own exact_fn, at its honest coordinate cost) and summarize."""
    Q, n = st.mean.shape
    qi = jnp.arange(Q)[:, None]
    acc = st.accepted
    sel_score = jnp.where(acc & ~st.exact, st.mean, INF)
    _, pos = jax.lax.top_k(-sel_score, k)
    need = jnp.take_along_axis(acc & ~st.exact, pos, axis=1)
    vals = jax.lax.cond(
        jnp.any(need), fns.exact_fn,
        lambda s: jnp.zeros(s.shape, jnp.float32), pos)
    cur = jnp.take_along_axis(st.mean, pos, axis=1)
    mean = st.mean.at[qi, pos].set(jnp.where(need, vals, cur))
    exact = st.exact.at[qi, pos].set(
        jnp.take_along_axis(st.exact, pos, axis=1) | need)
    coord_ops = st.coord_ops + jnp.sum(
        need * jnp.take_along_axis(fns.exact_cost, pos, axis=1), 1)
    st = st._replace(mean=mean, exact=exact, coord_ops=coord_ops)
    ci = fns.ci_radius(st)
    ids = gid_base + jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.int32)[None], (Q, n))
    valid = jnp.ones((Q, n), bool)
    summ = _summarize(ids, st.mean, ci, st.exact, st.accepted, st.rejected,
                      valid, st.done, st.coord_ops, st.rounds,
                      jnp.sum(st.exact, 1), k)
    return st, summ


# ---------------------------------------------------------------------------
# single-shard jitted entry points
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "d", "log_term",
                                             "prior_weight"))
def _fused_partial(x, qs, st: FrontierState, prior_pool, *, cfg: BMOConfig,
                   d: int, log_term: float, prior_weight: float):
    st = _exactify_frontier(x, qs, st, k=cfg.k, metric=cfg.metric, d=d)
    ci = _frontier_ci(st, cfg, log_term, prior_pool, prior_weight)
    summ = _summarize(st.ids, st.mean, ci, st.exact, st.accepted,
                      st.rejected, st.valid, st.done, st.coord_ops,
                      st.rounds, st.n_exact, cfg.k)
    return st, summ


@functools.partial(jax.jit, static_argnames=("cfg", "d", "eliminate",
                                             "prior_weight"))
def _sparse_sess_init(indices, values, nnz, alive, prior, q_idx, q_val,
                      q_nnz, rng, *, cfg: BMOConfig, d: int, eliminate: bool,
                      prior_weight: float):
    fns = make_sparse_rounds_race(
        indices, values, nnz, alive, prior, q_idx, q_val, q_nnz, cfg=cfg,
        d=d, eliminate=eliminate, prior_weight=prior_weight)
    return _rounds_partial(fns, fns.init(rng), cfg.k)


@functools.partial(jax.jit, static_argnames=("cfg", "d", "eliminate",
                                             "prior_weight", "rounds"))
def _sparse_sess_chunk(indices, values, nnz, alive, prior, q_idx, q_val,
                       q_nnz, st: BatchedRaceState, *, cfg: BMOConfig,
                       d: int, eliminate: bool, prior_weight: float,
                       rounds: int):
    fns = make_sparse_rounds_race(
        indices, values, nnz, alive, prior, q_idx, q_val, q_nnz, cfg=cfg,
        d=d, eliminate=eliminate, prior_weight=prior_weight)
    limit = st.round_no + rounds
    st = jax.lax.while_loop(
        lambda s: fns.active(s) & (s.round_no < limit), fns.body, st)
    return _rounds_partial(fns, st, cfg.k)


def _force_done(st, mask):
    """Freeze rows (plane retire): drivers never pull / mutate done rows."""
    done = st.done
    mask = jnp.asarray(mask)
    if done.ndim == mask.ndim + 1:          # (S, Q) sharded-stacked state
        mask = mask[None]
    return st._replace(done=done | mask)


# ---------------------------------------------------------------------------
# sharded jitted entry points (shard-local bodies under shard_map)
# ---------------------------------------------------------------------------

_SUMM_SPEC = RaceSummary(*([P(AXIS)] * len(RaceSummary._fields)))
_BR_SPEC = BatchedRaceState(*([P(AXIS)] * len(BatchedRaceState._fields)))


@functools.lru_cache(maxsize=None)
def _sharded_fused_partial_fn(mesh, cfg, d, log_term, prior_weight, stride):
    def body(x, qs, st, pool):
        st = _squeeze(st)
        st = _exactify_frontier(x[0], qs, st, k=cfg.k, metric=cfg.metric,
                                d=d)
        ci = _frontier_ci(st, cfg, log_term, pool[0], prior_weight)
        gids = jax.lax.axis_index(AXIS) * stride + st.ids
        summ = _summarize(gids, st.mean, ci, st.exact, st.accepted,
                          st.rejected, st.valid, st.done, st.coord_ops,
                          st.rounds, st.n_exact, cfg.k)
        return (_unsqueeze(st),
                jax.tree_util.tree_map(lambda a: a[None], summ))

    return jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P(AXIS), P(), _ST_SPEC, P(AXIS)),
        out_specs=(_ST_SPEC, _SUMM_SPEC), check_vma=False))


@functools.lru_cache(maxsize=None)
def _sharded_sparse_init_fn(mesh, cfg, d, eliminate, prior_weight, stride):
    def body(idx, val, nnz, alive, prior, qi, qv, qn, rng):
        fns = make_sparse_rounds_race(
            idx[0], val[0], nnz[0], alive[0], prior[0], qi, qv, qn, cfg=cfg,
            d=d, eliminate=eliminate, prior_weight=prior_weight)
        rng = jax.random.fold_in(rng, jax.lax.axis_index(AXIS))
        st, summ = _rounds_partial(
            fns, fns.init(rng), cfg.k,
            gid_base=jax.lax.axis_index(AXIS) * stride)
        return (_unsqueeze(st),
                jax.tree_util.tree_map(lambda a: a[None], summ))

    return jax.jit(jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS),
                  P(), P(), P(), P()),
        out_specs=(_BR_SPEC, _SUMM_SPEC), check_vma=False))


@functools.lru_cache(maxsize=None)
def _sharded_sparse_chunk_fn(mesh, cfg, d, eliminate, prior_weight, stride,
                             rounds):
    def body(idx, val, nnz, alive, prior, qi, qv, qn, st):
        fns = make_sparse_rounds_race(
            idx[0], val[0], nnz[0], alive[0], prior[0], qi, qv, qn, cfg=cfg,
            d=d, eliminate=eliminate, prior_weight=prior_weight)
        st = _squeeze(st)
        limit = st.round_no + rounds
        st = jax.lax.while_loop(
            lambda s: fns.active(s) & (s.round_no < limit), fns.body, st)
        st, summ = _rounds_partial(
            fns, st, cfg.k, gid_base=jax.lax.axis_index(AXIS) * stride)
        return (_unsqueeze(st),
                jax.tree_util.tree_map(lambda a: a[None], summ))

    return jax.jit(jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS),
                  P(), P(), P(), _BR_SPEC),
        out_specs=(_BR_SPEC, _SUMM_SPEC), check_vma=False))


def _merge_shard_partials(p: Partial) -> Partial:
    """Merge S per-shard partial views into one global view (host-side;
    Q and k are serving-small). Accepted entries — already exact — are
    merged by (θ, gid); the best-effort tail interleaves the shards'
    candidate estimates."""
    S, Q, k = p.ids.shape
    ids = np.full((Q, k), -1, np.int64)
    vals = np.full((Q, k), np.inf, np.float32)
    ci = np.zeros((Q, k), np.float32)
    acc_count = np.zeros((Q,), np.int32)
    for q in range(Q):
        accepted, cands = [], []
        for s in range(S):
            a = int(p.acc_count[s, q])
            for i in range(k):
                # host-sync: p is the host-side per-shard Partial
                v = float(p.values[s, q, i])
                if not np.isfinite(v):
                    continue
                entry = (v, int(p.ids[s, q, i]),
                         float(p.ci[s, q, i]))  # host-sync: host Partial
                (accepted if i < a else cands).append(entry)
        accepted.sort(key=lambda e: (e[0], e[1]))
        cands.sort(key=lambda e: (e[0], e[1]))
        merged = (accepted + cands)[:k]
        for i, (v, g, c) in enumerate(merged):
            vals[q, i], ids[q, i], ci[q, i] = v, g, c
        acc_count[q] = min(len(accepted), k)
    return Partial(
        ids=ids, values=vals, ci=ci, acc_count=acc_count,
        cand_lcb_min=np.min(p.cand_lcb_min, axis=0),
        done=np.all(p.done, axis=0),
        coord_ops=np.sum(p.coord_ops, axis=0),
        rounds=np.max(p.rounds, axis=0),
        n_exact=np.sum(p.n_exact, axis=0),
    )


# ---------------------------------------------------------------------------
# sessions
# ---------------------------------------------------------------------------


class RaceSession:
    """One resumable race batch. ``step()`` advances one epoch and refreshes
    ``snapshot``; ``retire(mask)`` freezes rows whose ticket left the plane
    (deadline/budget) so the remaining rows get their pull budget.

    The base ``step()`` owns the epoch boundary: it times the concrete
    driver's ``_step_impl()``, then records — entirely host-side, from the
    snapshot arrays the drivers already transferred — the epoch's pull /
    coord-op deltas, frontier width, survivors, the CI radius of the worst
    uncertified position, and (sharded) the per-shard straggler split, as a
    ``race.epoch`` span under the session's ``sid`` trace id plus registry
    metrics (DESIGN.md §8.3). Jitted code is untouched.
    """

    kind = "base"
    kernel = "fused_epoch_pull"   # device kernel this box's epochs launch

    def __init__(self, Q: int, k: int, *, obs=None, sid: Optional[str] = None):
        self.Q = Q
        self.k = k
        self.epochs = 0
        self.obs = obs if obs is not None else get_obs()
        self.sid = sid if sid is not None else new_trace_id("s")
        self.last_epoch: Optional[dict] = None
        self.shard_coord_ops: Optional[np.ndarray] = None
        self.shard_rounds: Optional[np.ndarray] = None
        self._snap: Optional[Partial] = None
        self._retired = np.zeros((Q,), bool)
        self._prev_coord_ops: Optional[float] = None
        self._prev_rounds = 0
        self._prev_shard_coord_ops: Optional[np.ndarray] = None
        self._prev_shard_rounds: Optional[np.ndarray] = None
        self._deadline_t: Optional[float] = None
        self._round_ms = 0.0

    def set_deadline(self, deadline_ms: Optional[float],
                     round_ms: Optional[float] = None) -> None:
        """Deadline-aware fused-round selection (DESIGN.md §9.7): with a
        wall-clock budget and a measured per-round cost estimate (the
        tuned config's ``round_ms``), the fused drivers cap the rounds
        fused into the NEXT launch so one epoch never overshoots the
        deadline — the plane harvests a certified prefix at the boundary
        instead of blocking an extra launch past expiry."""
        self._deadline_t = (None if deadline_ms is None
                            else time.perf_counter() + deadline_ms / 1e3)
        self._round_ms = float(round_ms or 0.0)

    def _deadline_R(self, R: int) -> int:
        """Cap the adaptive R by the rounds the remaining wall budget can
        pay for, quantized DOWN the warm R0·2^j chain — an off-chain R is
        a fresh T specialization whose XLA compile costs far more wall
        time than the rounds it would save."""
        if self._deadline_t is None or self._round_ms <= 0.0:
            return R
        left_ms = (self._deadline_t - time.perf_counter()) * 1e3
        cap = int(left_ms / self._round_ms)
        R0 = getattr(self, "_R0", 1)
        if cap <= R0:
            return min(R, R0)     # never below the chain's smallest rung
        return min(R, R0 * pow2_floor(cap // R0))

    @property
    def snapshot(self) -> Partial:
        return self._snap

    @property
    def done(self) -> np.ndarray:
        # host-sync: _snap crossed at the _to_host boundary (numpy)
        return np.asarray(self._snap.done) | self._retired

    @property
    def exhausted(self) -> bool:
        """Round cap hit with rows unresolved — the driver's safety net."""
        return not self.done.all() and self._rounds_spent >= self._max_rounds

    def retire(self, mask: np.ndarray) -> None:
        mask = np.asarray(mask, bool)  # host-sync: caller-side numpy mask
        self._retired |= mask
        self._apply_force_done(jnp.asarray(self._retired))

    def step(self) -> bool:
        if self.done.all() or self._rounds_spent >= self._max_rounds:
            return False
        if self._prev_coord_ops is None:     # baseline excludes init pulls
            # host-sync: _snap/shard stats are post-boundary numpy
            self._prev_coord_ops = float(np.sum(self._snap.coord_ops))
            self._prev_rounds = int(np.max(self._snap.rounds, initial=0))
            if self.shard_coord_ops is not None:
                # host-sync: post-boundary numpy
                self._prev_shard_coord_ops = np.array(self.shard_coord_ops,
                                                      float)
                # host-sync: post-boundary numpy
                self._prev_shard_rounds = np.array(self.shard_rounds, float)
        t0 = time.perf_counter()
        with obs_profile.annotate(f"repro.race.epoch.{self.kind}"):
            alive = self._step_impl()
        self._record_epoch(t0, time.perf_counter() - t0)
        return alive

    def _record_epoch(self, t0: float, dur: float) -> None:
        snap = self._snap  # host-sync: numpy snapshot, whole method is host math
        coord = float(np.sum(snap.coord_ops))  # host-sync: numpy
        rounds = int(np.max(snap.rounds, initial=0))
        d_coord = max(coord - self._prev_coord_ops, 0.0)
        d_rounds = max(rounds - self._prev_rounds, 0)
        self._prev_coord_ops, self._prev_rounds = coord, rounds
        finite_ci = np.where(np.isfinite(snap.ci), snap.ci, 0.0)
        info = {
            "epoch": self.epochs,
            "kind": self.kind,
            "coord_ops": d_coord,
            "rounds": d_rounds,
            "worst_ci": float(finite_ci.max(initial=0.0)),  # host-sync: numpy
            "active": int(np.sum(~self.done)),
            "done": int(np.sum(self.done)),
        }
        info.update(self._epoch_extra())
        if self.shard_coord_ops is not None:
            cur_c = np.asarray(self.shard_coord_ops, float)  # host-sync: numpy
            cur_r = np.asarray(self.shard_rounds, float)  # host-sync: numpy
            prev_c = (self._prev_shard_coord_ops
                      if self._prev_shard_coord_ops is not None
                      else np.zeros_like(cur_c))
            prev_r = (self._prev_shard_rounds
                      if self._prev_shard_rounds is not None
                      else np.zeros_like(cur_r))
            info["shard_coord_ops"] = [float(v)  # host-sync: numpy
                                       for v in cur_c - prev_c]
            info["shard_rounds"] = [float(v)  # host-sync: numpy
                                    for v in cur_r - prev_r]
            self._prev_shard_coord_ops = cur_c
            self._prev_shard_rounds = cur_r
        self.last_epoch = info
        reg = self.obs.registry
        reg.counter("repro_race_epochs_total",
                    "race epochs stepped", kind=self.kind).inc()
        reg.counter("repro_race_coord_ops_total",
                    "coordinate reads paid by race epochs",
                    kind=self.kind).inc(d_coord)
        reg.histogram("repro_race_epoch_ms",
                      "wall time of one race epoch (ms)",
                      kind=self.kind).observe(dur * 1e3)
        obs_profile.record_kernel_launch(
            self.obs, self.kernel,
            launches=self._epoch_launches(d_rounds),
            coord_ops=d_coord, pulls=float(d_rounds))  # host-sync: python int
        self.obs.tracer.complete("race.epoch", t0, dur, trace=self.sid,
                                 dur_ms=dur * 1e3, **info)

    def _epoch_extra(self) -> dict:
        """Per-box epoch attributes (frontier width, survivors, R)."""
        return {}

    def _epoch_launches(self, d_rounds: int) -> int:
        """Device programs this epoch issued (per-launch accounting)."""
        return 1

    def _step_impl(self) -> bool:
        raise NotImplementedError

    def _apply_force_done(self, mask) -> None:
        raise NotImplementedError


class FusedSession(RaceSession):
    """Single-shard dense/rotated: the §4 epoch-fused survivor-compacted
    driver, host loop exposed one epoch at a time (same compaction schedule
    and adaptive-R rule as the blocking ``fused_race_topk``)."""

    kind = "fused"

    def __init__(self, store, queries, rng, *, cfg: BMOConfig,
                 impl: str = "auto", eliminate: bool = True,
                 prior=None, prior_weight: float = 0.0,
                 obs=None, sid: Optional[str] = None):
        x, qs = store.x, store.prepare_queries(queries)
        n = x.shape[0]
        super().__init__(qs.shape[0], cfg.k, obs=obs, sid=sid)
        nb = x.shape[1] // store.block
        B0 = min(cfg.batch_arms, n)
        P_ = cfg.pulls_per_round
        self._cfg, self._x, self._qs = cfg, x, qs
        self._block, self._d, self._impl = store.block, store.d, impl
        self._eliminate, self._prior_weight = eliminate, prior_weight
        self._log_term = float(
            np.log(2.0 / conf.delta_prime(cfg.delta, n, nb)))
        self._max_rounds = cfg.max_rounds or int(
            2 * math.ceil(n * nb / max(B0 * P_, 1)) + n + 16)
        self._R0 = max(cfg.epoch_rounds, 1)
        self._R_cap = max(1, -(-nb // P_))
        self._floor_w = floor_width(cfg, n, B0=B0)
        prior = store.prior_var if prior is None else jnp.asarray(
            prior, jnp.float32)
        st, self._pool = _fused_init(
            x, qs, store.alive, prior, rng, cfg=cfg, block=store.block,
            impl=impl, prior_weight=prior_weight)
        self._W0 = st.width
        self._rounds_spent = 0
        self._last_R = 0
        self._n_surv = np.full((self.Q,), n)
        self._refresh(st)

    def _refresh(self, st) -> None:
        self._st, summ = _fused_partial(
            self._x, self._qs, st, self._pool, cfg=self._cfg, d=self._d,
            log_term=self._log_term, prior_weight=self._prior_weight)
        self._snap = _to_host(summ)

    def _apply_force_done(self, mask) -> None:
        self._st = _force_done(self._st, mask)
        self._n_surv = np.where(np.asarray(self._retired), 0, self._n_surv)

    def _epoch_extra(self) -> dict:
        return {"width": int(self._st.width),
                "n_surv": int(self._n_surv.max(initial=0)),
                "R": self._last_R}

    def _step_impl(self) -> bool:
        need = int(self._n_surv[~self.done].max(initial=1))
        # halve the buffer at most once per epoch (unlike the blocking
        # driver's jump-to-cover): every session then walks the SAME
        # descending width chain, so one warm full-certification race
        # pre-compiles every (Q, W) specialization a serving race can hit —
        # no mid-traffic XLA compiles on the request plane's hot path
        W_new = max(bucket_width(need, floor=self._floor_w,
                                 current=self._st.width),
                    self._st.width // 2)
        if W_new < self._st.width:
            self._st = compact_frontier(self._st, W_new=W_new)
        R = min(self._R0 * pow2_floor(self._W0 // max(need, 1)), self._R_cap)
        R = self._deadline_R(R)
        st, n_surv, _ = _fused_epoch_step(
            self._x, self._qs, self._st, self._pool, cfg=self._cfg,
            block=self._block, d=self._d, impl=self._impl,
            eliminate=self._eliminate, prior_weight=self._prior_weight,
            log_term=self._log_term, T=R * self._cfg.pulls_per_round)
        self._rounds_spent += R
        self._last_R = R
        self._n_surv = host_fetch(n_surv)
        self.epochs += 1
        self._refresh(st)
        return not self.done.all()


class SparseRoundsSession(RaceSession):
    """Single-shard sparse: the §3.2 per-round driver in bounded-round
    chunks (one chunk = one scheduler epoch)."""

    kind = "sparse"
    kernel = "block_pull_multi"

    def __init__(self, store, queries, rng, *, cfg: BMOConfig,
                 eliminate: bool = True, prior=None,
                 prior_weight: float = 0.0, chunk_rounds: int = 0,
                 obs=None, sid: Optional[str] = None):
        q_idx, q_val, q_nnz = (jnp.asarray(a) for a in queries)
        super().__init__(q_idx.shape[0], cfg.k, obs=obs, sid=sid)
        self._args = (store.indices, store.values, store.nnz, store.alive,
                      store.prior_var if prior is None
                      else jnp.asarray(prior, jnp.float32),
                      q_idx, q_val, q_nnz)
        self._cfg, self._d = cfg, store.d
        self._eliminate, self._prior_weight = eliminate, prior_weight
        self._chunk = chunk_rounds or 2 * max(cfg.epoch_rounds, 1)
        n, m = store.indices.shape
        B0 = min(cfg.batch_arms, n)
        mp = int(m + q_idx.shape[1])
        self._max_rounds = cfg.max_rounds or int(
            2 * math.ceil(n * mp / max(B0 * cfg.pulls_per_round, 1)) + n + 16)
        self._rounds_spent = 0
        self._st, summ = _sparse_sess_init(
            *self._args, rng, cfg=cfg, d=store.d, eliminate=eliminate,
            prior_weight=prior_weight)
        self._snap = _to_host(summ)

    def _apply_force_done(self, mask) -> None:
        self._st = _force_done(self._st, mask)

    def _epoch_extra(self) -> dict:
        return {"R": self._chunk}

    def _epoch_launches(self, d_rounds: int) -> int:
        # the chunked while-loop issues one block_pull_multi per round
        return max(int(d_rounds), 1)

    def _step_impl(self) -> bool:
        self._st, summ = _sparse_sess_chunk(
            *self._args, self._st, cfg=self._cfg, d=self._d,
            eliminate=self._eliminate, prior_weight=self._prior_weight,
            rounds=self._chunk)
        self._rounds_spent += self._chunk
        self._snap = _to_host(summ)
        self.epochs += 1
        return not self.done.all()


class ShardedFusedSession(RaceSession):
    """Sharded dense/rotated: the §5.2 shard-local fused race with the
    shared host epoch loop — including the cross-shard pull-budget
    reallocator — stepped one epoch at a time; snapshots merge the
    shards' certified/accepted frontiers on host."""

    kind = "sharded_fused"

    def __init__(self, store: ShardedIndexStore, queries, rng, *,
                 cfg: BMOConfig, impl: str = "auto", eliminate: bool = True,
                 prior_st=None, prior_weight: float = 0.0,
                 obs=None, sid: Optional[str] = None):
        qs = store.prepare_queries(queries)
        super().__init__(qs.shape[0], cfg.k, obs=obs, sid=sid)
        self._store, self._qs, self._cfg = store, qs, cfg
        self._S, self._stride, self._mesh = (store.n_shards, store.stride,
                                             store.mesh)
        dev = store.device_arrays()
        self._x_st, alive_st = dev["x"], dev["alive"]
        if prior_st is None:
            prior_st = dev["prior_var"]
        self._impl, self._eliminate = impl, eliminate
        self._prior_weight = prior_weight
        nb = self._x_st.shape[2] // store.block
        P_ = cfg.pulls_per_round
        self._log_term = float(np.log(
            2.0 / conf.delta_prime(cfg.delta, self._S * self._stride, nb)))
        B0 = min(cfg.batch_arms, self._stride)
        self._R0 = max(cfg.epoch_rounds, 1)
        self._R_cap = max(1, -(-nb // P_))
        self._floor_w = floor_width(cfg, self._stride, B0=B0)
        self._max_rounds = cfg.max_rounds or int(
            2 * math.ceil(self._stride * nb / max(B0 * P_, 1))
            + self._stride + 16)
        st, self._pool = _fused_init_fn(
            self._mesh, cfg, store.block, impl, prior_weight)(
            self._x_st, qs, alive_st, prior_st, rng)
        self._W0 = st.ids.shape[2]
        self._rounds_spent = 0
        self._last_R = 0
        self._n_surv = np.full((self._S, self.Q), self._stride)
        self._refresh(st)

    def _refresh(self, st) -> None:
        self._st, summ = _sharded_fused_partial_fn(
            self._mesh, self._cfg, self._store.d, self._log_term,
            self._prior_weight, self._stride)(
            self._x_st, self._qs, st, self._pool)
        per_shard = Partial(*host_fetch(tuple(summ)))
        self.shard_coord_ops = per_shard.coord_ops.sum(axis=1)
        self.shard_rounds = per_shard.rounds.max(axis=1)
        self._snap = _merge_shard_partials(per_shard)

    def _apply_force_done(self, mask) -> None:
        self._st = _force_done(self._st, mask)
        self._n_surv = np.where(np.asarray(self._retired)[None], 0,
                                self._n_surv)

    def _epoch_extra(self) -> dict:
        return {"width": int(self._st.ids.shape[2]),
                "n_surv": int(self._n_surv.max(initial=0)),
                "R": self._last_R, "shards": self._S}

    def _epoch_launches(self, d_rounds: int) -> int:
        return self._S      # one shard-local program per mesh device

    def _step_impl(self) -> bool:
        active_q = ~self.done
        need = int(self._n_surv[:, active_q].max(initial=1))
        # at-most-halving schedule — see FusedSession.step
        W_new = max(bucket_width(need, floor=self._floor_w,
                                 current=self._st.ids.shape[2]),
                    self._st.ids.shape[2] // 2)
        if W_new < self._st.ids.shape[2]:
            self._st = _compact_stacked(self._st, W_new=W_new)
        total_need = int(
            np.sum(self._n_surv[:, active_q].max(axis=1, initial=0)))
        R = min(self._R0 * pow2_floor((self._S * self._W0)
                                      // max(total_need, 1)), self._R_cap)
        R = self._deadline_R(R)
        st, n_surv, _ = _fused_step_fn(
            self._mesh, self._cfg, self._store.block, self._store.d,
            self._impl, self._eliminate, self._prior_weight, self._log_term,
            R * self._cfg.pulls_per_round)(self._x_st, self._qs, self._st,
                                           self._pool)
        self._rounds_spent += R
        self._last_R = R
        self._n_surv = host_fetch(n_surv)
        self.epochs += 1
        self._refresh(st)
        return not self.done.all()


class ShardedSparseSession(RaceSession):
    """Sharded sparse: the per-round driver chunked shard-locally under
    ``shard_map`` (each chunk one collective program), merged per snapshot."""

    kind = "sharded_sparse"
    kernel = "block_pull_multi"

    def __init__(self, store: ShardedIndexStore, queries, rng, *,
                 cfg: BMOConfig, eliminate: bool = True, prior_st=None,
                 prior_weight: float = 0.0, chunk_rounds: int = 0,
                 obs=None, sid: Optional[str] = None):
        q_idx, q_val, q_nnz = (jnp.asarray(a) for a in queries)
        super().__init__(q_idx.shape[0], cfg.k, obs=obs, sid=sid)
        cfg = _shard_delta(cfg, store.n_shards)
        self._cfg, self._d = cfg, store.d
        self._S, self._stride, self._mesh = (store.n_shards, store.stride,
                                             store.mesh)
        dev = store.device_arrays()
        if prior_st is None:
            prior_st = dev["prior_var"]
        self._args = (dev["indices"], dev["values"], dev["nnz"],
                      dev["alive"], prior_st, q_idx, q_val, q_nnz)
        self._eliminate, self._prior_weight = eliminate, prior_weight
        self._chunk = chunk_rounds or 2 * max(cfg.epoch_rounds, 1)
        m = int(dev["indices"].shape[2])
        B0 = min(cfg.batch_arms, self._stride)
        mp = m + int(q_idx.shape[1])
        self._max_rounds = cfg.max_rounds or int(
            2 * math.ceil(self._stride * mp
                          / max(B0 * cfg.pulls_per_round, 1))
            + self._stride + 16)
        self._rounds_spent = 0
        st, summ = _sharded_sparse_init_fn(
            self._mesh, cfg, store.d, eliminate, prior_weight,
            self._stride)(*self._args, rng)
        self._st = st
        self._ingest(summ)

    def _ingest(self, summ) -> None:
        per_shard = Partial(*host_fetch(tuple(summ)))
        self.shard_coord_ops = per_shard.coord_ops.sum(axis=1)
        self.shard_rounds = per_shard.rounds.max(axis=1)
        self._snap = _merge_shard_partials(per_shard)

    def _apply_force_done(self, mask) -> None:
        self._st = _force_done(self._st, mask)

    def _epoch_extra(self) -> dict:
        return {"R": self._chunk, "shards": self._S}

    def _epoch_launches(self, d_rounds: int) -> int:
        return max(int(d_rounds), 1) * self._S

    def _step_impl(self) -> bool:
        self._st, summ = _sharded_sparse_chunk_fn(
            self._mesh, self._cfg, self._d, self._eliminate,
            self._prior_weight, self._stride, self._chunk)(
            *self._args, self._st)
        self._rounds_spent += self._chunk
        self.epochs += 1
        self._ingest(summ)
        return not self.done.all()


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------


def make_session(store, queries, rng, *, cfg: Optional[BMOConfig] = None,
                 impl: str = "auto", eliminate: bool = True,
                 warm_start: bool = True, prior_hint=None,
                 chunk_rounds: int = 0, obs=None,
                 sid: Optional[str] = None,
                 deadline_ms: Optional[float] = None,
                 round_ms: Optional[float] = None) -> RaceSession:
    """Build the right resumable session for ``store``'s box and layout —
    the anytime twin of ``index_knn`` (same priors, same δ accounting).
    ``obs``/``sid`` select the observability context and trace id the
    session records epoch spans under (default: process obs, fresh id).
    ``deadline_ms`` (wall budget) + ``round_ms`` (the tuned per-round cost
    estimate, ``repro.tune``) turn on deadline-aware fused-round selection
    — see ``RaceSession.set_deadline``."""
    cfg = cfg if cfg is not None else store.cfg
    if cfg.k > store.n_live:
        raise ValueError(
            f"k={cfg.k} exceeds the index's {store.n_live} live slots — "
            "tombstoned slots can never be returned")
    sharded = hasattr(store, "shards")
    w = store.prior_weight if (warm_start or prior_hint is not None) else 0.0
    if sharded:
        S, stride = store.n_shards, store.stride
        if prior_hint is not None:
            Q = (queries[0] if isinstance(queries, tuple)
                 else jnp.asarray(queries)).shape[0]
            prior_st = jnp.asarray(prior_hint, jnp.float32).reshape(
                Q, S, stride).transpose(1, 0, 2)
        else:
            prior_st = None
        if store.kind == "sparse":
            sess = ShardedSparseSession(
                store, queries, rng, cfg=cfg, eliminate=eliminate,
                prior_st=prior_st, prior_weight=w, chunk_rounds=chunk_rounds,
                obs=obs, sid=sid)
        else:
            sess = ShardedFusedSession(
                store, queries, rng, cfg=cfg, impl=impl, eliminate=eliminate,
                prior_st=prior_st, prior_weight=w, obs=obs, sid=sid)
    else:
        prior = None if prior_hint is None else jnp.asarray(prior_hint,
                                                            jnp.float32)
        if store.kind == "sparse":
            sess = SparseRoundsSession(
                store, queries, rng, cfg=cfg, eliminate=eliminate,
                prior=prior, prior_weight=w, chunk_rounds=chunk_rounds,
                obs=obs, sid=sid)
        else:
            sess = FusedSession(store, queries, rng, cfg=cfg, impl=impl,
                                eliminate=eliminate, prior=prior,
                                prior_weight=w, obs=obs, sid=sid)
    if deadline_ms is not None:
        sess.set_deadline(deadline_ms, round_ms)
    return sess
