"""Cross-query batched racing (DESIGN.md §3.2/§4) — the index-serving
drivers that replace per-query ``jax.lax.map`` over ``core.ucb.race_topk``.

Two drivers share this module:

``batched_race_topk`` (PR-1, DESIGN.md §3.2) races one ``(Q, B)`` arm
frontier with one ``block_pull_multi`` launch *per round*: wall-clock is the
MAX of per-query rounds instead of the SUM, but every round still pays one
launch plus O(Q·n) bookkeeping (CI radii, top-k selection, acceptance) even
late in the race when nearly every arm is rejected.

The *epoch-fused* driver (``fused_race_topk`` + ``index/frontier.py``,
DESIGN.md §4) restructures that loop into a two-level epoch loop: the inner
R pull-rounds are fused into one ``kernels/ops.fused_epoch_pull`` launch
(on-chip Welford, double-buffered corpus DMA), acceptance runs only at epoch
boundaries, and between epochs the still-candidate arms are gathered into
shrinking power-of-two buckets so bookkeeping scales with *survivors*
instead of n. It serves the dense/rotated boxes; the sparse box stays on the
per-round driver.

Correctness is the per-query algorithm's, unchanged: selection, Welford
updates, CI radii, and the Alg. 1 acceptance/rejection step
(``core.ucb.acceptance_step``) are applied per query via ``vmap``; the only
coupling across queries is the shared kernel launch. Warm-start priors from
the IndexStore enter through ``confidence.empirical_sigma_sq_prior`` —
variance estimates only, never CI sample counts.

Tombstoned (dead) slots enter the race pre-rejected (mutable.py): they are
never selected, never pulled, and can never be returned.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BMOConfig
from repro.core import confidence as conf
from repro.core.bmo_nn import KNNResult, sparse_exact_theta, sparse_pull_one
from repro.core.datasets import SparseDataset
from repro.core.ucb import (INF, acceptance_step, acceptance_step_masked,
                            topk_from_state, topk_from_state_masked)
from repro.obs import get_obs
from repro.obs import profile as obs_profile
from repro.utils.hostsync import host_fetch
from repro.index.frontier import (FrontierState, bucket_width,
                                  compact_frontier, floor_width, pow2_floor,
                                  survivors)
from repro.kernels import ops as kops


class BatchedRaceState(NamedTuple):
    mean: jax.Array        # (Q, n)
    count: jax.Array       # (Q, n)
    m2: jax.Array          # (Q, n)
    exact: jax.Array       # (Q, n) bool
    accepted: jax.Array    # (Q, n) bool
    rejected: jax.Array    # (Q, n) bool
    coord_ops: jax.Array   # (Q,)
    rounds: jax.Array      # (Q,) rounds spent while the query was active
    done: jax.Array        # (Q,) bool
    round_no: jax.Array    # () int32
    rng: jax.Array


class RoundsRaceFns(NamedTuple):
    """The per-round driver's pieces, exposed so callers can drive the race
    in bounded chunks (the anytime request plane, ``index/anytime.py``)
    instead of one run-to-certification ``while_loop``. All members are
    trace-compatible closures over the box's pull/exact functions."""
    init: Callable        # rng -> BatchedRaceState
    body: Callable        # state -> state (one racing round)
    active: Callable      # state -> bool (queries left AND round cap unhit)
    ci_radius: Callable   # state -> (Q, n) CI half-widths
    exact_fn: Callable    # (sel (Q, B)) -> (Q, B) exact θ
    exact_cost: jax.Array  # (Q, n) coordinate-op cost of an exact eval
    max_rounds: int


def make_rounds_race(
    pull_fn: Callable,          # (sel (Q, B), rng) -> (Q, B, P) samples
    exact_fn: Callable,         # (sel (Q, B)) -> (Q, B) exact θ
    n: int,
    Q: int,
    max_pulls,                  # scalar, (n,) or (Q, n)
    pull_cost: float,
    exact_cost,                 # scalar, (n,) or (Q, n)
    cfg: BMOConfig,
    *,
    eliminate: bool = True,
    dead: Optional[jax.Array] = None,       # (n,) bool tombstones
    prior_var: Optional[jax.Array] = None,  # (n,) warm-start variance prior
    prior_weight: float = 0.0,
    max_pulls_static: int = 0,
) -> RoundsRaceFns:
    k = cfg.k
    B = min(cfg.batch_arms, n)
    P = cfg.pulls_per_round
    max_pulls_arr = jnp.broadcast_to(
        jnp.asarray(max_pulls, jnp.float32), (Q, n))
    exact_cost_arr = jnp.broadcast_to(
        jnp.asarray(exact_cost, jnp.float32), (Q, n))
    max_pulls_hi = max_pulls_static or int(np.max(np.asarray(max_pulls)))
    log_term = float(np.log(2.0 / conf.delta_prime(cfg.delta, n, max_pulls_hi)))
    max_rounds = cfg.max_rounds or int(
        2 * math.ceil(n * max_pulls_hi / max(B * P, 1)) + n + 16)

    alive = jnp.ones((n,), bool) if dead is None else ~dead
    alive_f = alive.astype(jnp.float32)
    n_alive = jnp.sum(alive_f)
    if prior_var is None:
        prior_var = jnp.zeros((n,), jnp.float32)
        prior_weight = 0.0
    # priors may be per-arm (n,) — the build-time block statistics — or
    # per-query (Q, n) when the caller seeds them (near-repeat warm starts)
    prior2 = (jnp.broadcast_to(prior_var[None], (Q, n))
              if prior_var.ndim == 1 else prior_var)
    prior_pool = jnp.sum(prior2 * alive_f[None], 1) / jnp.maximum(n_alive, 1.0)
    qi = jnp.arange(Q)[:, None]

    def ci_radius(st: BatchedRaceState) -> jax.Array:
        if cfg.sigma is not None:
            sig_sq = jnp.full((Q, n), float(cfg.sigma) ** 2, jnp.float32)
        else:
            # per-query pooled variance, warm-started by the build-time prior
            num = jnp.sum(st.m2 * alive_f, 1) + prior_weight * prior_pool
            den = (jnp.sum(jnp.maximum(st.count - 1.0, 0.0) * alive_f, 1)
                   + prior_weight)
            global_var = num / jnp.maximum(den, 1.0)         # (Q,)
            sig_sq = conf.empirical_sigma_sq_prior(
                st.m2, st.count, 1e-12, global_var[:, None],
                prior2, prior_weight)
        c = conf.hoeffding_radius(sig_sq, st.count, log_term)
        return jnp.where(st.exact, 0.0, c)

    def init_state(rng):
        # wide init (paper App. D-A): every alive arm of every query gets
        # init_pulls samples, as reps of ONE (Q, n, P) launch
        n_init = max(cfg.init_pulls, 2)
        reps = max(1, n_init // P)
        mean = jnp.zeros((Q, n), jnp.float32)
        count = jnp.zeros((Q, n), jnp.float32)
        m2 = jnp.zeros((Q, n), jnp.float32)
        all_arms = jnp.broadcast_to(jnp.arange(n)[None], (Q, n))
        mask = jnp.broadcast_to(alive_f[None], (Q, n)).reshape(-1)

        def rep_body(carry, _):
            mean, count, m2, rng = carry
            rng, sub = jax.random.split(rng)
            vals = pull_fn(all_arms, sub)                    # (Q, n, P)
            nm, nc, n2 = conf.welford_batch_update(
                mean.reshape(-1), count.reshape(-1), m2.reshape(-1),
                vals.reshape(Q * n, P), mask)
            return (nm.reshape(Q, n), nc.reshape(Q, n), n2.reshape(Q, n),
                    rng), None

        (mean, count, m2, rng), _ = jax.lax.scan(
            rep_body, (mean, count, m2, rng), None, length=reps)
        return BatchedRaceState(
            mean=mean, count=count, m2=m2,
            exact=jnp.zeros((Q, n), bool),
            accepted=jnp.zeros((Q, n), bool),
            rejected=jnp.broadcast_to(~alive[None], (Q, n)),
            coord_ops=jnp.full((Q,), float(reps * P * pull_cost)) * n_alive,
            rounds=jnp.zeros((Q,), jnp.int32),
            done=jnp.zeros((Q,), bool),
            round_no=jnp.zeros((), jnp.int32),
            rng=rng,
        )

    def cond(st: BatchedRaceState):
        return (~jnp.all(st.done)) & (st.round_no < max_rounds)

    def body(st: BatchedRaceState):
        ci = ci_radius(st)
        lcb = st.mean - ci
        candidate = ~st.accepted & ~st.rejected
        need = candidate & ~st.exact & ~st.done[:, None]

        # ---- selection: per query, B lowest-LCB candidates ---------------
        sel_score = jnp.where(need, lcb, INF)
        _, sel = jax.lax.top_k(-sel_score, B)                # (Q, B)
        sel_valid = jnp.take_along_axis(need, sel, axis=1)   # (Q, B)

        rng, sub = jax.random.split(st.rng)
        vals = pull_fn(sel, sub)                             # (Q, B, P)
        cm, cc, c2 = st.mean[qi, sel], st.count[qi, sel], st.m2[qi, sel]
        nm, nc, n2 = conf.welford_batch_update(
            cm.reshape(-1), cc.reshape(-1), c2.reshape(-1),
            vals.reshape(Q * B, P), sel_valid.reshape(-1).astype(jnp.float32))
        mean = st.mean.at[qi, sel].set(nm.reshape(Q, B))
        count = st.count.at[qi, sel].set(nc.reshape(Q, B))
        m2 = st.m2.at[qi, sel].set(n2.reshape(Q, B))
        coord_ops = st.coord_ops + jnp.sum(sel_valid, 1) * P * pull_cost

        # ---- lazy exact evaluation for arms that crossed MAX_PULLS -------
        crossed = ((count[qi, sel] >= max_pulls_arr[qi, sel])
                   & sel_valid & ~st.exact[qi, sel])
        exact_vals = jax.lax.cond(
            jnp.any(crossed),
            lambda s: exact_fn(s),
            lambda s: jnp.zeros((Q, B), jnp.float32),
            sel)
        mean = mean.at[qi, sel].set(
            jnp.where(crossed, exact_vals, mean[qi, sel]))
        exact = st.exact.at[qi, sel].set(st.exact[qi, sel] | crossed)
        coord_ops = coord_ops + jnp.sum(crossed * exact_cost_arr[qi, sel], 1)

        st2 = st._replace(mean=mean, count=count, m2=m2, exact=exact,
                          coord_ops=coord_ops, rng=rng)

        # ---- per-query acceptance / rejection (shared Alg. 1 step) -------
        ci2 = ci_radius(st2)
        accept_new, rejected = jax.vmap(
            lambda m, c, e, a, r: acceptance_step(
                m, c, e, a, r, k, epsilon=cfg.epsilon, eliminate=eliminate)
        )(st2.mean, ci2, st2.exact, st2.accepted, st2.rejected)
        accepted = st2.accepted | accept_new
        # freeze finished queries
        frozen = st.done[:, None]
        accepted = jnp.where(frozen, st.accepted, accepted)
        rejected = jnp.where(frozen, st.rejected, rejected)

        # a query is finished when it has its k certified arms — or when no
        # candidate is left at all, which a full-corpus race can only reach
        # *after* k acceptances (elimination keeps ≥ k arms non-rejected) but
        # a sharded shard-local race with fewer than k live slots reaches
        # with every live arm certified (sharded.py races such shards for
        # their entire live set; the cross-shard merge tops it back up).
        no_candidates = jnp.sum(~accepted & ~rejected, 1) == 0
        done = st.done | (jnp.sum(accepted, 1) >= k) | no_candidates
        rounds = jnp.where(st.done, st.rounds, st.rounds + 1)
        return st2._replace(accepted=accepted, rejected=rejected,
                            rounds=rounds, done=done,
                            round_no=st.round_no + 1)

    return RoundsRaceFns(init=init_state, body=body, active=cond,
                         ci_radius=ci_radius, exact_fn=exact_fn,
                         exact_cost=exact_cost_arr, max_rounds=max_rounds)


def run_to_certification(fns: RoundsRaceFns, rng: jax.Array,
                         k: int) -> KNNResult:
    """Drive a rounds race to completion in one ``while_loop`` — the
    blocking twin of the chunked sessions in ``index/anytime.py``."""
    st = fns.init(rng)
    st = jax.lax.while_loop(fns.active, fns.body, st)
    ci = fns.ci_radius(st)
    topk, topk_vals = jax.vmap(
        lambda m, c, a, r: topk_from_state(m, c, a, r, k)
    )(st.mean, ci, st.accepted, st.rejected)
    return KNNResult(indices=topk, values=topk_vals, coord_ops=st.coord_ops,
                     rounds=st.rounds, n_exact=jnp.sum(st.exact, 1))


def batched_race_topk(
    pull_fn: Callable,          # (sel (Q, B), rng) -> (Q, B, P) samples
    exact_fn: Callable,         # (sel (Q, B)) -> (Q, B) exact θ
    n: int,
    Q: int,
    max_pulls,                  # scalar, (n,) or (Q, n)
    pull_cost: float,
    exact_cost,                 # scalar, (n,) or (Q, n)
    cfg: BMOConfig,
    rng: jax.Array,
    *,
    eliminate: bool = True,
    dead: Optional[jax.Array] = None,       # (n,) bool tombstones
    prior_var: Optional[jax.Array] = None,  # (n,) warm-start variance prior
    prior_weight: float = 0.0,
    max_pulls_static: int = 0,
) -> KNNResult:
    fns = make_rounds_race(
        pull_fn, exact_fn, n, Q, max_pulls, pull_cost, exact_cost, cfg,
        eliminate=eliminate, dead=dead, prior_var=prior_var,
        prior_weight=prior_weight, max_pulls_static=max_pulls_static)
    return run_to_certification(fns, rng, cfg.k)


# ---------------------------------------------------------------------------
# Epoch-fused driver (DESIGN.md §4): R rounds per launch, survivor-compacted
# bookkeeping. Dense/rotated boxes only — the pulls are corpus-block reads.
# ---------------------------------------------------------------------------


def _dense_exact_theta(x, qs, sel, metric: str, d: int):
    """Exact θ for selected slots: full-row distance / d (the Alg. 1 lazy
    exact evaluation both dense drivers share). sel (Q, B) → (Q, B)."""
    rows = x[sel]                                            # (Q, B, d_pad)
    diff = rows - qs[:, None, :]
    if metric == "l1":
        dist = jnp.sum(jnp.abs(diff), -1)
    else:
        dist = jnp.sum(diff * diff, -1)
    return dist / d


def _frontier_ci(st: FrontierState, cfg: BMOConfig, log_term: float,
                 prior_pool, prior_weight: float) -> jax.Array:
    """Masked CI radii over the compacted frontier. The variance pool is
    taken over *survivors* (not all alive arms as in the PR-1 driver) so the
    radii — and therefore every accept/reject decision — are invariant under
    frontier compaction, which only ever removes rejected entries."""
    Q, W = st.mean.shape
    if cfg.sigma is not None:
        sig_sq = jnp.full((Q, W), float(cfg.sigma) ** 2, jnp.float32)
    else:
        pool_f = survivors(st).astype(jnp.float32)
        num = jnp.sum(st.m2 * pool_f, 1) + prior_weight * prior_pool
        den = (jnp.sum(jnp.maximum(st.count - 1.0, 0.0) * pool_f, 1)
               + prior_weight)
        global_var = num / jnp.maximum(den, 1.0)              # (Q,)
        sig_sq = conf.empirical_sigma_sq_prior(
            st.m2, st.count, 1e-12, global_var[:, None], st.prior,
            prior_weight)
    c = conf.hoeffding_radius_masked(sig_sq, st.count, log_term, st.valid)
    return jnp.where(st.exact, 0.0, c)


@functools.partial(jax.jit, static_argnames=("cfg", "block", "impl",
                                             "prior_weight"))
def _fused_init(x, qs, alive, prior_var, rng, *, cfg: BMOConfig, block: int,
                impl: str, prior_weight: float):
    """Full-width frontier after the paper's wide init: every alive arm of
    every query gets ``init_pulls`` samples from ONE fused launch. Returns
    (state, prior_pool) — the pool term is frozen here so it stays invariant
    across compactions."""
    n = x.shape[0]
    Q = qs.shape[0]
    nb = x.shape[1] // block
    P = cfg.pulls_per_round
    T0 = max(1, max(cfg.init_pulls, 2) // P) * P

    alive_f = alive.astype(jnp.float32)
    n_alive = jnp.sum(alive_f)
    # (n,) build-time priors or (Q, n) per-query seeded priors (near-repeat
    # warm starts) — the pool term is per query either way
    prior2 = (jnp.broadcast_to(prior_var[None], (Q, n))
              if prior_var.ndim == 1 else prior_var)
    prior_pool = jnp.sum(prior2 * alive_f[None], 1) / jnp.maximum(n_alive, 1.0)

    rng, sub = jax.random.split(rng)
    all_arms = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None], (Q, n))
    blk = jax.random.randint(sub, (Q, n, T0), 0, nb)
    with jax.named_scope("repro.fused_epoch_pull"):
        stats = kops.fused_epoch_pull(x, qs, all_arms, blk, block=block,
                                      metric=cfg.metric, impl=impl,
                                      n_buf=cfg.kernel_buffers)
    zeros = jnp.zeros((Q, n), jnp.float32)
    mask = jnp.broadcast_to(alive_f[None], (Q, n))
    mean, count, m2 = conf.welford_merge(
        zeros, zeros, zeros, stats[..., 0], float(T0), stats[..., 1], mask)
    st = FrontierState(
        ids=all_arms,
        mean=mean, count=count, m2=m2,
        prior=prior2,
        exact=jnp.zeros((Q, n), bool),
        accepted=jnp.zeros((Q, n), bool),
        rejected=jnp.broadcast_to(~alive[None], (Q, n)),
        valid=jnp.broadcast_to(alive[None], (Q, n)),
        coord_ops=jnp.full((Q,), float(T0 * block)) * n_alive,
        n_exact=jnp.zeros((Q,), jnp.int32),
        rounds=jnp.zeros((Q,), jnp.int32),
        done=jnp.zeros((Q,), bool),
        rng=rng,
    )
    return st, prior_pool


@functools.partial(jax.jit, static_argnames=(
    "cfg", "block", "d", "impl", "eliminate", "prior_weight", "log_term",
    "T"))
def _fused_epoch_step(x, qs, st: FrontierState, prior_pool, *,
                      cfg: BMOConfig, block: int, d: int, impl: str,
                      eliminate: bool, prior_weight: float, log_term: float,
                      T: int):
    """One epoch: select B lowest-LCB candidates per query, pull each T
    times in one fused launch, merge the on-chip Welford stats, lazily
    exact-evaluate arms that crossed MAX_PULLS, then run acceptance ONCE.
    Everything is O(Q·W) with W the current bucket width."""
    Q, W = st.mean.shape
    k = cfg.k
    B = min(cfg.batch_arms, W)
    nb = x.shape[1] // block
    max_pulls = float(nb)
    qi = jnp.arange(Q)[:, None]

    ci = _frontier_ci(st, cfg, log_term, prior_pool, prior_weight)
    need = (st.valid & ~st.accepted & ~st.rejected & ~st.exact
            & ~st.done[:, None])

    # ---- selection: per query, B lowest-LCB candidates -------------------
    sel_score = jnp.where(need, st.mean - ci, INF)
    _, sel = jax.lax.top_k(-sel_score, B)                    # (Q, B) positions
    sel_valid = jnp.take_along_axis(need, sel, axis=1)
    slot = jnp.take_along_axis(st.ids, sel, axis=1)
    slot_safe = jnp.where(sel_valid, slot, 0)

    # ---- one fused launch: T pulls per selected arm, reduced on-chip -----
    rng, sub = jax.random.split(st.rng)
    blk = jax.random.randint(sub, (Q, B, T), 0, nb)
    with jax.named_scope("repro.fused_epoch_pull"):
        stats = kops.fused_epoch_pull(x, qs, slot_safe, blk, block=block,
                                      metric=cfg.metric, impl=impl,
                                      n_buf=cfg.kernel_buffers)
    cm = jnp.take_along_axis(st.mean, sel, axis=1)
    cc = jnp.take_along_axis(st.count, sel, axis=1)
    c2 = jnp.take_along_axis(st.m2, sel, axis=1)
    nm, nc, n2 = conf.welford_merge(
        cm, cc, c2, stats[..., 0], float(T), stats[..., 1],
        sel_valid.astype(jnp.float32))
    coord_ops = st.coord_ops + jnp.sum(sel_valid, 1) * float(T * block)

    # ---- lazy exact evaluation for arms that crossed MAX_PULLS -----------
    crossed = ((nc >= max_pulls) & sel_valid
               & ~jnp.take_along_axis(st.exact, sel, axis=1))
    exact_vals = jax.lax.cond(
        jnp.any(crossed),
        lambda s: _dense_exact_theta(x, qs, s, cfg.metric, d),
        lambda s: jnp.zeros((Q, B), jnp.float32), slot_safe)
    nm = jnp.where(crossed, exact_vals, nm)
    mean = st.mean.at[qi, sel].set(nm)
    count = st.count.at[qi, sel].set(nc)
    m2 = st.m2.at[qi, sel].set(n2)
    exact = st.exact.at[qi, sel].set(
        jnp.take_along_axis(st.exact, sel, axis=1) | crossed)
    coord_ops = coord_ops + jnp.sum(crossed, 1) * float(d)

    st2 = st._replace(mean=mean, count=count, m2=m2, exact=exact,
                      coord_ops=coord_ops,
                      n_exact=st.n_exact + jnp.sum(crossed, 1, dtype=jnp.int32),
                      rng=rng)

    # ---- acceptance / rejection, ONCE per epoch --------------------------
    ci2 = _frontier_ci(st2, cfg, log_term, prior_pool, prior_weight)
    accept_new, rejected = jax.vmap(
        lambda m, c, e, a, r, v: acceptance_step_masked(
            m, c, e, a, r, v, k, epsilon=cfg.epsilon, eliminate=eliminate)
    )(st2.mean, ci2, st2.exact, st2.accepted, st2.rejected, st2.valid)
    accepted = st2.accepted | accept_new
    frozen = st.done[:, None]
    accepted = jnp.where(frozen, st.accepted, accepted)
    rejected = jnp.where(frozen, st.rejected, rejected)

    # done at k certified arms — or at candidate exhaustion, reachable only
    # in shard-local races over fewer than k live slots (see the per-round
    # driver's note; full-corpus races certify k first)
    no_candidates = jnp.sum(st2.valid & ~accepted & ~rejected, 1) == 0
    done = st.done | (jnp.sum(accepted, 1) >= k) | no_candidates
    # a finished query owes its unresolved candidates nothing: retire them
    # so its survivor set is exactly its k accepted arms — without this a
    # done query could freeze a large candidate set and either pin the
    # bucket width or (worse) have compaction truncate it, breaking the
    # compaction-invariance guarantee.
    rejected = jnp.where(done[:, None], rejected | ~accepted, rejected)
    R = max(1, T // cfg.pulls_per_round)
    rounds = jnp.where(st.done, st.rounds, st.rounds + R)
    st2 = st2._replace(accepted=accepted, rejected=rejected,
                       rounds=rounds, done=done)
    n_surv = jnp.sum((st2.valid & ~st2.rejected & ~st2.done[:, None]), 1)
    return st2, n_surv, done


@functools.partial(jax.jit, static_argnames=("cfg", "log_term",
                                             "prior_weight"))
def _fused_finalize(st: FrontierState, prior_pool, *, cfg: BMOConfig,
                    log_term: float, prior_weight: float):
    ci = _frontier_ci(st, cfg, log_term, prior_pool, prior_weight)
    topk, topk_vals = jax.vmap(
        lambda m, c, a, r, v, i: topk_from_state_masked(
            m, c, a, r, v, i, cfg.k)
    )(st.mean, ci, st.accepted, st.rejected, st.valid, st.ids)
    return topk, topk_vals, st.n_exact


def fused_race_topk(x, qs, alive, prior_var, rng, *, cfg: BMOConfig,
                    block: int, d: int, impl: str, eliminate: bool,
                    prior_weight: float, compaction: bool = True,
                    _return_state: bool = False):
    """Epoch-fused, survivor-compacted dense/rotated race (DESIGN.md §4).

    Two-level loop: the *host* iterates epochs (re-jitted per bucket width —
    a bounded, ~log₂ n-sized specialization cache), each epoch running R
    fused pull-rounds in one kernel launch and one acceptance pass. Pulls
    per epoch are reallocated adaptively: as the frontier shrinks by c×, R
    scales up by c× (capped at MAX_PULLS worth), so stragglers drain in a
    handful of launches instead of hundreds of rounds.

    ``compaction=False`` keeps the full-width buffers (used by the
    invariance tests — decisions must match exactly).
    ``_return_state`` additionally returns the final FrontierState.
    """
    n = x.shape[0]
    Q = qs.shape[0]
    k = cfg.k
    P = cfg.pulls_per_round
    nb = x.shape[1] // block
    B0 = min(cfg.batch_arms, n)
    # host-sync: python-float math on cfg.delta, no device value
    log_term = float(np.log(2.0 / conf.delta_prime(cfg.delta, n, nb)))
    max_rounds = cfg.max_rounds or int(
        2 * math.ceil(n * nb / max(B0 * P, 1)) + n + 16)
    R0 = max(cfg.epoch_rounds, 1)
    R_cap = max(1, -(-nb // P))          # one epoch never overshoots exact
    floor_w = floor_width(cfg, n, B0=B0)

    st, prior_pool = _fused_init(x, qs, alive, prior_var, rng, cfg=cfg,
                                 block=block, impl=impl,
                                 prior_weight=prior_weight)
    W0 = st.width
    rounds_spent = 0
    n_surv = np.full((Q,), n)
    done = np.zeros((Q,), bool)
    obs = get_obs()
    prev_coord = float(np.sum(host_fetch(st.coord_ops)))
    while not done.all() and rounds_spent < max_rounds:
        # adaptive reallocation (Neufeld et al. style): as the candidate
        # frontier shrinks by c×, fuse c× more rounds into the next launch —
        # the same pull budget per epoch, concentrated on the survivors.
        # Keyed off the *survivor count*, not the buffer width, so the pull
        # schedule is identical with compaction on or off (tested).
        need = int(n_surv[~done].max(initial=1))
        if compaction:
            W_new = bucket_width(need, floor=floor_w, current=st.width)
            if W_new < st.width:
                st = compact_frontier(st, W_new=W_new)
        R = min(R0 * pow2_floor(W0 // max(need, 1)), R_cap)
        t0 = time.perf_counter()
        with obs_profile.annotate("repro.race.epoch.fused_blocking"):
            st, n_surv_d, done_d = _fused_epoch_step(
                x, qs, st, prior_pool, cfg=cfg, block=block, d=d, impl=impl,
                eliminate=eliminate, prior_weight=prior_weight,
                log_term=log_term, T=R * P)
            rounds_spent += R
            # the per-epoch boundary: survivor count + done flags must
            # cross to host to drive the Python reallocation loop
            n_surv, done = host_fetch((n_surv_d, done_d))
        # n_surv/done already crossed to host, so the per-launch accounting
        # adds no extra device round-trip beyond the coord-op scalar
        coord = float(np.sum(host_fetch(st.coord_ops)))
        obs.registry.histogram(
            "repro_race_epoch_ms", "wall time of one race epoch (ms)",
            kind="fused_blocking").observe((time.perf_counter() - t0) * 1e3)
        obs_profile.record_kernel_launch(
            obs, "fused_epoch_pull", launches=1,
            coord_ops=max(coord - prev_coord, 0.0),
            pulls=float(R))  # host-sync: python int
        prev_coord = coord

    topk, topk_vals, n_exact = _fused_finalize(
        st, prior_pool, cfg=cfg, log_term=log_term, prior_weight=prior_weight)
    res = KNNResult(indices=topk, values=topk_vals, coord_ops=st.coord_ops,
                    rounds=st.rounds, n_exact=n_exact)
    if _return_state:
        return res, st
    return res


# ---------------------------------------------------------------------------
# IndexStore front-ends
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "block", "d", "impl",
                                             "eliminate", "prior_weight"))
def _dense_index_knn(x, qs, alive, prior_var, rng, *, cfg: BMOConfig,
                     block: int, d: int, impl: str, eliminate: bool,
                     prior_weight: float) -> KNNResult:
    n, d_pad = x.shape
    Q = qs.shape[0]
    nb = d_pad // block

    def pull(sel, key):
        blk = jax.random.randint(key, sel.shape + (cfg.pulls_per_round,), 0, nb)
        with jax.named_scope("repro.block_pull_multi"):
            return kops.block_pull_multi(x, qs, sel, blk, block=block,
                                         metric=cfg.metric, impl=impl)

    def exact(sel):
        return _dense_exact_theta(x, qs, sel, cfg.metric, d)

    return batched_race_topk(
        pull, exact, n=n, Q=Q,
        max_pulls=float(d_pad // block),
        pull_cost=float(block),
        exact_cost=float(d),
        cfg=cfg, rng=rng, eliminate=eliminate,
        dead=~alive, prior_var=prior_var, prior_weight=prior_weight,
    )


def make_sparse_rounds_race(indices, values, nnz, alive, prior_var,
                            q_idx, q_val, q_nnz, *, cfg: BMOConfig, d: int,
                            eliminate: bool, prior_weight: float
                            ) -> RoundsRaceFns:
    """Assemble the §IV-A sparse box's per-round race pieces (shared by the
    blocking driver below and the resumable sessions in index/anytime.py)."""
    n, m = indices.shape
    Q, mq = q_idx.shape
    ds = SparseDataset(indices=indices, values=values, nnz=nnz, d=d)
    P = cfg.pulls_per_round

    def pull(sel, key):
        B = sel.shape[1]
        keys = jax.random.split(key, Q * B * P).reshape(Q, B, P, 2)
        per_pull = lambda qi_, qv_, qn_, a, kk: sparse_pull_one(
            ds, qi_, qv_, qn_, a, kk)
        over_p = jax.vmap(per_pull, in_axes=(None, None, None, None, 0))
        over_b = jax.vmap(over_p, in_axes=(None, None, None, 0, 0))
        over_q = jax.vmap(over_b, in_axes=(0, 0, 0, 0, 0))
        return over_q(q_idx, q_val, q_nnz, sel, keys).astype(jnp.float32)

    def exact(sel):
        return jax.vmap(lambda qi_, qv_, s: sparse_exact_theta(ds, qi_, qv_, s))(
            q_idx, q_val, sel)

    exact_cost = (nnz[None, :] + q_nnz[:, None]).astype(jnp.float32)  # (Q, n)
    max_pulls = jnp.maximum(exact_cost, 8.0)
    return make_rounds_race(
        pull, exact, n=n, Q=Q,
        max_pulls=max_pulls, pull_cost=1.0, exact_cost=exact_cost,
        cfg=cfg, eliminate=eliminate,
        dead=~alive, prior_var=prior_var, prior_weight=prior_weight,
        max_pulls_static=int(m + mq),
    )


@functools.partial(jax.jit, static_argnames=("cfg", "d", "eliminate",
                                             "prior_weight"))
def _sparse_index_knn(indices, values, nnz, alive, prior_var,
                      q_idx, q_val, q_nnz, rng, *, cfg: BMOConfig, d: int,
                      eliminate: bool, prior_weight: float) -> KNNResult:
    fns = make_sparse_rounds_race(
        indices, values, nnz, alive, prior_var, q_idx, q_val, q_nnz,
        cfg=cfg, d=d, eliminate=eliminate, prior_weight=prior_weight)
    return run_to_certification(fns, rng, cfg.k)


def index_knn(store, queries, rng: jax.Array, *, k=None, impl: str = "auto",
              eliminate: bool = True, warm_start: bool = True,
              mode: str = "auto", prior_hint=None) -> KNNResult:
    """Batched k-NN against an IndexStore (slot indices; tombstones are
    excluded). Drop-in for ``bmo_nn.knn`` on the serving path — same
    KNNResult fields, one batched race instead of Q sequential ones.

    ``mode``: "fused" — the epoch-fused, survivor-compacted driver
    (DESIGN.md §4; dense/rotated only); "rounds" — the PR-1 one-launch-per-
    round driver; "auto" — fused where available, rounds for sparse.

    ``prior_hint``: optional (Q, capacity) per-query CI variance priors
    replacing the store's build-time per-arm priors — the near-repeat
    warm-start path (serve/engine.py) seeds these from a cached neighbour's
    result. A ``ShardedIndexStore`` (DESIGN.md §5) dispatches to the
    mesh-spanning driver in ``index/sharded.py``.
    """
    if hasattr(store, "shards"):      # ShardedIndexStore — mesh present
        from repro.index.sharded import sharded_index_knn
        return sharded_index_knn(store, queries, rng, k=k, impl=impl,
                                 eliminate=eliminate, warm_start=warm_start,
                                 mode=mode, prior_hint=prior_hint)
    cfg = store.cfg if k is None else dataclasses.replace(store.cfg, k=k)
    n_live = store.n_live
    if cfg.k > n_live:
        raise ValueError(
            f"k={cfg.k} exceeds the index's {n_live} live slots — "
            "tombstoned slots can never be returned")
    if mode not in ("auto", "fused", "rounds"):
        raise ValueError(f"unknown mode {mode!r}")
    w = store.prior_weight if warm_start else 0.0
    prior = store.prior_var if prior_hint is None else jnp.asarray(
        prior_hint, jnp.float32)
    if prior_hint is not None:
        w = store.prior_weight        # a seeded prior implies warm start
    if store.kind == "sparse":
        if mode == "fused":
            raise ValueError("the fused epoch driver pulls corpus blocks — "
                             "sparse boxes race on the per-round driver")
        q_idx, q_val, q_nnz = queries
        return _sparse_index_knn(
            store.indices, store.values, store.nnz, store.alive,
            prior, q_idx, q_val, q_nnz, rng,
            cfg=cfg, d=store.d, eliminate=eliminate, prior_weight=w)
    qs = store.prepare_queries(queries)
    if mode == "rounds":
        return _dense_index_knn(
            store.x, qs, store.alive, prior, rng,
            cfg=cfg, block=store.block, d=store.d, impl=impl,
            eliminate=eliminate, prior_weight=w)
    return fused_race_topk(
        store.x, qs, store.alive, prior, rng,
        cfg=cfg, block=store.block, d=store.d, impl=impl,
        eliminate=eliminate, prior_weight=w)
