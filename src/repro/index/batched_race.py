"""Cross-query batched racing (DESIGN.md §3.2) — the index-serving driver
that replaces per-query ``jax.lax.map`` over ``core.ucb.race_topk``.

The per-query path runs Q *sequential* while-loops; every round launches a
tiny (B, P) pull. Under serving traffic that shape is wrong twice over:
wall-clock is the SUM of per-query rounds, and each round's kernel is too
small to fill the machine. Here one ``(Q, B)`` arm frontier races
simultaneously:

  * one ``kernels/ops.block_pull_multi`` launch serves every active query
    per round (per-round overhead paid once, corpus rows fetched for one
    query's frontier ride in the same launch as everyone else's),
  * wall-clock is the MAX of per-query rounds, not the sum,
  * queries that finish early are masked out (no pulls, no cost) while the
    stragglers drain.

Correctness is the per-query algorithm's, unchanged: selection, Welford
updates, CI radii, and the Alg. 1 acceptance/rejection step
(``core.ucb.acceptance_step``) are applied per query via ``vmap``; the only
coupling across queries is the shared kernel launch. Warm-start priors from
the IndexStore enter through ``confidence.empirical_sigma_sq_prior`` —
variance estimates only, never CI sample counts.

Tombstoned (dead) slots enter the race pre-rejected (mutable.py): they are
never selected, never pulled, and can never be returned.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BMOConfig
from repro.core import confidence as conf
from repro.core.bmo_nn import KNNResult, sparse_exact_theta, sparse_pull_one
from repro.core.datasets import SparseDataset
from repro.core.ucb import INF, acceptance_step, topk_from_state
from repro.kernels import ops as kops


class BatchedRaceState(NamedTuple):
    mean: jax.Array        # (Q, n)
    count: jax.Array       # (Q, n)
    m2: jax.Array          # (Q, n)
    exact: jax.Array       # (Q, n) bool
    accepted: jax.Array    # (Q, n) bool
    rejected: jax.Array    # (Q, n) bool
    coord_ops: jax.Array   # (Q,)
    rounds: jax.Array      # (Q,) rounds spent while the query was active
    done: jax.Array        # (Q,) bool
    round_no: jax.Array    # () int32
    rng: jax.Array


def batched_race_topk(
    pull_fn: Callable,          # (sel (Q, B), rng) -> (Q, B, P) samples
    exact_fn: Callable,         # (sel (Q, B)) -> (Q, B) exact θ
    n: int,
    Q: int,
    max_pulls,                  # scalar, (n,) or (Q, n)
    pull_cost: float,
    exact_cost,                 # scalar, (n,) or (Q, n)
    cfg: BMOConfig,
    rng: jax.Array,
    *,
    eliminate: bool = True,
    dead: Optional[jax.Array] = None,       # (n,) bool tombstones
    prior_var: Optional[jax.Array] = None,  # (n,) warm-start variance prior
    prior_weight: float = 0.0,
    max_pulls_static: int = 0,
) -> KNNResult:
    k = cfg.k
    B = min(cfg.batch_arms, n)
    P = cfg.pulls_per_round
    max_pulls_arr = jnp.broadcast_to(
        jnp.asarray(max_pulls, jnp.float32), (Q, n))
    exact_cost_arr = jnp.broadcast_to(
        jnp.asarray(exact_cost, jnp.float32), (Q, n))
    max_pulls_hi = max_pulls_static or int(np.max(np.asarray(max_pulls)))
    log_term = float(np.log(2.0 / conf.delta_prime(cfg.delta, n, max_pulls_hi)))
    max_rounds = cfg.max_rounds or int(
        2 * math.ceil(n * max_pulls_hi / max(B * P, 1)) + n + 16)

    alive = jnp.ones((n,), bool) if dead is None else ~dead
    alive_f = alive.astype(jnp.float32)
    n_alive = jnp.sum(alive_f)
    if prior_var is None:
        prior_var = jnp.zeros((n,), jnp.float32)
        prior_weight = 0.0
    prior_pool = jnp.sum(prior_var * alive_f) / jnp.maximum(n_alive, 1.0)
    qi = jnp.arange(Q)[:, None]

    def ci_radius(st: BatchedRaceState) -> jax.Array:
        if cfg.sigma is not None:
            sig_sq = jnp.full((Q, n), float(cfg.sigma) ** 2, jnp.float32)
        else:
            # per-query pooled variance, warm-started by the build-time prior
            num = jnp.sum(st.m2 * alive_f, 1) + prior_weight * prior_pool
            den = (jnp.sum(jnp.maximum(st.count - 1.0, 0.0) * alive_f, 1)
                   + prior_weight)
            global_var = num / jnp.maximum(den, 1.0)         # (Q,)
            sig_sq = conf.empirical_sigma_sq_prior(
                st.m2, st.count, 1e-12, global_var[:, None],
                prior_var[None, :], prior_weight)
        c = conf.hoeffding_radius(sig_sq, st.count, log_term)
        return jnp.where(st.exact, 0.0, c)

    def init_state(rng):
        # wide init (paper App. D-A): every alive arm of every query gets
        # init_pulls samples, as reps of ONE (Q, n, P) launch
        n_init = max(cfg.init_pulls, 2)
        reps = max(1, n_init // P)
        mean = jnp.zeros((Q, n), jnp.float32)
        count = jnp.zeros((Q, n), jnp.float32)
        m2 = jnp.zeros((Q, n), jnp.float32)
        all_arms = jnp.broadcast_to(jnp.arange(n)[None], (Q, n))
        mask = jnp.broadcast_to(alive_f[None], (Q, n)).reshape(-1)

        def rep_body(carry, _):
            mean, count, m2, rng = carry
            rng, sub = jax.random.split(rng)
            vals = pull_fn(all_arms, sub)                    # (Q, n, P)
            nm, nc, n2 = conf.welford_batch_update(
                mean.reshape(-1), count.reshape(-1), m2.reshape(-1),
                vals.reshape(Q * n, P), mask)
            return (nm.reshape(Q, n), nc.reshape(Q, n), n2.reshape(Q, n),
                    rng), None

        (mean, count, m2, rng), _ = jax.lax.scan(
            rep_body, (mean, count, m2, rng), None, length=reps)
        return BatchedRaceState(
            mean=mean, count=count, m2=m2,
            exact=jnp.zeros((Q, n), bool),
            accepted=jnp.zeros((Q, n), bool),
            rejected=jnp.broadcast_to(~alive[None], (Q, n)),
            coord_ops=jnp.full((Q,), float(reps * P * pull_cost)) * n_alive,
            rounds=jnp.zeros((Q,), jnp.int32),
            done=jnp.zeros((Q,), bool),
            round_no=jnp.zeros((), jnp.int32),
            rng=rng,
        )

    def cond(st: BatchedRaceState):
        return (~jnp.all(st.done)) & (st.round_no < max_rounds)

    def body(st: BatchedRaceState):
        ci = ci_radius(st)
        lcb = st.mean - ci
        candidate = ~st.accepted & ~st.rejected
        need = candidate & ~st.exact & ~st.done[:, None]

        # ---- selection: per query, B lowest-LCB candidates ---------------
        sel_score = jnp.where(need, lcb, INF)
        _, sel = jax.lax.top_k(-sel_score, B)                # (Q, B)
        sel_valid = jnp.take_along_axis(need, sel, axis=1)   # (Q, B)

        rng, sub = jax.random.split(st.rng)
        vals = pull_fn(sel, sub)                             # (Q, B, P)
        cm, cc, c2 = st.mean[qi, sel], st.count[qi, sel], st.m2[qi, sel]
        nm, nc, n2 = conf.welford_batch_update(
            cm.reshape(-1), cc.reshape(-1), c2.reshape(-1),
            vals.reshape(Q * B, P), sel_valid.reshape(-1).astype(jnp.float32))
        mean = st.mean.at[qi, sel].set(nm.reshape(Q, B))
        count = st.count.at[qi, sel].set(nc.reshape(Q, B))
        m2 = st.m2.at[qi, sel].set(n2.reshape(Q, B))
        coord_ops = st.coord_ops + jnp.sum(sel_valid, 1) * P * pull_cost

        # ---- lazy exact evaluation for arms that crossed MAX_PULLS -------
        crossed = ((count[qi, sel] >= max_pulls_arr[qi, sel])
                   & sel_valid & ~st.exact[qi, sel])
        exact_vals = jax.lax.cond(
            jnp.any(crossed),
            lambda s: exact_fn(s),
            lambda s: jnp.zeros((Q, B), jnp.float32),
            sel)
        mean = mean.at[qi, sel].set(
            jnp.where(crossed, exact_vals, mean[qi, sel]))
        exact = st.exact.at[qi, sel].set(st.exact[qi, sel] | crossed)
        coord_ops = coord_ops + jnp.sum(crossed * exact_cost_arr[qi, sel], 1)

        st2 = st._replace(mean=mean, count=count, m2=m2, exact=exact,
                          coord_ops=coord_ops, rng=rng)

        # ---- per-query acceptance / rejection (shared Alg. 1 step) -------
        ci2 = ci_radius(st2)
        accept_new, rejected = jax.vmap(
            lambda m, c, e, a, r: acceptance_step(
                m, c, e, a, r, k, epsilon=cfg.epsilon, eliminate=eliminate)
        )(st2.mean, ci2, st2.exact, st2.accepted, st2.rejected)
        accepted = st2.accepted | accept_new
        # freeze finished queries
        frozen = st.done[:, None]
        accepted = jnp.where(frozen, st.accepted, accepted)
        rejected = jnp.where(frozen, st.rejected, rejected)

        done = st.done | (jnp.sum(accepted, 1) >= k)
        rounds = jnp.where(st.done, st.rounds, st.rounds + 1)
        return st2._replace(accepted=accepted, rejected=rejected,
                            rounds=rounds, done=done,
                            round_no=st.round_no + 1)

    st = init_state(rng)
    st = jax.lax.while_loop(cond, body, st)

    ci = ci_radius(st)
    topk, topk_vals = jax.vmap(
        lambda m, c, a, r: topk_from_state(m, c, a, r, k)
    )(st.mean, ci, st.accepted, st.rejected)
    return KNNResult(indices=topk, values=topk_vals, coord_ops=st.coord_ops,
                     rounds=st.rounds, n_exact=jnp.sum(st.exact, 1))


# ---------------------------------------------------------------------------
# IndexStore front-ends
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "block", "d", "impl",
                                             "eliminate", "prior_weight"))
def _dense_index_knn(x, qs, alive, prior_var, rng, *, cfg: BMOConfig,
                     block: int, d: int, impl: str, eliminate: bool,
                     prior_weight: float) -> KNNResult:
    n, d_pad = x.shape
    Q = qs.shape[0]
    nb = d_pad // block

    def pull(sel, key):
        blk = jax.random.randint(key, sel.shape + (cfg.pulls_per_round,), 0, nb)
        return kops.block_pull_multi(x, qs, sel, blk, block=block,
                                     metric=cfg.metric, impl=impl)

    def exact(sel):
        rows = x[sel]                                        # (Q, B, d_pad)
        diff = rows - qs[:, None, :]
        if cfg.metric == "l1":
            dist = jnp.sum(jnp.abs(diff), -1)
        else:
            dist = jnp.sum(diff * diff, -1)
        return dist / d

    return batched_race_topk(
        pull, exact, n=n, Q=Q,
        max_pulls=float(d_pad // block),
        pull_cost=float(block),
        exact_cost=float(d),
        cfg=cfg, rng=rng, eliminate=eliminate,
        dead=~alive, prior_var=prior_var, prior_weight=prior_weight,
    )


@functools.partial(jax.jit, static_argnames=("cfg", "d", "eliminate",
                                             "prior_weight"))
def _sparse_index_knn(indices, values, nnz, alive, prior_var,
                      q_idx, q_val, q_nnz, rng, *, cfg: BMOConfig, d: int,
                      eliminate: bool, prior_weight: float) -> KNNResult:
    n, m = indices.shape
    Q, mq = q_idx.shape
    ds = SparseDataset(indices=indices, values=values, nnz=nnz, d=d)
    P = cfg.pulls_per_round

    def pull(sel, key):
        B = sel.shape[1]
        keys = jax.random.split(key, Q * B * P).reshape(Q, B, P, 2)
        per_pull = lambda qi_, qv_, qn_, a, kk: sparse_pull_one(
            ds, qi_, qv_, qn_, a, kk)
        over_p = jax.vmap(per_pull, in_axes=(None, None, None, None, 0))
        over_b = jax.vmap(over_p, in_axes=(None, None, None, 0, 0))
        over_q = jax.vmap(over_b, in_axes=(0, 0, 0, 0, 0))
        return over_q(q_idx, q_val, q_nnz, sel, keys).astype(jnp.float32)

    def exact(sel):
        return jax.vmap(lambda qi_, qv_, s: sparse_exact_theta(ds, qi_, qv_, s))(
            q_idx, q_val, sel)

    exact_cost = (nnz[None, :] + q_nnz[:, None]).astype(jnp.float32)  # (Q, n)
    max_pulls = jnp.maximum(exact_cost, 8.0)
    return batched_race_topk(
        pull, exact, n=n, Q=Q,
        max_pulls=max_pulls, pull_cost=1.0, exact_cost=exact_cost,
        cfg=cfg, rng=rng, eliminate=eliminate,
        dead=~alive, prior_var=prior_var, prior_weight=prior_weight,
        max_pulls_static=int(m + mq),
    )


def index_knn(store, queries, rng: jax.Array, *, k=None, impl: str = "auto",
              eliminate: bool = True, warm_start: bool = True) -> KNNResult:
    """Batched k-NN against an IndexStore (slot indices; tombstones are
    excluded). Drop-in for ``bmo_nn.knn`` on the serving path — same
    KNNResult fields, one batched race instead of Q sequential ones."""
    cfg = store.cfg if k is None else dataclasses.replace(store.cfg, k=k)
    n_live = store.n_live
    if cfg.k > n_live:
        raise ValueError(
            f"k={cfg.k} exceeds the index's {n_live} live slots — "
            "tombstoned slots can never be returned")
    w = store.prior_weight if warm_start else 0.0
    if store.kind == "sparse":
        q_idx, q_val, q_nnz = queries
        return _sparse_index_knn(
            store.indices, store.values, store.nnz, store.alive,
            store.prior_var, q_idx, q_val, q_nnz, rng,
            cfg=cfg, d=store.d, eliminate=eliminate, prior_weight=w)
    qs = store.prepare_queries(queries)
    return _dense_index_knn(
        store.x, qs, store.alive, store.prior_var, rng,
        cfg=cfg, block=store.block, d=store.d, impl=impl,
        eliminate=eliminate, prior_weight=w)
