"""The sanctioned device→host boundary (DESIGN.md §8, §12.4).

The serving stack's transfer discipline: arrays cross to the host at ONE
deliberate boundary per epoch, and everything downstream works on
host-resident numpy. ``host_fetch`` is that boundary — an explicit
``jax.device_get`` wrapped in a transfer-guard allow-scope, so the CI
sanitize tier (tier-1 under ``jax.transfer_guard("disallow")``) passes
exactly where the code says "this transfer is on purpose" and fails
everywhere else. The host-sync lint rule closes the static half: any
other sync-shaped call on a hot path must carry a
``# host-sync: <why>`` annotation.

``host_fetch`` also accepts values that are already host-side (numpy
arrays, floats, pytrees of either) — ``device_get`` is a no-op copy for
those — so call sites don't need to branch on residency.
"""
from __future__ import annotations

import contextlib

import jax

__all__ = ["host_fetch", "host_boundary"]


@contextlib.contextmanager
def host_boundary():
    """Allow device→host transfers inside this scope even when the
    sanitize tier runs with ``jax.transfer_guard("disallow")``. Use for
    a *block* of deliberate host work (e.g. checkpoint serialization);
    single values should prefer ``host_fetch``."""
    with jax.transfer_guard_device_to_host("allow"):
        yield


def host_fetch(value):
    """Bring ``value`` (array or pytree) to the host, deliberately.

    The ONE sanctioned sync: blocks until the device computation behind
    ``value`` is done and returns host-resident numpy. Equivalent to
    ``jax.device_get`` under an explicit allow-scope — it stays legal
    under the sanitize tier's ``transfer_guard("disallow")``.
    """
    with jax.transfer_guard_device_to_host("allow"):
        return jax.device_get(value)
