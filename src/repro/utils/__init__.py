from repro.utils.tree import tree_bytes, tree_count, tree_map_with_path_str
from repro.utils.hostsync import host_boundary, host_fetch
from repro.utils.logging import get_logger

__all__ = ["tree_bytes", "tree_count", "tree_map_with_path_str", "get_logger",
           "host_boundary", "host_fetch"]
