"""Pytree helpers used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree) -> int:
    """Total number of elements (parameters) in a pytree of arrays."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) for l in leaves if hasattr(l, "shape")))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays/ShapeDtypeStructs."""
    total = 0
    for l in jax.tree_util.tree_leaves(tree):
        if hasattr(l, "shape") and hasattr(l, "dtype"):
            total += int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
    return total


def tree_map_with_path_str(fn, tree):
    """tree_map where fn receives ('a/b/c', leaf)."""

    def _fmt(path) -> str:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return "/".join(parts)

    return jax.tree_util.tree_map_with_path(lambda p, l: fn(_fmt(p), l), tree)


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.allclose(x, y, rtol=rtol, atol=atol) for x, y in zip(la, lb))
