"""Minimal structured logging for the framework (no external deps).

``get_logger(name)`` returns a ``StructuredLogger`` — a stdlib
``LoggerAdapter`` with one addition: ``bind(**ctx)`` returns a child
logger whose every record carries the bound context as a ``[k=v ...]``
suffix. The serving stack binds the obs trace id so a grep over logs
joins with the trace-event dumps on the same ``trace_id``
(DESIGN.md §8.3)::

    log = get_logger("repro.serve.plane").bind(trace_id=ticket.trace_id)
    log.warning("deadline expired after %d epochs", n)
    # 12:00:01 W repro.serve.plane] deadline expired after 3 epochs
    #                               [trace_id=p0.t17]

``REPRO_LOGLEVEL`` is re-read on every ``get_logger`` call (not only the
first), so a long-lived process — or a test — can flip verbosity by
setting the environment variable and re-creating its logger.
"""
from __future__ import annotations

import logging
import os
import sys
from typing import Optional

_FMT = "%(asctime)s %(levelname).1s %(name)s] %(message)s"


class StructuredLogger(logging.LoggerAdapter):
    """A LoggerAdapter whose bound context renders as a ``[k=v ...]``
    record suffix. ``bind`` is pure: it returns a NEW adapter, so one
    module-level logger can be specialized per ticket/trace without
    cross-talk."""

    def bind(self, **ctx) -> "StructuredLogger":
        merged = dict(self.extra or {})
        merged.update({k: v for k, v in ctx.items() if v is not None})
        return StructuredLogger(self.logger, merged)

    def process(self, msg, kwargs):
        if self.extra:
            suffix = " ".join(f"{k}={v}" for k, v in self.extra.items())
            msg = f"{msg} [{suffix}]"
        return msg, kwargs


def _level() -> int:
    raw = os.environ.get("REPRO_LOGLEVEL", "INFO").upper()
    got = getattr(logging, raw, None)
    return got if isinstance(got, int) else logging.INFO


def get_logger(name: str,
               trace_id: Optional[str] = None) -> StructuredLogger:
    """A structured logger for ``name``; optionally pre-bound to a trace
    id. Honours ``REPRO_LOGLEVEL`` at every call."""
    logger = logging.getLogger(name)
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter(_FMT, datefmt="%H:%M:%S"))
        logger.addHandler(handler)
        logger.propagate = False
    logger.setLevel(_level())
    out = StructuredLogger(logger, {})
    return out.bind(trace_id=trace_id) if trace_id is not None else out
