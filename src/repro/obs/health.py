"""repro.obs.health — one JSON health/SLO snapshot for the serving stack
(DESIGN.md §10.4).

``health_snapshot`` folds the pieces PR 8 added — the δ-auditor's
estimator state, the SLO engine's burn state and active alerts, the
serving-fallback flags on the handle — together with the plane's
``ServeStats`` into a single schema-versioned JSON document. The CI audit
gate and ``--health-dump`` flags (launcher, benches) emit exactly this
document; dashboards and the replay tooling parse it.
"""
from __future__ import annotations

import json
from typing import Optional

import numpy as np


def _jsonify(obj):
    """Best-effort JSON coercion for numpy scalars/arrays inside stats."""
    if isinstance(obj, dict):
        return {str(k): _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonify(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return _jsonify(obj.tolist())
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        v = float(obj)
        return v if np.isfinite(v) else repr(v)
    if isinstance(obj, float) and not np.isfinite(obj):
        return repr(obj)
    return obj


def health_snapshot(*, plane=None, index=None, auditor=None,
                    slo=None, fleet=None) -> dict:
    """One JSON-safe health document. Pass whichever pieces exist — a
    plane implies its index and auditor unless overridden; a fleet adds a
    per-namespace residency/queue rollup (``fleet`` section). ``ok`` is
    the one-bit rollup: no active SLO alert, no audited key in
    δ-violation, and no forced serving fallback."""
    from repro.api.spec import SCHEMA_VERSION
    if plane is not None:
        index = index if index is not None else plane.index
        auditor = auditor if auditor is not None else \
            getattr(plane, "auditor", None)
        if fleet is None and getattr(plane, "router", None) is not None \
                and hasattr(plane.router, "stats"):
            fleet = plane.router
    doc = {"schema_version": SCHEMA_VERSION,
           "generated_by": "repro.obs.health"}
    violations = []
    active_alerts = []
    if plane is not None:
        doc["stats"] = _jsonify(plane.stats.as_dict())
    elif index is not None:
        doc["stats"] = _jsonify(index.stats.as_dict())
    if index is not None:
        doc["index"] = {
            "kind": index.kind,
            "shards": index.n_shards,
            "live": index.n_live,
            "capacity": index.capacity,
            "epoch": index.epoch,
            "k": index.k,
            "delta": float(index.cfg.delta),
            "tuned": index.tuned is not None,
            "serving_fallback": getattr(index, "serving_fallback", False),
            "retune_requested": bool(
                getattr(index, "retune_requested", False)),
        }
    if auditor is not None:
        audit = auditor.summary()
        doc["audit"] = _jsonify(audit)
        violations = [k for k in audit["keys"] if k["violated"]]
    if fleet is not None:
        fdoc = dict(fleet.stats())
        if plane is not None and hasattr(plane, "ns_queue_depth"):
            fdoc["ns_queue_depth"] = plane.ns_queue_depth()
        doc["fleet"] = _jsonify(fdoc)
    if slo is not None:
        state = slo.state()
        doc["slo"] = _jsonify(state)
        active_alerts = state["active"]
    doc["violations"] = _jsonify(violations)
    doc["ok"] = (not violations and not active_alerts
                 and not (index is not None
                          and getattr(index, "serving_fallback", False)))
    return doc


def dump_health(path: str, *, plane=None, index=None, auditor=None,
                slo=None, fleet=None) -> dict:
    """Write ``health_snapshot`` to ``path``; returns the document."""
    doc = health_snapshot(plane=plane, index=index, auditor=auditor,
                          slo=slo, fleet=fleet)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return doc


def print_health(doc: dict, *, out=None) -> None:
    """Terse human rendering of a health snapshot (launcher/bench logs)."""
    import sys
    out = out if out is not None else sys.stderr
    audit = doc.get("audit") or {}
    lines = [f"health ok={doc['ok']}"]
    if audit:
        lines.append(
            f"  audit: {audit['sampled_rows']} rows sampled, "
            f"{audit['mismatch_rows']} mismatches, "
            f"err_upper={audit['err_upper']:.4g} "
            f"(pending {audit['pending']}, dropped {audit['dropped']})")
    for s in (doc.get("slo") or {}).get("slos", []):
        burn = max((r["burn"] for r in s["rules"]), default=0.0)
        lines.append(f"  slo {s['name']}: budget={s['budget']:g} "
                     f"bad_frac={s['bad_frac']:.4g} burn={burn:.2f}x")
    for v in doc.get("violations", []):
        lines.append(f"  VIOLATION: {v}")
    print("\n".join(lines), file=out)
