"""jax compile-time telemetry → obs registry (DESIGN.md §8.6).

XLA backend compiles are the serving stack's worst tail event: a fresh
(shape, static-arg) specialization on the hot path stalls every ticket in
the batch for hundreds of milliseconds. The drivers are built so one warm
race precompiles every specialization mid-traffic requests can reach
(pow2 width chain, pow2-quantized adaptive R) — this module makes that
guarantee *measurable*:

  * ``repro_xla_compiles_total``   — backend compiles since process start,
  * ``repro_xla_compile_ms``       — their wall-time histogram.

Implemented over ``jax.monitoring``'s event-duration listeners — the same
channel jax's own telemetry uses, so there is nothing to patch and no
overhead beyond the listener call. The listener is registered once per
process (jax only exposes clear-all, never unregister) and resolves
``get_obs()`` *at event time*, so a test that installs its own ObsContext
sees exactly the compiles its own traffic caused.

The regression test (tests/test_obs.py) asserts the counter stays flat
across repeat traffic after a warm race — the guard that keeps
``repro.tune``'s bucket-schedule changes from causing recompile storms.
"""
from __future__ import annotations

#: jax._src.dispatch.BACKEND_COMPILE_EVENT — the event every XLA
#: backend.compile() call records (stable across jax 0.4.x).
BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_installed = False


def _on_event_duration(event: str, duration_secs: float, **kwargs) -> None:
    if event != BACKEND_COMPILE_EVENT:
        return
    from repro.obs import get_obs  # resolve the CURRENT context, lazily
    reg = get_obs().registry
    reg.counter("repro_xla_compiles_total",
                "XLA backend compiles since process start").inc()
    reg.histogram("repro_xla_compile_ms",
                  "XLA backend compile wall time (ms)").observe(
        duration_secs * 1e3)


def install_compile_hook() -> bool:
    """Register the listener (idempotent). Returns True when active."""
    global _installed
    if _installed:
        return True
    try:
        from jax import monitoring
        monitoring.register_event_duration_secs_listener(_on_event_duration)
    except Exception:  # pragma: no cover — jax without the monitoring API
        return False
    _installed = True
    return True


def compiles_total(obs=None) -> int:
    """Current value of ``repro_xla_compiles_total`` in ``obs`` (default:
    the process context). 0 if nothing compiled since the context began."""
    from repro.obs import get_obs
    reg = (obs if obs is not None else get_obs()).registry
    return int(reg.counter("repro_xla_compiles_total",
                           "XLA backend compiles since process start").value)
