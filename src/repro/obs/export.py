"""Exporters: Prometheus text, JSON snapshots, raw event dumps
(DESIGN.md §8.4).

Three stable output shapes, all derivable offline from one ``ObsContext``:

  * ``prometheus_text`` — the Prometheus exposition format (text/plain
    0.0.4): counters/gauges as single samples, histograms as cumulative
    ``_bucket{le=...}`` series plus ``_sum``/``_count``.
  * ``json_snapshot`` — every series (and optionally the event log) as one
    JSON document, tagged with the API ``schema_version``.
  * ``dump_events`` — the raw event-log snapshot ``tools/trace_view.py``
    renders or converts to a Perfetto-loadable Chrome trace.
"""
from __future__ import annotations

import collections
import json
from typing import List, Optional

from repro.obs.registry import Counter, Gauge, Histogram, MetricsRegistry


def _esc_label(v) -> str:
    """Label-value escaping per the exposition format: backslash, double
    quote and newline."""
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _esc_help(s: str) -> str:
    """HELP-text escaping: backslash and newline only."""
    return s.replace("\\", "\\\\").replace("\n", "\\n")


def _fmt_labels(labels, extra: str = "") -> str:
    parts = [f'{k}="{_esc_label(v)}"' for k, v in labels]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_value(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    if v != v:
        return "NaN"
    return repr(v) if isinstance(v, float) and not v.is_integer() \
        else str(int(v))


def prometheus_text(registry: MetricsRegistry) -> str:
    """Prometheus exposition text (text/plain 0.0.4) for every series in
    the registry. Scraper-conformant: all series of one metric name are
    emitted contiguously in one group (registration can interleave
    names), each group carries exactly one ``# TYPE`` (before any sample)
    and at most one ``# HELP`` (escaped), label values are escaped, and
    histograms emit cumulative ``le`` buckets + ``+Inf`` + ``_sum`` +
    ``_count``."""
    groups: "collections.OrderedDict[str, List[object]]" = \
        collections.OrderedDict()
    for m in registry.collect():
        groups.setdefault(m.name, []).append(m)
    lines: List[str] = []
    for name, series in groups.items():
        help_text = next((m.help for m in series if m.help), "")
        if help_text:
            lines.append(f"# HELP {name} {_esc_help(help_text)}")
        lines.append(f"# TYPE {name} {series[0].kind}")
        for m in series:
            if isinstance(m, (Counter, Gauge)):
                lines.append(f"{m.name}{_fmt_labels(m.labels)} "
                             f"{_fmt_value(m.value)}")
            elif isinstance(m, Histogram):
                cum = 0
                for b, c in zip(m.buckets, m.counts):
                    cum += c
                    le = 'le="' + _fmt_value(b) + '"'
                    lines.append(f"{m.name}_bucket"
                                 f"{_fmt_labels(m.labels, le)} {cum}")
                cum += m.counts[-1]
                le_inf = 'le="+Inf"'
                lines.append(f"{m.name}_bucket"
                             f"{_fmt_labels(m.labels, le_inf)} {cum}")
                lines.append(f"{m.name}_sum{_fmt_labels(m.labels)} "
                             f"{_fmt_value(m.sum)}")
                lines.append(
                    f"{m.name}_count{_fmt_labels(m.labels)} {m.count}")
    return "\n".join(lines) + "\n"


def json_snapshot(obs, include_events: bool = False) -> dict:
    """One JSON document: every metric series (+ the event log on demand)."""
    from repro.api.spec import SCHEMA_VERSION
    series = []
    for m in obs.registry.collect():
        entry = {"name": m.name, "kind": m.kind, "labels": dict(m.labels)}
        if isinstance(m, Histogram):
            entry.update(m.snapshot())
        else:
            entry["value"] = m.value
        series.append(entry)
    out = {"schema_version": SCHEMA_VERSION, "metrics": series,
           "events_total": obs.events.total,
           "event_drops": obs.events.drops}
    if include_events:
        out["events"] = obs.events.snapshot()
    return out


def events_doc(obs) -> dict:
    """The raw trace document ``tools/trace_view.py`` consumes."""
    from repro.api.spec import SCHEMA_VERSION
    return {"schema_version": SCHEMA_VERSION,
            "clock": "perf_counter_s",
            "event_drops": obs.events.drops,
            "events": obs.events.snapshot()}


def dump_events(path: str, obs) -> None:
    with open(path, "w") as f:
        json.dump(events_doc(obs), f, indent=1)


def dump_metrics(path: str, obs,
                 include_events: Optional[bool] = None) -> None:
    """Write metrics to ``path``: ``.json`` gets the JSON snapshot,
    anything else the Prometheus text format."""
    if path.endswith(".json"):
        with open(path, "w") as f:
            json.dump(json_snapshot(
                obs, include_events=bool(include_events)), f, indent=1)
    else:
        with open(path, "w") as f:
            f.write(prometheus_text(obs.registry))
