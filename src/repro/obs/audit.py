"""repro.obs.audit — the shadow δ-auditor and failure flight recorder
(DESIGN.md §10).

The paper's whole contract is statistical: the racing index returns exact
nearest neighbors with probability ≥ 1−δ. Nothing in PRs 1–7 ever
*measures* that on served traffic — this module closes the loop:

  * ``exact_topk`` / ``exact_theta_of`` — a brute-force oracle over every
    store box (dense / rotated / sparse / sharded) built from the SAME
    exact-evaluation primitives the racing drivers use for Alg. 1 lazy
    exact evaluation, chunked so a full corpus scan stays memory-bounded.
  * ``DeltaAuditor`` — samples a configurable fraction of terminal tickets
    into a bounded per-tenant reservoir (``offer``, a cheap RNG draw plus
    array refs — nothing expensive on the serving path) and re-answers
    them exactly later (``process``/``flush``, run off the critical path:
    the plane only calls it between races or on demand). Per
    (tenant, store-epoch, tuned-vs-default) empirical error rates carry a
    Wilson/Clopper–Pearson upper confidence bound compared against the
    effective δ, exported as ``repro_audit_{sampled,mismatch}_total``
    counters and ``repro_audit_err_upper`` gauges.
  * ``FlightRecorder`` — every audit mismatch is captured as a replayable
    on-disk bundle (query arrays, QuerySpec, store epoch, tuned config,
    the ticket's trace spans, served-vs-exact ids/θ) written atomically;
    ``replay_bundle`` / ``tools/replay_audit.py`` re-run a bundle
    deterministically against a loaded index.

Mismatch definition: a served id is *correct* iff its exact θ is within a
tie tolerance of the k-th smallest exact θ (distinct slots may tie — the
1−δ contract promises *a* set of exact nearest neighbors, not a unique
one); a row fails if any served id is invalid, duplicated, or strictly
worse than the k-th exact value plus tolerance.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import json
import math
import os
import random
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.utils import get_logger

log = get_logger("repro.obs.audit")

#: flight-recorder bundle schema — bump on any layout change so
#: ``tools/replay_audit.py`` can gate.
BUNDLE_SCHEMA = 1

BUNDLE_DOC = "bundle.json"
BUNDLE_ARRAYS = "arrays.npz"

#: tie tolerance for the served-vs-exact θ comparison: θ values are f32
#: distances / d, so equal slots can differ in the last few ulps between
#: the racing driver's accumulation order and the oracle's.
DEFAULT_RTOL = 1e-4
DEFAULT_ATOL = 1e-5

_AUDIT_SKIP_REASONS = ("stale_epoch", "uncertified", "reservoir_full",
                       "namespaced", "unroutable")


# -- binomial upper confidence bounds ---------------------------------------

def _norm_ppf(p: float) -> float:
    """Inverse standard-normal CDF (Acklam's rational approximation,
    |rel err| < 1.2e-9 — no scipy in the container)."""
    if not 0.0 < p < 1.0:
        raise ValueError(f"p must be in (0, 1), got {p}")
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        q = math.sqrt(-2 * math.log(p))
        return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4])
                * q + c[5]) / ((((d[0] * q + d[1]) * q + d[2]) * q
                                + d[3]) * q + 1)
    if p > phigh:
        q = math.sqrt(-2 * math.log(1 - p))
        return -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4])
                 * q + c[5]) / ((((d[0] * q + d[1]) * q + d[2]) * q
                                 + d[3]) * q + 1)
    q = p - 0.5
    r = q * q
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4])
            * r + a[5]) * q / (((((b[0] * r + b[1]) * r + b[2]) * r
                                 + b[3]) * r + b[4]) * r + 1)


def wilson_upper(failures: int, n: int, confidence: float = 0.95) -> float:
    """One-sided Wilson-score upper bound on a binomial proportion: the
    largest error rate still consistent (at ``confidence``) with seeing
    ``failures`` δ-failures in ``n`` audited rows. 1.0 when nothing has
    been audited yet — no evidence means no claim."""
    if n <= 0:
        return 1.0
    if failures < 0 or failures > n:
        raise ValueError(f"failures must be in [0, {n}], got {failures}")
    z = _norm_ppf(confidence)
    p = failures / n
    z2 = z * z
    center = p + z2 / (2 * n)
    rad = z * math.sqrt(p * (1 - p) / n + z2 / (4 * n * n))
    return min(1.0, (center + rad) / (1 + z2 / n))


def clopper_pearson_upper(failures: int, n: int,
                          confidence: float = 0.95) -> float:
    """Exact (Clopper–Pearson) one-sided upper bound, via bisection on the
    binomial CDF in log space. Slower than ``wilson_upper`` but exact —
    the estimator default stays Wilson; this is the cross-check."""
    if n <= 0:
        return 1.0
    if failures < 0 or failures > n:
        raise ValueError(f"failures must be in [0, {n}], got {failures}")
    if failures >= n:
        return 1.0
    alpha = 1.0 - confidence
    log_comb = [math.lgamma(n + 1) - math.lgamma(i + 1)
                - math.lgamma(n - i + 1) for i in range(failures + 1)]

    def cdf(p: float) -> float:
        if p <= 0.0:
            return 1.0
        if p >= 1.0:
            return 0.0
        lp, l1p = math.log(p), math.log1p(-p)
        return sum(math.exp(lc + i * lp + (n - i) * l1p)
                   for i, lc in enumerate(log_comb))

    lo, hi = failures / n, 1.0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if cdf(mid) > alpha:
            lo = mid
        else:
            hi = mid
    return hi


# -- exact oracle over every store box ---------------------------------------

def _dense_theta(store, qs_dev, sel: np.ndarray) -> np.ndarray:
    """Exact θ of (Q, B) local slots against prepared queries — the same
    ``_dense_exact_theta`` the racing drivers use for lazy exact eval."""
    import jax.numpy as jnp

    from repro.index.batched_race import _dense_exact_theta
    th = _dense_exact_theta(store.x, qs_dev,
                            jnp.asarray(sel, jnp.int32),
                            store.cfg.metric, store.d)
    return np.asarray(th, np.float64)


def _sparse_ds(store):
    from repro.core.datasets import SparseDataset
    return SparseDataset(indices=store.indices, values=store.values,
                         nnz=store.nnz, d=store.d)


def _merge_topk(cand_i: np.ndarray, cand_v: np.ndarray,
                k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-row top-k of a (Q, C) candidate pool (C >= k), ascending θ."""
    if cand_v.shape[1] > k:
        part = np.argpartition(cand_v, k - 1, axis=1)[:, :k]
        cand_v = np.take_along_axis(cand_v, part, axis=1)
        cand_i = np.take_along_axis(cand_i, part, axis=1)
    order = np.argsort(cand_v, axis=1, kind="stable")
    return (np.take_along_axis(cand_i, order, axis=1),
            np.take_along_axis(cand_v, order, axis=1))


def _dense_topk(store, queries, k: int) -> Tuple[np.ndarray, np.ndarray]:
    import jax.numpy as jnp
    qs_dev = jnp.asarray(store.prepare_queries(
        np.asarray(queries, np.float32)))
    Q = int(qs_dev.shape[0])
    cap = store.capacity
    alive = np.asarray(store.alive)
    kk = min(k, cap)
    d_pad = int(store.x.shape[1])
    # bound the (Q, B, d_pad) gather the exact-θ kernel materialises
    chunk = int(max(kk, min(cap, (1 << 22) // max(d_pad, 1))))
    best_i = np.full((Q, kk), -1, np.int64)
    best_v = np.full((Q, kk), np.inf, np.float64)
    for s in range(0, cap, chunk):
        slots = np.arange(s, min(s + chunk, cap))
        sel = np.broadcast_to(slots[None, :], (Q, len(slots)))
        th = _dense_theta(store, qs_dev, np.ascontiguousarray(sel))
        th = np.where(alive[slots][None, :], th, np.inf)
        best_i, best_v = _merge_topk(
            np.concatenate([best_i, sel], axis=1),
            np.concatenate([best_v, th], axis=1), kk)
    return best_i, best_v


def _sparse_theta(store, q_idx, q_val, arm_idx: np.ndarray) -> np.ndarray:
    """Exact sparse θ of (B,) slots for ONE query row (alive-agnostic)."""
    import jax.numpy as jnp

    from repro.core.bmo_nn import sparse_exact_theta
    th = sparse_exact_theta(_sparse_ds(store), jnp.asarray(q_idx),
                            jnp.asarray(q_val), jnp.asarray(arm_idx))
    return np.asarray(th, np.float64)


def _sparse_topk(store, queries, k: int) -> Tuple[np.ndarray, np.ndarray]:
    q_idx, q_val, _q_nnz = (np.asarray(a) for a in queries)
    Q = q_idx.shape[0]
    cap = store.capacity
    alive = np.asarray(store.alive)
    kk = min(k, cap)
    chunk = max(kk, min(cap, 8192))
    best_i = np.full((Q, kk), -1, np.int64)
    best_v = np.full((Q, kk), np.inf, np.float64)
    for s in range(0, cap, chunk):
        slots = np.arange(s, min(s + chunk, cap))
        th = np.stack([_sparse_theta(store, q_idx[i], q_val[i], slots)
                       for i in range(Q)])
        th = np.where(alive[slots][None, :], th, np.inf)
        sel = np.broadcast_to(slots[None, :], (Q, len(slots)))
        best_i, best_v = _merge_topk(
            np.concatenate([best_i, sel], axis=1),
            np.concatenate([best_v, th], axis=1), kk)
    return best_i, best_v


def exact_topk(store, queries, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Ground-truth top-k over any store box: (Q, k) GLOBAL slot ids
    (ascending exact θ) and the θ values. Dead slots never appear; ids are
    −1 (θ = inf) past the live count. Sharded stores merge per-shard exact
    candidates exactly like the serving merge, gid = shard·stride + local."""
    if hasattr(store, "shards"):
        stride = store.stride
        pools_i, pools_v = [], []
        for si, shard in enumerate(store.shards):
            ids, vals = exact_topk(shard, queries, k)
            gids = np.where(ids >= 0, si * stride + ids, -1)
            pools_i.append(gids)
            pools_v.append(vals)
        return _merge_topk(np.concatenate(pools_i, axis=1),
                           np.concatenate(pools_v, axis=1), k)
    if store.kind == "sparse":
        ids, vals = _sparse_topk(store, queries, k)
    else:
        ids, vals = _dense_topk(store, queries, k)
    ids = np.where(np.isfinite(vals), ids, -1)
    if ids.shape[1] < k:            # store smaller than k: pad with -1/inf
        pad = k - ids.shape[1]
        ids = np.concatenate(
            [ids, np.full((ids.shape[0], pad), -1, np.int64)], axis=1)
        vals = np.concatenate(
            [vals, np.full((vals.shape[0], pad), np.inf)], axis=1)
    return ids, vals


def exact_theta_of(store, queries, ids: np.ndarray) -> np.ndarray:
    """Exact θ of arbitrary (Q, k) GLOBAL slot ids; inf where an id is
    invalid (−1 / out of range) or tombstoned."""
    import jax.numpy as jnp
    ids = np.asarray(ids, np.int64)
    Q, k = ids.shape
    out = np.full((Q, k), np.inf)
    if hasattr(store, "shards"):
        stride = store.stride
        valid = (ids >= 0) & (ids < store.capacity)
        si_of = np.where(valid, ids // stride, -1)
        local = np.where(valid, ids % stride, 0)
        for si, shard in enumerate(store.shards):
            m = si_of == si
            if not m.any():
                continue
            th = exact_theta_of(shard, queries, np.where(m, local, 0))
            out[m] = th[m]
        return out
    alive = np.asarray(store.alive)
    valid = (ids >= 0) & (ids < store.capacity)
    valid &= alive[np.where(valid, ids, 0)]
    sel = np.where(valid, ids, 0)
    if store.kind == "sparse":
        q_idx, q_val, _ = (np.asarray(a) for a in queries)
        th = np.stack([_sparse_theta(store, q_idx[i], q_val[i], sel[i])
                       for i in range(Q)])
    else:
        qs_dev = jnp.asarray(store.prepare_queries(
            np.asarray(queries, np.float32)))
        th = _dense_theta(store, qs_dev, sel)
    out[valid] = th[valid]
    return out


@dataclasses.dataclass(frozen=True)
class AuditCheck:
    """One oracle comparison: served ids vs the exact answer."""

    row_mismatch: np.ndarray     # (Q,)   bool — row violated the contract
    bad: np.ndarray              # (Q, k) bool — per served id
    served_theta: np.ndarray     # (Q, k) exact θ of the served ids
    exact_ids: np.ndarray        # (Q, k) oracle top-k (global ids)
    exact_vals: np.ndarray       # (Q, k) oracle θ (ascending)

    @property
    def mismatches(self) -> int:
        return int(self.row_mismatch.sum())


def check_topk(store, queries, served_ids, k: int, *,
               rtol: float = DEFAULT_RTOL,
               atol: float = DEFAULT_ATOL) -> AuditCheck:
    """Audit one served batch against the exact oracle. A served id passes
    iff it is a live slot whose exact θ is ≤ the k-th exact θ + tie
    tolerance; a row additionally fails on duplicated served ids (a
    duplicate means some true neighbor is missing)."""
    served_ids = np.asarray(served_ids, np.int64)[:, :k]
    exact_ids, exact_vals = exact_topk(store, queries, k)
    kth = exact_vals[:, min(k, exact_vals.shape[1]) - 1]
    served_theta = exact_theta_of(store, queries, served_ids)
    tol = atol + rtol * np.abs(np.where(np.isfinite(kth), kth, 0.0))
    bad = served_theta > (kth + tol)[:, None]
    row_bad = bad.any(axis=1)
    for i in range(served_ids.shape[0]):
        if len(np.unique(served_ids[i])) < served_ids.shape[1]:
            row_bad[i] = True
    return AuditCheck(row_mismatch=row_bad, bad=bad,
                      served_theta=served_theta,
                      exact_ids=exact_ids, exact_vals=exact_vals)


# -- flight recorder ---------------------------------------------------------

def _spec_doc(spec) -> dict:
    """JSON-safe QuerySpec view (arrays/objects are summarised, never
    serialised — the bundle's arrays.npz carries the data that matters)."""
    return {
        "k": spec.k, "mode": spec.mode, "impl": spec.impl,
        "delta": spec.delta, "max_rounds": spec.max_rounds,
        "eliminate": spec.eliminate, "warm_start": spec.warm_start,
        "cache": spec.cache, "use_tuned": spec.use_tuned,
        "deadline": repr(spec.deadline) if spec.deadline else None,
        "budget": repr(spec.budget) if spec.budget else None,
        "prior_hint": (None if spec.prior_hint is None
                       else f"array{np.asarray(spec.prior_hint).shape}"),
    }


def ticket_events(obs, trace_id: str) -> List[dict]:
    """The ticket's trace events plus the race-session spans it joined
    (the ``plane.admit`` instant carries ``session=<sid>`` as the join
    key, DESIGN.md §8.3) — the bundle's why-did-this-certify evidence."""
    if obs is None:
        return []
    evs = obs.events.snapshot()
    mine = [e for e in evs if e.get("trace") == trace_id]
    sids = {e.get("attrs", {}).get("session") for e in mine}
    sids.discard(None)
    race = [e for e in evs if e.get("trace") in sids]
    return mine + race


class FlightRecorder:
    """Writes one replayable bundle directory per audit mismatch:
    ``bundle.json`` (metadata, spec, tuned config, mismatch rows, trace
    events) + ``arrays.npz`` (queries, served/exact ids and θ). Bundles
    are staged in a ``.tmp`` sibling and ``os.replace``d into place, so a
    reader never sees a half-written bundle (same atomic-write idiom as
    the tuned.json sidecar)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._seq = itertools.count()

    def record(self, *, check: AuditCheck, queries, served_ids, served_vals,
               k: int, delta: float, trace_id: str = "", tenant: str = "",
               store_epoch: int = 0, contract: str = "default",
               store_kind: str = "", metric: str = "", spec=None,
               tuned=None, obs=None) -> str:
        """Capture one mismatch. Returns the bundle directory path."""
        safe = "".join(ch if ch.isalnum() or ch in "._-" else "_"
                       for ch in (trace_id or "anon"))
        final = os.path.join(self.root,
                             f"audit-{next(self._seq):04d}-{safe}")
        while os.path.exists(final):       # seq restarts across processes
            final = os.path.join(self.root,
                                 f"audit-{next(self._seq):04d}-{safe}")
        tmp = final + f".tmp-{os.getpid()}"
        os.makedirs(tmp)
        arrays = {
            "served_ids": np.asarray(served_ids, np.int64),
            "served_vals": np.asarray(served_vals, np.float64),
            "served_theta": check.served_theta,
            "exact_ids": check.exact_ids,
            "exact_vals": check.exact_vals,
            "bad": check.bad,
        }
        if isinstance(queries, tuple):
            q_idx, q_val, q_nnz = (np.asarray(a) for a in queries)
            arrays.update(q_idx=q_idx, q_val=q_val, q_nnz=q_nnz)
        else:
            arrays["queries"] = np.asarray(queries)
        np.savez(os.path.join(tmp, BUNDLE_ARRAYS), **arrays)
        doc = {
            "schema_version": BUNDLE_SCHEMA,
            "trace_id": trace_id,
            "tenant": tenant,
            "store_epoch": int(store_epoch),
            "contract": contract,
            "k": int(k),
            "delta": float(delta),
            "store_kind": store_kind,
            "metric": metric,
            "sparse_queries": isinstance(queries, tuple),
            "mismatch_rows": np.nonzero(check.row_mismatch)[0].tolist(),
            "spec": _spec_doc(spec) if spec is not None else None,
            "tuned": (tuned.to_dict() if tuned is not None
                      and hasattr(tuned, "to_dict") else None),
            "written_at": time.time(),
            "events": ticket_events(obs, trace_id),
        }
        with open(os.path.join(tmp, BUNDLE_DOC), "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True, default=str)
        os.replace(tmp, final)
        return final


def load_bundle(path: str) -> Tuple[dict, dict]:
    """(doc, arrays) of one flight-recorder bundle directory."""
    with open(os.path.join(path, BUNDLE_DOC)) as f:
        doc = json.load(f)
    if doc.get("schema_version") != BUNDLE_SCHEMA:
        raise ValueError(
            f"bundle schema {doc.get('schema_version')!r} != "
            f"{BUNDLE_SCHEMA} (bundle {path})")
    with np.load(os.path.join(path, BUNDLE_ARRAYS)) as z:
        arrays = {k: z[k] for k in z.files}
    return doc, arrays


def replay_bundle(index, path: str) -> dict:
    """Re-run a bundle against a loaded ``repro.api.Index``: recompute the
    exact oracle on the CURRENT store and re-check the recorded served
    ids. ``reproduced`` is True when the same rows mismatch again — on an
    index with the same content this is deterministic (the oracle has no
    randomness); on a mutated store ``epoch_match=False`` flags that the
    ground truth itself may have moved."""
    doc, arrays = load_bundle(path)
    queries = ((arrays["q_idx"], arrays["q_val"], arrays["q_nnz"])
               if doc["sparse_queries"] else arrays["queries"])
    check = check_topk(index.store, queries, arrays["served_ids"],
                       int(doc["k"]))
    now_rows = np.nonzero(check.row_mismatch)[0].tolist()
    recorded = list(doc["mismatch_rows"])
    return {
        "bundle": path,
        "schema_version": BUNDLE_SCHEMA,
        "reproduced": now_rows == recorded,
        "mismatch_rows_recorded": recorded,
        "mismatch_rows_now": now_rows,
        "exact_ids_match": bool(
            (check.exact_ids == arrays["exact_ids"]).all()),
        "store_epoch_recorded": doc["store_epoch"],
        "store_epoch_now": index.epoch,
        "epoch_match": doc["store_epoch"] == index.epoch,
        "delta": doc["delta"],
        "k": doc["k"],
        "trace_id": doc["trace_id"],
    }


# -- the shadow auditor ------------------------------------------------------

@dataclasses.dataclass
class _AuditItem:
    """One sampled terminal ticket, queued for off-path oracle work."""

    trace_id: str
    tenant: str
    store_epoch: int
    contract: str                 # "tuned" | "default"
    k: int
    delta: float
    queries: object               # (Q, d) dense or (q_idx, q_val, q_nnz)
    served_ids: np.ndarray        # (Q, k)
    served_vals: np.ndarray       # (Q, k)
    spec: object = None
    namespace: Optional[str] = None   # fleet namespace; None = default

    @property
    def rows(self) -> int:
        return int(self.served_ids.shape[0])


@dataclasses.dataclass
class _KeyState:
    """Empirical error-rate estimator for one (namespace, tenant,
    store-epoch, contract) key: audited rows, observed δ-failures, the
    tightest δ any audited query promised."""

    sampled: int = 0
    mismatches: int = 0
    delta: float = 1.0

    def err_upper(self, confidence: float) -> float:
        return wilson_upper(self.mismatches, self.sampled, confidence)


class DeltaAuditor:
    """Shadow δ-auditor over one ``repro.api.Index`` — or, given a
    ``router`` (a ``repro.fleet.Fleet``), over every namespace a fleet
    plane serves.

    ``offer`` runs ON the serving path and must stay cheap: one RNG draw,
    then array copies into a bounded per-tenant reservoir (overflow drops
    the oldest pending item, counted — backpressure by forgetting audits,
    never by stalling serving). ``process``/``flush`` run the brute-force
    oracle OFF the critical path; namespaced items resolve their backing
    index through the router at oracle time (transparent reload-on-access,
    the plane's own routing contract). Items whose store epoch fell behind
    a mutation are skipped (the ground truth they were served against no
    longer exists) and counted as ``stale_epoch``; items whose namespace
    was dropped in the meantime count as ``unroutable``."""

    def __init__(self, index=None, *, router=None, rate: float, obs=None,
                 recorder: Optional[FlightRecorder] = None, seed: int = 0,
                 reservoir: int = 256, confidence: float = 0.95,
                 rtol: float = DEFAULT_RTOL, atol: float = DEFAULT_ATOL,
                 labels: Optional[dict] = None):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"audit rate must be in [0, 1], got {rate}")
        if reservoir < 1:
            raise ValueError(f"reservoir must be >= 1, got {reservoir}")
        if not 0.5 <= confidence < 1.0:
            raise ValueError(
                f"confidence must be in [0.5, 1), got {confidence}")
        if index is None and router is None:
            raise ValueError("DeltaAuditor needs an index, a router "
                             "(fleet), or both")
        self.index = index
        self.router = router
        self.rate = rate
        self.obs = obs
        self.recorder = recorder
        self.confidence = confidence
        self.rtol, self.atol = rtol, atol
        self._rng = random.Random(seed)
        self._labels = dict(labels or {})
        self._reservoir = reservoir
        self._pending: "collections.OrderedDict[str, collections.deque]" = \
            collections.OrderedDict()
        self._states: Dict[Tuple[str, str, int, str], _KeyState] = {}
        self.bundles: List[str] = []
        self.offered = 0              # terminal tickets seen
        self.sampled_tickets = 0      # tickets drawn into the reservoir
        self.dropped = 0              # items evicted by reservoir overflow
        self.skipped: Dict[str, int] = {r: 0 for r in _AUDIT_SKIP_REASONS}
        if obs is not None:
            reg = obs.registry
            self._c_dropped = reg.counter(
                "repro_audit_dropped_total",
                "sampled audits evicted by reservoir overflow",
                **self._labels)
            self._g_pending = reg.gauge(
                "repro_audit_pending",
                "audited rows waiting in the shadow reservoir",
                **self._labels)
            self._h_ms = reg.histogram(
                "repro_audit_ms", "oracle wall time per audited item (ms)",
                **self._labels)
        else:
            self._c_dropped = self._g_pending = self._h_ms = None

    # -- serving-path half ---------------------------------------------------

    def offer(self, *, trace_id: str, tenant: str, store_epoch: int,
              contract: str, k: int, delta: float, queries, served_ids,
              served_vals, spec=None,
              namespace: Optional[str] = None) -> bool:
        """Maybe sample one terminal ticket into the reservoir. Cheap by
        construction — a Bernoulli(rate) draw plus array copies; all
        oracle work waits for ``process``. Returns True iff sampled."""
        self.offered += 1
        if self._rng.random() >= self.rate:
            return False
        if contract not in ("tuned", "default"):
            raise ValueError(
                f"contract must be 'tuned' or 'default', got {contract!r}")
        if isinstance(queries, tuple):
            q = tuple(np.array(a) for a in queries)
        else:
            q = np.array(queries)
        item = _AuditItem(
            trace_id=trace_id, tenant=tenant, store_epoch=int(store_epoch),
            contract=contract, k=int(k), delta=float(delta), queries=q,
            served_ids=np.array(served_ids, np.int64),
            served_vals=np.array(served_vals), spec=spec,
            namespace=namespace)
        dq = self._pending.setdefault(tenant, collections.deque())
        if len(dq) >= self._reservoir:
            dq.popleft()
            self.dropped += 1
            self.skipped["reservoir_full"] += 1
            if self._c_dropped is not None:
                self._c_dropped.inc()
        dq.append(item)
        self.sampled_tickets += 1
        if self._g_pending is not None:
            self._g_pending.set(self.pending)
        return True

    def note_skip(self, reason: str) -> None:
        """Count a terminal ticket the plane chose not to audit (e.g. a
        partial deadline/budget result — only fully-certified answers
        claim the full 1-δ contract)."""
        self.skipped[reason] = self.skipped.get(reason, 0) + 1

    @property
    def pending(self) -> int:
        return sum(len(dq) for dq in self._pending.values())

    # -- off-path half -------------------------------------------------------

    def _pop_round_robin(self) -> Optional[_AuditItem]:
        for tenant in list(self._pending):
            dq = self._pending[tenant]
            if not dq:
                del self._pending[tenant]
                continue
            item = dq.popleft()
            self._pending.move_to_end(tenant)   # fairness across tenants
            if not dq:
                del self._pending[tenant]
            return item
        return None

    def _key_metrics(self, key):
        namespace, tenant, epoch, contract = key
        if self.obs is None:
            return None, None, None
        reg = self.obs.registry
        lbl = dict(self._labels, tenant=tenant, store_epoch=str(epoch),
                   contract=contract)
        if namespace:
            lbl["namespace"] = namespace
        return (reg.counter("repro_audit_sampled_total",
                            "query rows shadow-audited", **lbl),
                reg.counter("repro_audit_mismatch_total",
                            "audited rows that violated the 1-δ contract",
                            **lbl),
                reg.gauge("repro_audit_err_upper",
                          "Wilson upper confidence bound on the empirical "
                          "error rate (compare against δ)", **lbl))

    def _resolve_index(self, item: _AuditItem):
        """The backing index the item's ground truth lives in: the bound
        default for un-namespaced items, the router's (possibly lazily
        reloaded) handle for namespaced ones. None when unroutable."""
        if item.namespace is None:
            return self.index
        if self.router is None:
            return None
        try:
            return self.router.resolve(item.namespace)
        except KeyError:
            return None                     # namespace dropped since

    def _audit(self, item: _AuditItem, index) -> bool:
        """Oracle one item against its resolved index. Returns True iff a
        mismatch was found."""
        t0 = time.perf_counter()
        check = check_topk(index.store, item.queries, item.served_ids,
                           item.k, rtol=self.rtol, atol=self.atol)
        if self._h_ms is not None:
            self._h_ms.observe((time.perf_counter() - t0) * 1e3)
        key = (item.namespace or "", item.tenant, item.store_epoch,
               item.contract)
        state = self._states.setdefault(key, _KeyState())
        state.sampled += item.rows
        state.mismatches += check.mismatches
        state.delta = min(state.delta, item.delta)
        c_sampled, c_mismatch, g_upper = self._key_metrics(key)
        if c_sampled is not None:
            c_sampled.inc(item.rows)
            if check.mismatches:
                c_mismatch.inc(check.mismatches)
            g_upper.set(state.err_upper(self.confidence))
        if check.mismatches == 0:
            if self.obs is not None:
                self.obs.tracer.instant(
                    "audit.pass", trace=item.trace_id, rows=item.rows,
                    store_epoch=item.store_epoch, contract=item.contract)
            return False
        bundle = None
        if self.recorder is not None:
            bundle = self.recorder.record(
                check=check, queries=item.queries,
                served_ids=item.served_ids, served_vals=item.served_vals,
                k=item.k, delta=item.delta, trace_id=item.trace_id,
                tenant=item.tenant, store_epoch=item.store_epoch,
                contract=item.contract, store_kind=index.kind,
                metric=index.cfg.metric, spec=item.spec,
                tuned=index.tuned, obs=self.obs)
            self.bundles.append(bundle)
        log.bind(trace=item.trace_id, tenant=item.tenant).warning(
            "delta-audit MISMATCH: %d/%d rows violate the 1-delta contract "
            "(delta=%g, store_epoch=%d, contract=%s)%s",
            check.mismatches, item.rows, item.delta, item.store_epoch,
            item.contract, f" -> bundle {bundle}" if bundle else "")
        if self.obs is not None:
            self.obs.tracer.instant(
                "audit.mismatch", trace=item.trace_id,
                rows=item.rows, mismatches=check.mismatches,
                store_epoch=item.store_epoch, contract=item.contract,
                bundle=bundle or "")
        return True

    def process(self, limit: Optional[int] = None) -> int:
        """Run the oracle on up to ``limit`` pending items (None = all).
        Call this OFF the serving critical path — the plane does so only
        when no race group is active, or from an explicit flush. Returns
        the number of items processed (audited or skipped)."""
        done = 0
        while limit is None or done < limit:
            item = self._pop_round_robin()
            if item is None:
                break
            done += 1
            index = self._resolve_index(item)
            if index is None:
                self.skipped["unroutable"] += 1
                if self.obs is not None:
                    self.obs.tracer.instant(
                        "audit.skip", trace=item.trace_id,
                        reason="unroutable",
                        namespace=item.namespace or "")
                continue
            if item.store_epoch != index.epoch:
                self.skipped["stale_epoch"] += 1
                if self.obs is not None:
                    self.obs.tracer.instant(
                        "audit.skip", trace=item.trace_id,
                        reason="stale_epoch",
                        item_epoch=item.store_epoch,
                        index_epoch=index.epoch)
                continue
            self._audit(item, index)
        if self._g_pending is not None:
            self._g_pending.set(self.pending)
        return done

    def flush(self) -> int:
        """Drain the whole reservoir through the oracle."""
        return self.process(None)

    # -- reporting -----------------------------------------------------------

    @property
    def sampled_rows(self) -> int:
        return sum(s.sampled for s in self._states.values())

    @property
    def mismatch_rows(self) -> int:
        return sum(s.mismatches for s in self._states.values())

    def err_upper(self) -> float:
        """Global Wilson upper bound over every audited row."""
        return wilson_upper(self.mismatch_rows, self.sampled_rows,
                            self.confidence)

    def summary(self) -> dict:
        """JSON-safe estimator state (the health snapshot's audit section):
        per-key counts, error rates, upper bounds, and whether each key's
        bound still clears its effective δ."""
        keys = []
        for (ns, tenant, epoch, contract), st in sorted(
                self._states.items()):
            upper = st.err_upper(self.confidence)
            keys.append({
                "namespace": ns,
                "tenant": tenant,
                "store_epoch": epoch,
                "contract": contract,
                "sampled": st.sampled,
                "mismatches": st.mismatches,
                "err_rate": (st.mismatches / st.sampled
                             if st.sampled else 0.0),
                "err_upper": upper,
                "delta": st.delta,
                # the bound needs ~log(1-conf)/log(1-δ) clean rows before
                # it can dip under δ — until then "not yet violated" is
                # the honest reading, so gate on observed failures
                "violated": st.mismatches > 0 and upper > st.delta,
            })
        return {
            "rate": self.rate,
            "confidence": self.confidence,
            "method": "wilson",
            "offered": self.offered,
            "sampled_tickets": self.sampled_tickets,
            "sampled_rows": self.sampled_rows,
            "mismatch_rows": self.mismatch_rows,
            "err_upper": self.err_upper(),
            "pending": self.pending,
            "dropped": self.dropped,
            "skipped": dict(self.skipped),
            "bundles": list(self.bundles),
            "keys": keys,
        }
