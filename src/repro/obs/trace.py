"""Race-level trace spans over the event log (DESIGN.md §8.3).

A *span* is one timed phase of one trace (``ph="X"`` in the Chrome trace
event model); an *instant* is a point event (``ph="i"``). Every serving
ticket gets a trace id at submit (``p<plane>.t<ticket>``) that is
propagated through its whole lifecycle — submit → queue → admit → each
race epoch → terminal — so ``tools/trace_view.py`` can reconstruct exactly
where any individual query's pulls, epochs and wall-time went. Race
sessions record under their own ``s<N>`` trace id; the ticket's ``admit``
event carries ``session=<sid>`` as the join key.

Spans are recorded *at end* (one event each, into the bounded ring), so an
abandoned span costs nothing. All timing is ``time.perf_counter()`` on one
clock; exporters convert to microseconds.
"""
from __future__ import annotations

import itertools
import time
from typing import Optional

from repro.obs.registry import EventLog

_ids = itertools.count()


def new_trace_id(prefix: str) -> str:
    """Process-unique trace id: ``<prefix>-<N>``."""
    return f"{prefix}-{next(_ids)}"


class Span:
    """An open span; ``end()`` records it. Usable as a context manager."""

    __slots__ = ("_tracer", "name", "trace", "t0", "attrs", "_open")

    def __init__(self, tracer: "Tracer", name: str, trace: Optional[str],
                 attrs: dict):
        self._tracer = tracer
        self.name = name
        self.trace = trace
        self.attrs = attrs
        self.t0 = time.perf_counter()
        self._open = True

    def end(self, **attrs) -> None:
        if not self._open:          # idempotent: double-end records once
            return
        self._open = False
        if attrs:
            self.attrs.update(attrs)
        self._tracer.complete(self.name, self.t0,
                              time.perf_counter() - self.t0,
                              trace=self.trace, **self.attrs)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        self.end()


class _NullSpan:
    """No-op span handed out by a disabled tracer."""

    __slots__ = ()
    name = trace = None
    t0 = 0.0
    attrs: dict = {}

    def end(self, **attrs) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        pass


NULL_SPAN = _NullSpan()


class Tracer:
    """Records spans/instants into an ``EventLog``. Disabled ⇒ every call
    is a cheap no-op (the ≤2% overhead budget's off switch, §8.5)."""

    def __init__(self, log: EventLog, enabled: bool = True):
        self.log = log
        self.enabled = enabled

    def start(self, name: str, trace: Optional[str] = None, **attrs):
        """Open a span whose end is at a different call site (e.g. the
        queue span: opened at submit, ended at admit)."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, trace, attrs)

    def span(self, name: str, trace: Optional[str] = None, **attrs):
        """Context-manager form for lexically scoped phases."""
        return self.start(name, trace, **attrs)

    def complete(self, name: str, t0: float, dur: float,
                 trace: Optional[str] = None, **attrs) -> None:
        """Record an already-timed span (explicit t0/duration, seconds)."""
        if not self.enabled:
            return
        self.log.append({"ph": "X", "name": name, "trace": trace,
                         "ts": t0, "dur": dur, "attrs": attrs})

    def instant(self, name: str, trace: Optional[str] = None,
                **attrs) -> None:
        if not self.enabled:
            return
        self.log.append({"ph": "i", "name": name, "trace": trace,
                         "ts": time.perf_counter(), "dur": 0.0,
                         "attrs": attrs})
