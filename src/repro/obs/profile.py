"""Kernel profiling hooks (DESIGN.md §8.5): attribute bench time to
kernel vs host merge vs scheduler.

Two annotation layers, chosen by where the code runs:

  * ``named_scope(name)`` — INSIDE jitted code: names the HLO ops it wraps
    (``jax.named_scope``), so a ``jax.profiler`` device trace shows
    ``repro.fused_epoch_pull`` / ``repro.block_pull_multi`` as first-class
    slices instead of anonymous fusions. Zero runtime cost (trace-time
    only).
  * ``annotate(name)`` — HOST-side epoch loops: a
    ``jax.profiler.TraceAnnotation`` visible on the Python thread track of
    a Perfetto capture, gated to a null context when the profiler API is
    unavailable.

Per-launch coord-op accounting is host-side (jitted code is untouched):
the epoch drivers know exactly how many kernel launches an epoch issued
and what each cost, and fold that into the registry via
``record_kernel_launch`` at the epoch boundary.
"""
from __future__ import annotations

import contextlib

import jax

named_scope = jax.named_scope

try:
    _TraceAnnotation = jax.profiler.TraceAnnotation
except AttributeError:                        # pragma: no cover - old jax
    _TraceAnnotation = None


def annotate(name: str):
    """Host-side profiler annotation (null context without the API)."""
    if _TraceAnnotation is None:              # pragma: no cover - old jax
        return contextlib.nullcontext()
    return _TraceAnnotation(name)


def record_kernel_launch(obs, kernel: str, *, launches: int,
                         coord_ops: float, pulls: float = 0.0) -> None:
    """Fold one epoch's kernel-launch accounting into the registry:
    ``launches`` device programs of ``kernel`` paying ``coord_ops``
    coordinate reads total (``pulls`` block-pulls, when known)."""
    if not obs.enabled or launches <= 0:
        return
    obs.registry.counter(
        "repro_kernel_launches_total",
        "device kernel launches issued by the racing drivers",
        kernel=kernel).inc(launches)
    obs.registry.counter(
        "repro_kernel_coord_ops_total",
        "coordinate reads paid inside kernel launches",
        kernel=kernel).inc(max(coord_ops, 0.0))
    if pulls:
        obs.registry.counter(
            "repro_kernel_pulls_total",
            "block pulls executed inside kernel launches",
            kernel=kernel).inc(max(pulls, 0.0))
