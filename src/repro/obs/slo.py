"""repro.obs.slo — declarative SLOs with multi-window burn-rate alerting
(DESIGN.md §10.3).

An ``SLO`` names a *bad-event ratio* (recall mismatches / audited rows,
slow requests / completed, shed / submitted) and an error budget — for the
recall SLO the budget IS the paper's δ. The ``SLOEngine`` consumes
cumulative (bad, total) pairs per observation, keeps a short history, and
evaluates each SLO's ``BurnRule``s the SRE way: burn rate = (bad fraction
over a window) / budget, and a rule fires only when BOTH its long and its
short window burn exceed the factor — the long window keeps alerts
significant, the short window makes them reset quickly once the problem
stops.

Firing and resolving alerts land in the EventLog (``slo.alert`` /
``slo.resolve`` instants), in ``repro_slo_alerts_total`` /
``repro_slo_burn`` metrics, and in an ``AlertSink`` that
``serve/scale.py``'s ``RecallGuardPolicy`` consumes — a burning recall SLO
automatically forces the ``use_tuned=False`` fallback and flags an
``Index.tune()`` re-race: observability driving an action, not a
dashboard.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

from repro.utils import get_logger

log = get_logger("repro.obs.slo")

SEVERITIES = ("page", "ticket")


@dataclasses.dataclass(frozen=True)
class BurnRule:
    """One multi-window burn-rate rule: fire when the budget burns at
    ≥ ``factor``× over BOTH the long and the short window."""

    long_s: float
    short_s: float
    factor: float
    severity: str = "page"

    def __post_init__(self):
        if self.long_s <= 0 or self.short_s <= 0:
            raise ValueError("burn-rule windows must be > 0, got "
                             f"({self.long_s}, {self.short_s})")
        if self.short_s > self.long_s:
            raise ValueError(
                f"short window ({self.short_s}s) must not exceed the long "
                f"window ({self.long_s}s)")
        if self.factor <= 0:
            raise ValueError(f"factor must be > 0, got {self.factor}")
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r} "
                             f"(want one of {SEVERITIES})")

    @property
    def name(self) -> str:
        return f"{self.factor:g}x/{self.long_s:g}s"


#: default rule pair, scaled down from the classic SRE 1h/5m + 6h/30m
#: ladder to serving-loop timescales (the engine is observation-driven —
#: wall windows only matter relative to how often ``observe`` runs)
DEFAULT_RULES = (
    BurnRule(long_s=60.0, short_s=5.0, factor=10.0, severity="page"),
    BurnRule(long_s=300.0, short_s=30.0, factor=2.0, severity="ticket"),
)


@dataclasses.dataclass(frozen=True)
class SLO:
    """One declarative objective over a named (bad, total) ratio signal.

    ``budget`` is the allowed bad fraction: δ for the recall SLO, the
    tolerated slow fraction for a latency SLO, the tolerated shed
    fraction for admission."""

    name: str
    source: str                       # signal key in observe()'s dict
    budget: float                     # allowed bad-event fraction
    description: str = ""
    rules: Tuple[BurnRule, ...] = DEFAULT_RULES
    min_events: int = 1               # total events a window needs to fire

    def __post_init__(self):
        if not 0.0 < self.budget < 1.0:
            raise ValueError(
                f"budget must be in (0, 1), got {self.budget} "
                f"(SLO {self.name!r})")
        if not self.rules:
            raise ValueError(f"SLO {self.name!r} needs at least one rule")
        if self.min_events < 1:
            raise ValueError(
                f"min_events must be >= 1, got {self.min_events}")


@dataclasses.dataclass
class Alert:
    """One firing (or resolving) burn-rate alert."""

    slo: str
    severity: str
    rule: str                         # BurnRule.name
    burn_long: float
    burn_short: float
    bad_frac: float                   # long-window bad fraction
    budget: float
    at: float                         # engine clock timestamp
    active: bool = True               # False = this is the resolve edge


class AlertSink:
    """Collects alerts; ``active()`` is the set currently firing (keyed by
    (slo, rule)), which ``RecallGuardPolicy`` consumes."""

    def __init__(self):
        self.alerts: List[Alert] = []
        self._active: Dict[Tuple[str, str], Alert] = {}

    def emit(self, alert: Alert) -> None:
        self.alerts.append(alert)
        key = (alert.slo, alert.rule)
        if alert.active:
            self._active[key] = alert
        else:
            self._active.pop(key, None)

    def active(self, slo: Optional[str] = None) -> List[Alert]:
        return [a for a in self._active.values()
                if slo is None or a.slo == slo]

    def fired(self, slo: Optional[str] = None) -> List[Alert]:
        """Every rising-edge alert ever emitted (resolve edges excluded)."""
        return [a for a in self.alerts
                if a.active and (slo is None or a.slo == slo)]


def default_slos(delta: float, *, latency_ms: Optional[float] = None,
                 latency_budget: float = 0.01,
                 shed_budget: float = 0.05) -> Tuple[SLO, ...]:
    """The serving stack's stock objectives: recall ≥ 1−δ (budget = the
    effective δ — the paper's contract verbatim), optionally a latency SLO
    (≤ ``latency_budget`` of requests slower than ``latency_ms``), and a
    shed-rate SLO."""
    slos = [SLO(name="recall", source="recall", budget=delta,
                description=f"audited recall >= 1-delta (delta={delta:g})")]
    if latency_ms is not None:
        slos.append(SLO(
            name="latency", source="latency", budget=latency_budget,
            description=f"<= {latency_budget:g} of requests slower than "
                        f"{latency_ms:g} ms"))
    slos.append(SLO(name="shed", source="shed", budget=shed_budget,
                    description=f"<= {shed_budget:g} of submissions shed"))
    return tuple(slos)


def plane_sources(plane, auditor=None, *,
                  latency_ms: Optional[float] = None) -> dict:
    """Cumulative (bad, total) pairs for ``default_slos`` from a live
    ``RequestPlane`` (+ its auditor). The latency signal counts terminal
    latencies above the smallest histogram bucket ≥ ``latency_ms`` —
    the threshold snaps to a bucket boundary."""
    auditor = auditor if auditor is not None else \
        getattr(plane, "auditor", None)
    out = {}
    if auditor is not None:
        out["recall"] = (float(auditor.mismatch_rows),
                         float(auditor.sampled_rows))
    out["shed"] = (float(plane._shed.value),
                   float(plane._submitted.value))
    if latency_ms is not None:
        h = plane._h_latency
        slow = float(h.count)
        for b, c in zip(h.buckets, h.counts):
            if b >= latency_ms:
                break
            slow -= c
        out["latency"] = (max(slow, 0.0), float(h.count))
    return out


class SLOEngine:
    """Evaluates a set of ``SLO``s against cumulative (bad, total) signals.

    Feed one ``observe(sources)`` call per observation window; the engine
    differences the cumulative pairs over each rule's windows, computes
    burn rates, and edge-triggers alerts into the sink / EventLog /
    metrics. State is bounded: per-SLO history is trimmed to the longest
    rule window."""

    def __init__(self, slos, *, sink: Optional[AlertSink] = None,
                 obs=None, clock=time.monotonic,
                 labels: Optional[dict] = None):
        slos = tuple(slos)
        names = [s.name for s in slos]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO names: {names}")
        self.slos = slos
        self.sink = sink if sink is not None else AlertSink()
        self.obs = obs
        self.clock = clock
        self._labels = dict(labels or {})
        self._hist: Dict[str, List[Tuple[float, float, float]]] = \
            {s.name: [] for s in slos}
        self._active: Dict[Tuple[str, str], Alert] = {}
        self.alerts_fired = 0
        if obs is not None:
            reg = obs.registry
            self._c_alerts = {
                (s.name, r.severity): reg.counter(
                    "repro_slo_alerts_total", "burn-rate alerts fired",
                    slo=s.name, severity=r.severity, **self._labels)
                for s in slos for r in s.rules}
            self._g_burn = {
                s.name: reg.gauge(
                    "repro_slo_burn",
                    "error-budget burn rate over the longest rule window "
                    "(1.0 = burning exactly the budget)",
                    slo=s.name, **self._labels)
                for s in slos}
        else:
            self._c_alerts = {}
            self._g_burn = {}

    def _window(self, hist, now: float, window_s: float,
                min_events: int) -> Tuple[float, float]:
        """(bad fraction, total events) over the trailing window: delta of
        the cumulative pair against the earliest sample inside the window
        (or zero if the history starts inside it — cold starts count from
        the beginning)."""
        cutoff = now - window_s
        base_bad = base_total = 0.0
        for (t, bad, total) in hist:
            if t >= cutoff:
                break
            base_bad, base_total = bad, total
        bad, total = hist[-1][1] - base_bad, hist[-1][2] - base_total
        if total < min_events:
            return 0.0, total
        return (bad / total if total > 0 else 0.0), total

    def observe(self, sources: Dict[str, Tuple[float, float]],
                now: Optional[float] = None) -> List[Alert]:
        """One evaluation pass. ``sources`` maps signal name →
        cumulative (bad, total). Returns newly fired rising-edge alerts."""
        now = self.clock() if now is None else now
        fired: List[Alert] = []
        for slo in self.slos:
            if slo.source not in sources:
                continue
            bad, total = sources[slo.source]
            hist = self._hist[slo.name]
            hist.append((now, float(bad), float(total)))
            horizon = max(r.long_s for r in slo.rules)
            while len(hist) > 2 and hist[1][0] < now - horizon:
                hist.pop(0)
            longest = max(slo.rules, key=lambda r: r.long_s)
            frac_longest, _ = self._window(hist, now, longest.long_s,
                                           slo.min_events)
            if slo.name in self._g_burn:
                self._g_burn[slo.name].set(frac_longest / slo.budget)
            for rule in slo.rules:
                frac_l, n_l = self._window(hist, now, rule.long_s,
                                           slo.min_events)
                frac_s, _n_s = self._window(hist, now, rule.short_s, 1)
                burn_l = frac_l / slo.budget
                burn_s = frac_s / slo.budget
                key = (slo.name, rule.name)
                burning = (burn_l >= rule.factor and burn_s >= rule.factor
                           and n_l >= slo.min_events)
                was = key in self._active
                if burning and not was:
                    alert = Alert(slo=slo.name, severity=rule.severity,
                                  rule=rule.name, burn_long=burn_l,
                                  burn_short=burn_s, bad_frac=frac_l,
                                  budget=slo.budget, at=now, active=True)
                    self._active[key] = alert
                    self.sink.emit(alert)
                    fired.append(alert)
                    self.alerts_fired += 1
                    if (slo.name, rule.severity) in self._c_alerts:
                        self._c_alerts[(slo.name, rule.severity)].inc()
                    if self.obs is not None:
                        self.obs.tracer.instant(
                            "slo.alert", slo=slo.name, rule=rule.name,
                            severity=rule.severity, burn_long=burn_l,
                            burn_short=burn_s, budget=slo.budget)
                    log.bind(slo=slo.name).warning(
                        "SLO %s burning: rule %s fires (burn long=%.2fx "
                        "short=%.2fx of budget %g)", slo.name, rule.name,
                        burn_l, burn_s, slo.budget)
                elif was and not burning:
                    old = self._active.pop(key)
                    resolve = dataclasses.replace(
                        old, burn_long=burn_l, burn_short=burn_s,
                        bad_frac=frac_l, at=now, active=False)
                    self.sink.emit(resolve)
                    if self.obs is not None:
                        self.obs.tracer.instant(
                            "slo.resolve", slo=slo.name, rule=rule.name,
                            burn_long=burn_l)
                    log.bind(slo=slo.name).info(
                        "SLO %s recovered: rule %s resolved", slo.name,
                        rule.name)
        return fired

    @property
    def active_alerts(self) -> List[Alert]:
        return list(self._active.values())

    def state(self) -> dict:
        """JSON-safe engine state (the health snapshot's slo section)."""
        out = []
        for slo in self.slos:
            hist = self._hist[slo.name]
            now = hist[-1][0] if hist else self.clock()
            rules = []
            for rule in slo.rules:
                frac_l, n_l = (self._window(hist, now, rule.long_s,
                                            slo.min_events)
                               if hist else (0.0, 0.0))
                rules.append({
                    "rule": rule.name,
                    "severity": rule.severity,
                    "factor": rule.factor,
                    "burn": frac_l / slo.budget,
                    "window_events": n_l,
                    "active": (slo.name, rule.name) in self._active,
                })
            out.append({
                "name": slo.name,
                "source": slo.source,
                "budget": slo.budget,
                "description": slo.description,
                "bad_frac": (self._window(hist, now,
                                          max(r.long_s for r in slo.rules),
                                          1)[0] if hist else 0.0),
                "rules": rules,
            })
        return {
            "slos": out,
            "alerts_fired": self.alerts_fired,
            "active": [dataclasses.asdict(a) for a in self.active_alerts],
        }
