"""Metrics registry + bounded ring-buffer event log (DESIGN.md §8.1).

No external deps: three metric kinds (monotone ``Counter``, point-in-time
``Gauge``, explicit-bucket ``Histogram``) keyed by (name, labels) in one
``MetricsRegistry``, and an ``EventLog`` — a preallocated ring buffer whose
append is a single index store plus a list assignment (no locks taken; the
GIL makes the single-writer serving loop race-free, and a torn read from an
exporter thread at worst sees one stale slot, never a partial event).

Naming scheme (§8.2): ``repro_<subsystem>_<what>[_<unit>][_total]`` —
e.g. ``repro_plane_submitted_total``, ``repro_race_epoch_ms``,
``repro_kernel_coord_ops_total``. Counters end in ``_total``; durations are
milliseconds; labels distinguish instances (``plane="p0"``) and kinds
(``kernel="fused_epoch_pull"``), never unbounded values like trace ids.
"""
from __future__ import annotations

import bisect
import re
from typing import Dict, Iterable, List, Optional, Tuple

#: runtime half of the metrics-conformance contract — the static half is
#: repro.analysis.rules_metrics, which shares this shape (DESIGN.md §12.6)
_NAME_RE = re.compile(r"^repro_[a-z0-9_]+$")

#: default duration buckets (ms) — log-spaced to cover one kernel launch
#: (~0.1 ms) through a run-to-certification race under overload (~60 s)
DEFAULT_MS_BUCKETS = (0.5, 1, 2.5, 5, 10, 25, 50, 100, 250, 500,
                      1000, 2500, 5000, 15000, 60000)


class Counter:
    """Monotonically increasing float."""

    kind = "counter"
    __slots__ = ("name", "help", "labels", "value")

    def __init__(self, name: str, help: str = "", labels: Tuple = ()):
        self.name, self.help, self.labels = name, help, labels
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({v})")
        self.value += v


class Gauge:
    """Point-in-time float."""

    kind = "gauge"
    __slots__ = ("name", "help", "labels", "value")

    def __init__(self, name: str, help: str = "", labels: Tuple = ()):
        self.name, self.help, self.labels = name, help, labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def dec(self, v: float = 1.0) -> None:
        self.value -= v


class Histogram:
    """Explicit-bucket histogram (cumulative ``le`` semantics on export)."""

    kind = "histogram"
    __slots__ = ("name", "help", "labels", "buckets", "counts", "sum",
                 "count")

    def __init__(self, name: str, help: str = "", labels: Tuple = (),
                 buckets: Iterable[float] = DEFAULT_MS_BUCKETS):
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError(f"histogram {name} needs at least one bucket")
        self.name, self.help, self.labels = name, help, labels
        self.buckets = bs
        self.counts = [0] * (len(bs) + 1)      # last = +inf overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        if v != v:                              # NaN never lands in a bucket
            return
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1

    def snapshot(self) -> dict:
        """JSON-stable view: per-bucket (non-cumulative) counts + sum/count."""
        return {"buckets": list(self.buckets), "counts": list(self.counts),
                "sum": self.sum, "count": self.count}

    def quantile(self, q: float) -> float:
        """Approximate quantile by linear interpolation inside the bucket —
        good enough for dashboards; exact percentiles come from the plane's
        bounded latency window. Returns 0.0 on an empty histogram."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        lo = 0.0
        for i, c in enumerate(self.counts):
            hi = (self.buckets[i] if i < len(self.buckets)
                  else self.buckets[-1])
            if seen + c >= rank:
                if c == 0 or hi == lo:
                    return hi
                return lo + (hi - lo) * (rank - seen) / c
            seen += c
            lo = hi
        return self.buckets[-1]


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """One namespace of metrics, keyed by (name, sorted label items)."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, Tuple], object] = {}
        self._help: Dict[str, str] = {}
        self._kind: Dict[str, str] = {}

    def _get(self, kind: str, name: str, help: str, labels: dict,
             **kw):
        if not _NAME_RE.match(name):
            raise ValueError(
                f"metric name {name!r} does not match "
                f"'repro_[a-z0-9_]+' (naming scheme, DESIGN.md §8.2)")
        if (kind == "counter") != name.endswith("_total"):
            raise ValueError(
                f"{kind} {name!r}: the '_total' suffix is required on "
                f"counters and reserved for them (DESIGN.md §8.2)")
        if name in self._kind and self._kind[name] != kind:
            raise ValueError(
                f"metric {name!r} already registered as "
                f"{self._kind[name]}, not {kind}")
        key = (name, tuple(sorted(labels.items())))
        got = self._metrics.get(key)
        if got is None:
            got = _KINDS[kind](name, help or self._help.get(name, ""),
                               key[1], **kw)
            self._metrics[key] = got
            self._kind[name] = kind
            if help:
                self._help[name] = help
        return got

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get("counter", name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get("gauge", name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_MS_BUCKETS,
                  **labels) -> Histogram:
        return self._get("histogram", name, help, labels, buckets=buckets)

    def collect(self) -> List[object]:
        """All series, grouped by name (stable registration order)."""
        return list(self._metrics.values())

    def __len__(self) -> int:
        return len(self._metrics)


class EventLog:
    """Bounded ring buffer of event dicts.

    ``append`` never allocates buffer space (the ring is preallocated) and
    never blocks; once full, the oldest event is overwritten and counted in
    ``drops`` — backpressure by forgetting history, never by stalling the
    serving loop.
    """

    def __init__(self, capacity: int = 16384):
        if capacity < 1:
            raise ValueError(f"event log capacity must be >= 1, "
                             f"got {capacity}")
        self.capacity = capacity
        self._buf: List[Optional[dict]] = [None] * capacity
        self._head = 0
        self._count = 0       # events currently buffered
        self.total = 0        # events ever appended (lifetime)
        self.drops = 0        # events overwritten before being exported
        self.on_drop = None   # callback(ring) per overwrite — ObsContext
                              # wires the drops counter + warn-once here

    def append(self, event: dict) -> None:
        i = self._head
        if self._buf[i] is not None:
            self.drops += 1
            if self.on_drop is not None:
                self.on_drop(self)
        else:
            self._count += 1
        self._buf[i] = event
        self._head = (i + 1) % self.capacity
        self.total += 1

    def snapshot(self) -> List[dict]:
        """Events oldest-first (non-destructive)."""
        h = self._head
        out = self._buf[h:] + self._buf[:h]
        return [e for e in out if e is not None]

    def clear(self) -> None:
        self._buf = [None] * self.capacity
        self._head = 0
        self._count = 0

    def __len__(self) -> int:
        return self._count
