"""repro.obs — first-class observability for the serving stack
(DESIGN.md §8).

One ``ObsContext`` bundles the three primitives every layer records into:

  * ``registry`` — the metrics registry (counters / gauges / histograms),
    the single source of truth behind ``ServeStats`` and the
    ``serve/scale.py`` policies;
  * ``events``   — the bounded ring-buffer event log;
  * ``tracer``   — race-level trace spans over that log (per-ticket trace
    ids propagated submit → queue → admit → each race epoch → terminal).

``get_obs()`` returns the process-default context (what the launchers
export); tests and embedders can pass their own ``ObsContext`` to
``RequestPlane`` / ``make_session`` for isolation. ``REPRO_OBS=0``
disables event/span recording process-wide (metrics counters stay on —
``ServeStats`` must keep working); ``REPRO_OBS_EVENTS`` sizes the default
ring.
"""
from __future__ import annotations

import os
from typing import Optional

from repro.obs.audit import (DeltaAuditor, FlightRecorder,
                             clopper_pearson_upper, exact_topk,
                             load_bundle, replay_bundle, wilson_upper)
from repro.obs.export import (dump_events, dump_metrics, events_doc,
                              json_snapshot, prometheus_text)
from repro.obs.health import (dump_health, health_snapshot,
                              print_health)
from repro.obs.jaxmon import compiles_total, install_compile_hook
from repro.obs.registry import (DEFAULT_MS_BUCKETS, Counter, EventLog,
                                Gauge, Histogram, MetricsRegistry)
from repro.obs.slo import (SLO, Alert, AlertSink, BurnRule, SLOEngine,
                           default_slos, plane_sources)
from repro.obs.trace import NULL_SPAN, Span, Tracer, new_trace_id

__all__ = [
    "Alert", "AlertSink", "BurnRule", "Counter", "DEFAULT_MS_BUCKETS",
    "DeltaAuditor", "EventLog", "FlightRecorder", "Gauge", "Histogram",
    "MetricsRegistry", "NULL_SPAN", "ObsContext", "SLO", "SLOEngine",
    "Span", "Tracer", "clopper_pearson_upper", "compiles_total",
    "default_slos", "dump_events", "dump_health", "dump_metrics",
    "events_doc", "exact_topk", "get_obs", "health_snapshot",
    "install_compile_hook", "json_snapshot", "load_bundle",
    "new_trace_id", "plane_sources", "print_health", "prometheus_text",
    "replay_bundle", "reset_obs", "set_obs", "wilson_upper",
]


class ObsContext:
    """One observability namespace: registry + event log + tracer."""

    def __init__(self, name: str = "default", *,
                 event_capacity: int = 16384,
                 enabled: Optional[bool] = None):
        if enabled is None:
            enabled = os.environ.get("REPRO_OBS", "1") != "0"
        self.name = name
        self.enabled = enabled
        self.registry = MetricsRegistry()
        self.events = EventLog(event_capacity)
        self.tracer = Tracer(self.events, enabled=enabled)
        # ring overflow must be visible, not silent (DESIGN.md §10): every
        # overwrite counts into the registry, and the FIRST one warns so a
        # truncated trace never masquerades as a complete one
        self._drops_counter = self.registry.counter(
            "repro_obs_event_drops_total",
            "trace events overwritten before export (ring overflow)",
            ring=name)
        self._drop_warned = False
        self.events.on_drop = self._on_event_drop

    def _on_event_drop(self, ring) -> None:
        self._drops_counter.inc()
        if not self._drop_warned:
            self._drop_warned = True
            from repro.utils import get_logger
            get_logger("repro.obs").bind(ring=self.name).warning(
                "trace event ring overflowed (capacity %d): oldest events "
                "are being dropped — raise REPRO_OBS_EVENTS or export "
                "more often", ring.capacity)


_default: Optional[ObsContext] = None


def get_obs() -> ObsContext:
    """The process-default context (created lazily; honours ``REPRO_OBS``)."""
    global _default
    if _default is None:
        cap = int(os.environ.get("REPRO_OBS_EVENTS", "16384"))
        _default = ObsContext("default", event_capacity=cap)
    return _default


def set_obs(ctx: ObsContext) -> ObsContext:
    """Install ``ctx`` as the process default; returns the previous one."""
    global _default
    old = get_obs()
    _default = ctx
    return old


def reset_obs() -> ObsContext:
    """Fresh default context (test isolation)."""
    global _default
    _default = None
    return get_obs()


# jax compile-time telemetry (repro_xla_compiles_total) rides on the
# process-wide jax.monitoring listener; the hook resolves get_obs() per
# event, so it composes with set_obs()-swapped contexts. Best-effort: a
# jax build without the monitoring API simply leaves the counter at 0.
install_compile_hook()
