"""repro.obs — first-class observability for the serving stack
(DESIGN.md §8).

One ``ObsContext`` bundles the three primitives every layer records into:

  * ``registry`` — the metrics registry (counters / gauges / histograms),
    the single source of truth behind ``ServeStats`` and the
    ``serve/scale.py`` policies;
  * ``events``   — the bounded ring-buffer event log;
  * ``tracer``   — race-level trace spans over that log (per-ticket trace
    ids propagated submit → queue → admit → each race epoch → terminal).

``get_obs()`` returns the process-default context (what the launchers
export); tests and embedders can pass their own ``ObsContext`` to
``RequestPlane`` / ``make_session`` for isolation. ``REPRO_OBS=0``
disables event/span recording process-wide (metrics counters stay on —
``ServeStats`` must keep working); ``REPRO_OBS_EVENTS`` sizes the default
ring.
"""
from __future__ import annotations

import os
from typing import Optional

from repro.obs.export import (dump_events, dump_metrics, events_doc,
                              json_snapshot, prometheus_text)
from repro.obs.jaxmon import compiles_total, install_compile_hook
from repro.obs.registry import (DEFAULT_MS_BUCKETS, Counter, EventLog,
                                Gauge, Histogram, MetricsRegistry)
from repro.obs.trace import NULL_SPAN, Span, Tracer, new_trace_id

__all__ = [
    "Counter", "DEFAULT_MS_BUCKETS", "EventLog", "Gauge", "Histogram",
    "MetricsRegistry", "NULL_SPAN", "ObsContext", "Span", "Tracer",
    "compiles_total", "dump_events", "dump_metrics", "events_doc",
    "get_obs", "install_compile_hook", "json_snapshot", "new_trace_id",
    "prometheus_text", "reset_obs", "set_obs",
]


class ObsContext:
    """One observability namespace: registry + event log + tracer."""

    def __init__(self, name: str = "default", *,
                 event_capacity: int = 16384,
                 enabled: Optional[bool] = None):
        if enabled is None:
            enabled = os.environ.get("REPRO_OBS", "1") != "0"
        self.name = name
        self.enabled = enabled
        self.registry = MetricsRegistry()
        self.events = EventLog(event_capacity)
        self.tracer = Tracer(self.events, enabled=enabled)


_default: Optional[ObsContext] = None


def get_obs() -> ObsContext:
    """The process-default context (created lazily; honours ``REPRO_OBS``)."""
    global _default
    if _default is None:
        cap = int(os.environ.get("REPRO_OBS_EVENTS", "16384"))
        _default = ObsContext("default", event_capacity=cap)
    return _default


def set_obs(ctx: ObsContext) -> ObsContext:
    """Install ``ctx`` as the process default; returns the previous one."""
    global _default
    old = get_obs()
    _default = ctx
    return old


def reset_obs() -> ObsContext:
    """Fresh default context (test isolation)."""
    global _default
    _default = None
    return get_obs()


# jax compile-time telemetry (repro_xla_compiles_total) rides on the
# process-wide jax.monitoring listener; the hook resolves get_obs() per
# event, so it composes with set_obs()-swapped contexts. Best-effort: a
# jax build without the monitoring API simply leaves the counter at 0.
install_compile_hook()
