"""HLO-text cost model for the dry-run 'profile'.

``compiled.cost_analysis()`` visits each while body ONCE, so layer-scan and
grad-accumulation loops are massively under-counted. This module re-derives
FLOPs / bytes-accessed / collective-bytes from ``compiled.as_text()`` with
per-computation *trip-count multipliers*:

  * every `while` op carries ``backend_config={"known_trip_count":{"n":N}}``
    for counted loops (jax.lax.scan); its body and condition computations
    inherit ×N (nested loops multiply),
  * `fusion` / `call` / custom-call sub-computations inherit their caller's
    multiplier,
  * dot FLOPs = 2 × prod(output dims) × prod(contracting dims), resolved
    through a per-computation symbol table (operand names → shapes),
  * elementwise/transcendental ops count 1 FLOP per output element
    (HloCostAnalysis convention),
  * bytes accessed per op = operand bytes + output bytes (HloCostAnalysis
    convention), for compute ops only,
  * collective bytes: output-shape bytes per collective op (all-reduce ×2
    for the reduce+broadcast ring halves).

Data-dependent ``while`` loops (e.g. the BMO racing loop) have no
known_trip_count and count ×1 — noted where reported.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# 1-flop-per-element ops (HloCostAnalysis convention, incl. transcendentals)
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "tanh", "log", "rsqrt", "sqrt", "power",
    "sine", "cosine", "logistic", "expm1", "log1p", "floor", "ceil",
    "round-nearest-afz", "sign", "atan2", "cbrt", "erf",
}
_REDUCE_LIKE = {"reduce", "reduce-window"}
_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "while", "call",
    "conditional", "after-all", "partition-id", "replica-id", "bitcast",
    "get-dimension-size", "custom-call", "fusion", "opt-barrier",
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    """total (elements, bytes) over all arrays in a (possibly tuple) shape."""
    elems = byts = 0
    for m in _ARRAY_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _dims_of(shape_str: str) -> List[int]:
    m = _ARRAY_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Op:
    name: str
    shape: str           # output shape string
    opcode: str
    args: str            # raw remainder (operand list + attrs)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    shapes: Dict[str, str]   # symbol table: op name -> output shape str


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        # computation header: `%name (args) -> type {` — args may nest parens
        if stripped.endswith("{") and "->" in stripped and " = " not in stripped:
            m = re.match(r"\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(", stripped)
            if m:
                cur = Computation(m.group(2), [], {})
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape, opcode, rest = m.groups()
        cur.ops.append(Op(name, shape, opcode, rest))
        cur.shapes[name] = shape
    return comps, entry


def _called_comps(op: Op) -> List[Tuple[str, str]]:
    """(role, computation_name) pairs referenced by this op."""
    out = []
    for role in ("body", "condition", "to_apply", "calls", "branch_computations"):
        for m in re.finditer(role + r"=\{?%?([\w\.\-,%\s]+)\}?", op.args):
            for nm in re.split(r"[,\s]+", m.group(1)):
                nm = nm.strip().lstrip("%")
                if nm:
                    out.append((role, nm))
    return out


def _trip_count(op: Op) -> Optional[int]:
    m = re.search(r"known_trip_count[^0-9]*(\d+)", op.args)
    return int(m.group(1)) if m else None


def multipliers(comps: Dict[str, Computation],
                entry: Optional[str] = None) -> Dict[str, float]:
    """computation name -> execution-count multiplier from ENTRY."""
    if entry is None:
        referenced = set()
        for c in comps.values():
            for op in c.ops:
                referenced.update(nm for _, nm in _called_comps(op))
        unref = [n for n in comps if n not in referenced]
        entry = unref[-1] if unref else None
    mult: Dict[str, float] = {}
    stack = [(entry, 1.0)] if entry else []
    seen = set()
    while stack:
        name, m = stack.pop()
        if name not in comps:
            continue
        mult[name] = max(mult.get(name, 0.0), m)
        if (name, m) in seen:
            continue
        seen.add((name, m))
        for op in comps[name].ops:
            trip = _trip_count(op) if op.opcode == "while" else None
            for role, callee in _called_comps(op):
                child_m = m
                if op.opcode == "while":
                    t = trip if trip else 1
                    child_m = m * (t if role == "body" else t + 1)
                stack.append((callee, child_m))
    return mult


def _operand_names(op: Op) -> List[str]:
    """operand names from the leading parenthesized list of the op args."""
    depth, i, buf = 1, 0, []
    while i < len(op.args) and depth > 0:
        ch = op.args[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
        i += 1
    arglist = "".join(buf)
    return [m.group(1) for m in re.finditer(r"%([\w\.\-]+)", arglist)]


def _dot_flops(op: Op, comp: Computation) -> float:
    out_dims = _dims_of(op.shape)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    ops_names = _operand_names(op)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.args)
    if not m or not ops_names:
        return 2.0 * out_elems  # fallback
    lhs_shape = comp.shapes.get(ops_names[0], "")
    lhs_dims = _dims_of(lhs_shape)
    contract = 1
    if m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


_SLICE_LIKE = {"dynamic-slice", "slice", "gather"}


def _op_bytes(op: Op, comp: Computation, comps: Dict[str, Computation]) -> float:
    """HBM bytes for one top-level op: output write + operand reads, with
    slice-aware accounting:

      * (dynamic-)slice / gather: only the moved region (2× output),
      * dynamic-update-slice: 2× the update region (the big buffer aliases),
      * fusion: per-operand — if the matching parameter inside the fused
        computation is consumed *only* by slice-like ops, charge the slice
        outputs (stacked scan params are read one layer-slice at a time!);
        fusion with a DUS root charges the update region instead of the
        full output buffer.
    """
    out_bytes = _shape_elems_bytes(op.shape)[1]
    oc = op.opcode
    if oc in _SLICE_LIKE:
        return 2.0 * out_bytes
    if oc == "dynamic-update-slice":
        ops_n = _operand_names(op)
        upd = _shape_elems_bytes(comp.shapes.get(ops_n[1], ""))[1] if len(ops_n) > 1 else 0
        return 2.0 * (upd or out_bytes)
    if oc != "fusion":
        operand_bytes = sum(
            _shape_elems_bytes(comp.shapes[nm])[1]
            for nm in _operand_names(op) if nm in comp.shapes)
        return out_bytes + operand_bytes

    # ---- fusion ----
    callees = [nm for role, nm in _called_comps(op) if role == "calls"]
    callee = comps.get(callees[0]) if callees else None
    operand_names = _operand_names(op)
    if callee is None:
        return out_bytes + sum(
            _shape_elems_bytes(comp.shapes[nm])[1]
            for nm in operand_names if nm in comp.shapes)

    # parameter index -> param op
    params: Dict[int, Op] = {}
    for o in callee.ops:
        if o.opcode == "parameter":
            mm = re.match(r"\s*(\d+)", o.args)
            if mm:
                params[int(mm.group(1))] = o
    # consumers per value name inside callee
    total = 0.0
    for i, nm in enumerate(operand_names):
        full = _shape_elems_bytes(comp.shapes.get(nm, ""))[1]
        p = params.get(i)
        if p is None:
            total += full
            continue
        consumers = [o for o in callee.ops if p.name in _operand_names(o)]
        if consumers and all(o.opcode in _SLICE_LIKE for o in consumers):
            total += sum(_shape_elems_bytes(o.shape)[1] for o in consumers)
        else:
            total += full
    # in-place cache-update fusion: a DUS whose result dims match the fusion
    # output (possibly through a trailing convert) — charge the update
    # region, not the whole buffer, and drop the aliased buffer operand.
    out_dims = _dims_of(op.shape)
    dus = None
    for o in callee.ops:
        if o.opcode == "dynamic-update-slice" and _dims_of(o.shape) == out_dims:
            dus = o
            break
    if dus is not None:
        ops_n = _operand_names(dus)
        upd = _shape_elems_bytes(callee.shapes.get(ops_n[1], ""))[1] \
            if len(ops_n) > 1 else 0
        # the aliased buffer operand was charged at full size above; undo
        # the largest matching-size operand once
        for nm in operand_names:
            if nm in comp.shapes and \
                    _dims_of(comp.shapes[nm]) == out_dims:
                total -= _shape_elems_bytes(comp.shapes[nm])[1]
                break
        return max(total, 0.0) + 2.0 * (upd or out_bytes)
    return total + out_bytes


@dataclasses.dataclass
class HLOCost:
    flops: float
    bytes_accessed: float
    coll_bytes_by_kind: Dict[str, float]
    coll_ops: int
    unknown_trip_whiles: int

    @property
    def coll_bytes(self) -> float:
        return float(sum(self.coll_bytes_by_kind.values()))


def analyze_hlo(hlo: str) -> HLOCost:
    comps, entry = parse_module(hlo)
    mult = multipliers(comps, entry)
    # computations fused into a kernel: their internal ops move no HBM bytes
    fused: set = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                fused.update(nm for role, nm in _called_comps(op)
                             if role in ("calls", "to_apply"))
    flops = 0.0
    byts = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    coll_ops = 0
    unknown_whiles = 0
    for name, comp in comps.items():
        m = mult.get(name, 1.0)
        in_fusion = name in fused
        for op in comp.ops:
            oc = op.opcode
            if oc == "while" and _trip_count(op) is None:
                unknown_whiles += 1
            out_elems, out_bytes = _shape_elems_bytes(op.shape)
            # ---- flops ----
            if oc in ("dot", "dot-general"):
                flops += m * _dot_flops(op, comp)
            elif oc in _ELEMENTWISE:
                flops += m * out_elems
            elif oc in _REDUCE_LIKE:
                in_elems = 0
                for nm in _operand_names(op):
                    sh = comp.shapes.get(nm)
                    if sh:
                        in_elems += _shape_elems_bytes(sh)[0]
                flops += m * max(in_elems // 2, out_elems)
            # ---- collectives ----
            base = oc.replace("-start", "")
            if base in _COLLECTIVES:
                b = out_bytes
                if base == "all-reduce":
                    b *= 2
                coll[base] += m * b
                coll_ops += 1
            # ---- bytes (skip in-fusion ops: no HBM traffic) ----
            if in_fusion:
                continue
            if oc in _SKIP_BYTES and oc != "fusion":
                continue
            if oc.endswith("-done"):
                continue
            byts += m * _op_bytes(op, comp, comps)
    return HLOCost(flops=flops, bytes_accessed=byts, coll_bytes_by_kind=coll,
                   coll_ops=coll_ops, unknown_trip_whiles=unknown_whiles)


def cpu_upcast_artifact_bytes(hlo: str) -> float:
    """XLA *CPU* float-normalization converts whole bf16 argument stacks
    (weights, KV caches) to f32 because the CPU backend has no native bf16
    dot — a lowering artifact absent on TPU (MXU consumes bf16 directly).
    Returns the f32-copy bytes attributable to that, so memory reports can
    show a TPU-meaningful 'adjusted' peak. Detection: f32 tensors whose
    dims exactly match a bf16 entry parameter."""
    comps, entry = parse_module(hlo)
    if entry is None or entry not in comps:
        return 0.0
    ecomp = comps[entry]
    bf16_param_dims: Dict[str, int] = {}
    for op in ecomp.ops:
        if op.opcode == "parameter":
            m = _ARRAY_RE.search(op.shape)
            if m and m.group(1) == "bf16" and m.group(2):
                bf16_param_dims[m.group(2)] = bf16_param_dims.get(m.group(2), 0) + 1
    # count f32 twins per dims signature, capped by the number of bf16 params
    f32_counts: Dict[str, int] = {}
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode not in ("convert", "fusion"):
                continue
            m = _ARRAY_RE.search(op.shape)
            if m and m.group(1) == "f32" and m.group(2) in bf16_param_dims:
                f32_counts[m.group(2)] = f32_counts.get(m.group(2), 0) + 1
    artifact = 0.0
    for dims, cnt in f32_counts.items():
        n = 1
        for d in dims.split(","):
            n *= int(d)
        artifact += 4.0 * n * min(cnt, bf16_param_dims[dims])
    return artifact


# backwards-compatible helper used elsewhere
def collective_bytes(hlo: str):
    cost = analyze_hlo(hlo)

    @dataclasses.dataclass
    class CollectiveStats:
        bytes_by_kind: Dict[str, float]
        op_count: int

        @property
        def total_bytes(self) -> float:
            return float(sum(self.bytes_by_kind.values()))

    return CollectiveStats(cost.coll_bytes_by_kind, cost.coll_ops)
