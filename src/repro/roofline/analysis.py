"""Roofline-term derivation from a compiled dry-run artifact.

Per (arch × shape × mesh):
    compute    = HLO_FLOPs / (chips × peak_FLOPs)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw × links)

Hardware constants (TPU v5e-like, per assignment): 197 TFLOP/s bf16/chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional

from repro.roofline.hlo import analyze_hlo, cpu_upcast_artifact_bytes


@dataclasses.dataclass(frozen=True)
class Hardware:
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # B/s per chip
    ici_bw: float = 50e9             # B/s per link
    ici_links: int = 4               # links usable per chip (2D torus: 4)


HW = Hardware()


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_ops: int
    model_flops: float
    peak_memory_per_chip: float

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * HW.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HW.hbm_bw)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * HW.ici_bw * HW.ici_links)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """model-useful compute time / achievable step time (= max term):
        the score we hillclimb."""
        t_useful = self.model_flops / (self.chips * HW.peak_flops)
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / max(t_step, 1e-30)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes, "coll_ops": self.coll_ops,
            "model_flops": self.model_flops,
            "peak_memory_per_chip": self.peak_memory_per_chip,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     chips: int, model_flops: float) -> RooflineTerms:
    """Derives the three terms from the compiled artifact.

    ``cost_analysis()`` visits while bodies once, so we use the trip-count-
    scaled HLO cost model (roofline/hlo.py) for FLOPs / bytes / collectives;
    the raw cost_analysis numbers are kept for cross-checking in the JSONL.
    All totals are per-device programs under SPMD → ×chips for cluster
    totals (the roofline terms divide them back per chip)."""
    mem = compiled.memory_analysis()
    peak = float(getattr(mem, "temp_size_in_bytes", 0) or 0) + \
        float(getattr(mem, "argument_size_in_bytes", 0) or 0)
    hlo = compiled.as_text()
    cost = analyze_hlo(hlo)
    # subtract XLA-CPU bf16→f32 whole-stack upcasts (absent on TPU)
    artifact = cpu_upcast_artifact_bytes(hlo)
    peak_adj = max(peak - artifact, 0.0)
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=cost.flops * chips, hlo_bytes=cost.bytes_accessed * chips,
        coll_bytes=cost.coll_bytes * chips, coll_ops=cost.coll_ops,
        model_flops=model_flops,
        peak_memory_per_chip=peak_adj,
    )


def model_flops_estimate(cfg, shape, n_params_active: float,
                         n_params_total: Optional[float] = None) -> float:
    """6·N·D for train, 2·N·D for inference (D = processed tokens)."""
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_params_active * tokens
    # decode: one token per sequence
    return 2.0 * n_params_active * shape.global_batch
