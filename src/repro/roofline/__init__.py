from repro.roofline.analysis import RooflineTerms, analyze_compiled, HW
from repro.roofline.hlo import collective_bytes

__all__ = ["RooflineTerms", "analyze_compiled", "collective_bytes", "HW"]
