"""repro — Bandit-Based Monte Carlo Optimization for Nearest Neighbors,
built as a multi-pod JAX training/serving framework. See README.md."""

__version__ = "0.1.0"

from repro import _compat

_compat.install()
del _compat
