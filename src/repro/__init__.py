"""repro — Bandit-Based Monte Carlo Optimization for Nearest Neighbors,
built as a multi-pod JAX training/serving framework. See README.md."""

__version__ = "0.1.0"

try:
    from repro import _compat
except ModuleNotFoundError as _e:  # pragma: no cover - jax-free tooling
    # repro.analysis and tools/repro_lint.py are pure stdlib by design:
    # the CI lint job runs them without jax installed. Anything that
    # actually touches arrays still fails loudly at its own import.
    if _e.name not in ("jax", "jaxlib"):
        raise
else:
    _compat.install()
    del _compat
