from repro.data.synthetic import (
    clustered_dense, clustered_sparse, lm_batch, make_knn_benchmark_data,
)
from repro.data.loader import ShardedLoader

__all__ = ["clustered_dense", "clustered_sparse", "lm_batch",
           "make_knn_benchmark_data", "ShardedLoader"]
