"""Sharded, resumable host data loader.

Every batch is a pure function of (seed, step) — restart/elastic-reshard safe
by construction: after restoring a checkpoint at step s, the loader resumes
at step s with bit-identical data, for any device count.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.synthetic import lm_batch


class ShardedLoader:
    def __init__(self, vocab: int, batch: int, seq: int, *, seed: int = 0,
                 mesh: Optional[Mesh] = None, batch_pspec: P = P("data")):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        self.mesh = mesh
        self.batch_pspec = batch_pspec

    def get(self, step: int) -> Dict[str, jax.Array]:
        host = lm_batch(self.vocab, self.batch, self.seq, seed=self.seed,
                        step=step)
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        sh = NamedSharding(self.mesh, self.batch_pspec)
        return {k: jax.device_put(v, sh) for k, v in host.items()}
