"""Synthetic data generators.

LM side: deterministic Zipf-ish token streams keyed by (seed, step, shard) —
reproducible across restarts and elastic re-sharding.

kNN side: generators matched to the paper's two datasets in (n, d, sparsity,
coordinate-distance tail). Tiny-ImageNet-like data is a clustered heavy-tail
mixture (Fig. 4c shows rapidly-decaying but heavy-ish coordinate-distance
tails); the 10x-genomics-like data is ~7% dense non-negative with
exponential magnitudes.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# LM tokens
# ---------------------------------------------------------------------------


def lm_batch(vocab: int, batch: int, seq: int, *, seed: int, step: int,
             shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
    """Deterministic (tokens, labels) batch; labels are next-token shifted."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, shard, n_shards]))
    # Zipf-ish marginal over the vocab with short-range repetition structure
    ranks = rng.zipf(1.3, size=(batch, seq + 1)) % vocab
    rep = rng.random((batch, seq + 1)) < 0.3
    ranks[:, 1:][rep[:, 1:]] = ranks[:, :-1][rep[:, 1:]]
    toks = ranks.astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


# ---------------------------------------------------------------------------
# kNN corpora
# ---------------------------------------------------------------------------


def clustered_dense(n: int, d: int, *, n_clusters: int = 64,
                    noise: float = 0.15, heavy_tail: float = 1.0,
                    seed: int = 0) -> np.ndarray:
    """Image-like corpus: cluster centers with per-point heavy-tailed scale.
    Most inter-point gaps are large (cheap to race); same-cluster points are
    the hard arms — matching the paper's Tiny-ImageNet behaviour."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32)
    assign = rng.integers(0, n_clusters, n)
    scale = (1.0 + heavy_tail * rng.exponential(1.0, size=(n, 1))).astype(np.float32)
    pts = centers[assign] + noise * scale * rng.normal(size=(n, d)).astype(np.float32)
    return pts.astype(np.float32)


def clustered_sparse(n: int, d: int, *, sparsity: float = 0.07,
                     n_clusters: int = 32, seed: int = 0) -> np.ndarray:
    """RNA-seq-like corpus: ~sparsity fraction nonzero, non-negative,
    exponential magnitudes, cluster-structured supports."""
    rng = np.random.default_rng(seed)
    out = np.zeros((n, d), np.float32)
    # each cluster has a preferred support
    supports = [rng.choice(d, size=int(d * sparsity * 1.5), replace=False)
                for _ in range(n_clusters)]
    for i in range(n):
        c = rng.integers(0, n_clusters)
        sup = supports[c]
        keep = rng.random(len(sup)) < (sparsity / (sparsity * 1.5))
        idx = sup[keep]
        out[i, idx] = rng.exponential(2.0, size=len(idx)).astype(np.float32)
    return out


def make_knn_benchmark_data(kind: str, n: int, d: int, n_queries: int,
                            seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """(corpus, queries): queries are perturbed corpus points (paper queries
    points of the dataset itself)."""
    rng = np.random.default_rng(seed + 1)
    if kind == "sparse":
        corpus = clustered_sparse(n, d, seed=seed)
        qidx = rng.integers(0, n, n_queries)
        queries = corpus[qidx].copy()
        return corpus, queries
    corpus = clustered_dense(n, d, seed=seed)
    qidx = rng.integers(0, n, n_queries)
    queries = corpus[qidx] + 0.05 * rng.normal(size=(n_queries, d)).astype(np.float32)
    return corpus, queries.astype(np.float32)
