"""Roofline pre-pass: model-prune the candidate grid before racing it
(DESIGN.md §9.3).

Racing a candidate costs real compiles and real wall time; the grid is
~50 wide. This pass lowers the fused epoch kernel (``kernels/ops.py``)
at each candidate's (Q, B, T) proxy shape, runs the HLO roofline model
(``repro/roofline``) over the compiled artifact, and scores candidates by
*achievable time per useful pulled element*:

    e = max(t_compute, t_memory) / (Q · B · T · block)

Low e = the launch amortizes its fixed costs over more useful coordinate
reads. Candidates worse than ``prune_ratio ×`` the best e are discarded;
the survivors (capped at ``max_candidates``) go to the measurement racer.
The identity candidate (the store's current config) is never pruned —
the racer must always be able to conclude "the defaults were already
best", and a model mis-prediction must never force a regression.

The model runs on whatever backend is present (``impl="ref"`` lowers on
CPU); corpus length is capped at a proxy n — HLO flop/byte counts of the
gather+reduce scale with (Q, B, T, block), not with n, so a small proxy
keeps lowering cheap while preserving the candidate ordering.
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.roofline.analysis import analyze_compiled
from repro.tune.candidates import TunedConfig

PROXY_N = 4096          # corpus rows in the lowering proxy
PROXY_Q = 8             # query rows in the lowering proxy


@functools.lru_cache(maxsize=128)
def _lowered_terms(Q: int, B: int, T: int, n: int, d_pad: int, block: int,
                   metric: str, dtype: str):
    """Compile the fused epoch pull at a proxy shape and extract roofline
    terms. Cached per shape tuple — many candidates share (B, T)."""
    x = jnp.zeros((n, d_pad), jnp.dtype(dtype))
    qs = jnp.zeros((Q, d_pad), jnp.dtype(dtype))
    arm = jnp.zeros((Q, B), jnp.int32)
    blk = jnp.zeros((Q, B, T), jnp.int32)
    fn = functools.partial(kops.fused_epoch_pull, block=block,
                           metric=metric, impl="ref")
    compiled = jax.jit(fn).lower(x, qs, arm, blk).compile()
    return analyze_compiled(
        compiled, arch=jax.default_backend(),
        shape=f"fused_epoch Q{Q} B{B} T{T} blk{block}",
        mesh_name="tune-proxy", chips=1,
        # useful work: one FLOP per pulled coordinate (diff-and-reduce)
        model_flops=float(Q * B * T * block))


def model_efficiency(cand: TunedConfig, *, Q: int, n: int, d_pad: int,
                     block: int, metric: str, dtype: str) -> float:
    """Achievable seconds per useful pulled element under the candidate."""
    T = cand.epoch_rounds * cand.pulls_per_round
    B = min(cand.batch_arms, n)
    terms = _lowered_terms(Q, B, T, min(n, PROXY_N), d_pad, block,
                           metric, dtype)
    useful = float(Q * B * T * block)
    return max(terms.t_compute, terms.t_memory) / max(useful, 1.0)


def seed_candidates(store, cands: List[TunedConfig], *,
                    Q: int = PROXY_Q, max_candidates: int = 8,
                    prune_ratio: float = 3.0,
                    ) -> Tuple[List[TunedConfig], List[dict]]:
    """Model-score ``cands`` for ``store``; returns (survivors, report).

    Survivors are ordered best-model-score-first with the identity
    candidate (index 0 of ``cands``) always retained. Candidates the
    model cannot score (sparse stores, lowering failure) pass through
    unpruned — the measurement racer is the ground truth.
    """
    if store.kind == "sparse":
        return list(cands), [{"cand": c.to_dict(), "e": None}
                             for c in cands]
    leaf = store.shards[0] if hasattr(store, "shards") else store
    d_pad = leaf.d_pad
    dtype = str(leaf.x.dtype)
    metric = store.cfg.metric
    scored: List[Tuple[float, TunedConfig]] = []
    report = []
    for c in cands:
        if c.mode == "rounds":      # different driver — model not comparable
            scored.append((0.0, c))
            report.append({"cand": c.to_dict(), "e": None})
            continue
        try:
            e = model_efficiency(c, Q=Q, n=store.n_live, d_pad=d_pad,
                                 block=store.block, metric=metric,
                                 dtype=dtype)
        except Exception:           # pragma: no cover — lowering quirk
            e = 0.0
        scored.append((e, c))
        report.append({"cand": c.to_dict(), "e": e if e else None})
    floor_e = min((e for e, _ in scored if e > 0.0), default=0.0)
    keep: List[TunedConfig] = []
    for i, (e, c) in enumerate(scored):
        if i == 0 or e == 0.0 or e <= prune_ratio * floor_e:
            keep.append(c)
    # best model score first; identity stays in regardless of rank
    order = {id(c): e for e, c in scored}
    ranked = sorted(keep[1:], key=lambda c: order[id(c)])
    survivors = [keep[0]] + ranked[: max(max_candidates - 1, 0)]
    return survivors, report
