"""Tuned-config persistence: the ``tuned.json`` sidecar and the
in-process cache (DESIGN.md §9.5).

The sidecar rides the index checkpoint exactly like ``payload.npy``: one
versioned JSON file next to the checkpoint payload (single-shard) or the
manifest (sharded), written by ``Index.save`` when a tuned config is
active and validated by ``Index.load`` against the *reloaded* store's
signature. Fallback is strict and bit-compatible: a missing file, an
unreadable file, a version bump, or a signature mismatch (the store was
re-sharded, re-typed, or grew past its scale bucket since tuning) all
mean "serve on build-time defaults as if never tuned" — a stale tuning
must never half-apply.

The in-process cache memoizes signature → TunedConfig so repeated
``Index.tune()`` calls on equal-signature stores (replicas, reloads,
test fixtures) skip the measurement race entirely; ``force=True``
bypasses it.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional, Tuple

from repro.tune.candidates import TUNED_VERSION, TunedConfig
from repro.tune.signature import StoreSignature, signature_of
from repro.utils import get_logger

log = get_logger("repro.tune")

TUNED_FILE = "tuned.json"

_cache: Dict[tuple, TunedConfig] = {}


def cache_get(sig: StoreSignature) -> Optional[TunedConfig]:
    return _cache.get(sig.key())


def cache_put(sig: StoreSignature, tuned: TunedConfig) -> None:
    _cache[sig.key()] = tuned


def cache_clear() -> None:
    _cache.clear()


def save_tuned(path: str, sig: StoreSignature, tuned: TunedConfig,
               measured: Optional[dict] = None) -> str:
    """Write the sidecar into checkpoint directory ``path``."""
    doc = {
        "version": TUNED_VERSION,
        "signature": sig.to_dict(),
        "config": tuned.to_dict(),
        "measured": measured or {},
    }
    fpath = os.path.join(path, TUNED_FILE)
    tmp = fpath + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    os.replace(tmp, fpath)
    return fpath


def load_tuned(path: str, store) -> Tuple[Optional[TunedConfig], str]:
    """Read + validate the sidecar for the store just loaded from ``path``.

    Returns ``(tuned, reason)`` — tuned is None unless the sidecar exists,
    parses, carries the current version, and its signature matches the
    store as reloaded; ``reason`` says why it was rejected ("ok" when
    accepted, "missing" when there is simply no sidecar).
    """
    fpath = os.path.join(path, TUNED_FILE)
    if not os.path.exists(fpath):
        return None, "missing"
    try:
        with open(fpath) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        log.warning("unreadable tuned sidecar at %s — serving on defaults",
                    fpath)
        return None, "unreadable"
    if doc.get("version") != TUNED_VERSION:
        log.warning("tuned sidecar version %r != %d — serving on defaults",
                    doc.get("version"), TUNED_VERSION)
        return None, "version"
    try:
        sig = StoreSignature.from_dict(doc["signature"])
        tuned = TunedConfig.from_dict(doc["config"])
    except (KeyError, TypeError):
        log.warning("malformed tuned sidecar at %s — serving on defaults",
                    fpath)
        return None, "malformed"
    want = signature_of(store)
    if sig != want:
        log.warning("tuned sidecar signature drift (%s -> %s) — serving "
                    "on defaults", sig.to_dict(), want.to_dict())
        return None, "signature"
    return tuned, "ok"
