"""``tune_store`` — the end-to-end autotune pass (DESIGN.md §9).

enumerate (candidates.py) → model-prune (seed.py) → race the survivors
(racer.py) → memoize by store signature (sidecar.py). Pure store-level:
no ``Index`` handle involved, so the api layer can call down without an
import cycle, and benches/tests can tune a bare store directly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from repro.tune import sidecar
from repro.tune.candidates import TunedConfig, candidate_grid
from repro.tune.racer import race_candidates
from repro.tune.seed import seed_candidates
from repro.tune.signature import signature_of
from repro.utils import get_logger

log = get_logger("repro.tune")

TUNE_QUERIES = 8        # default synthetic tuning batch (pow2: warm-chain)


def synth_queries(store, rng, Q: int = TUNE_QUERIES) -> np.ndarray:
    """Synthetic tuning batch for dense/rotated boxes: live corpus rows
    plus noise, so the tuning races see realistic distance gaps rather
    than isotropic worst-case ones. Sparse boxes have no dense rows to
    perturb — callers must supply real queries."""
    if store.kind == "sparse":
        raise ValueError("a sparse index needs explicit tuning queries "
                         "(pass the (q_idx, q_val, q_nnz) triplet)")
    leaf = store.shards[0] if hasattr(store, "shards") else store
    x = np.asarray(leaf.x, np.float32)
    alive = np.flatnonzero(np.asarray(leaf.alive))
    kq, kn = jax.random.split(rng)
    rows = np.asarray(jax.random.choice(kq, alive, shape=(Q,)))
    noise = 0.1 * np.asarray(
        jax.random.normal(kn, (Q, leaf.d_pad)), np.float32)
    qs = x[rows] + noise * np.std(x[rows], axis=-1, keepdims=True)
    return qs[:, : store.d]


def tune_store(store, queries=None, rng=None, *, levels: int = 2,
               reps: int = 1, max_candidates: int = 8,
               prune_ratio: float = 3.0, force: bool = False,
               ) -> Tuple[TunedConfig, dict]:
    """Race the candidate grid on ``store``; returns (winner, report).

    The winner carries measured ``epoch_ms`` / ``round_ms`` (the deadline
    planner's cost basis) and is memoized in the in-process cache keyed by
    the store's signature — equal-signature stores reuse it without
    re-racing unless ``force``.
    """
    sig = signature_of(store)
    if not force:
        hit = sidecar.cache_get(sig)
        if hit is not None:
            return hit, {"signature": sig.to_dict(), "cached": True,
                         "config": hit.to_dict()}
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    if queries is None:
        rng, kq = jax.random.split(rng)
        queries = synth_queries(store, kq)
    cands = candidate_grid(store, backend=sig.backend)
    survivors, model_report = seed_candidates(
        store, cands, max_candidates=max_candidates,
        prune_ratio=prune_ratio)
    log.info("tune: %d candidates, %d after roofline prune (sig=%s)",
             len(cands), len(survivors), sig.key())
    winner, results = race_candidates(store, survivors, queries, rng,
                                      levels=levels, reps=reps)
    tuned = winner.cand.with_measured(epoch_ms=winner.epoch_ms,
                                      round_ms=winner.round_ms)
    sidecar.cache_put(sig, tuned)
    default_ms = next((m.median_ms for m in results
                       if m.cand == survivors[0]), float("nan"))
    log.info("tune: winner %s — %.1f ms vs %.1f ms default",
             tuned.to_dict(), winner.median_ms, default_ms)
    report = {
        "signature": sig.to_dict(),
        "cached": False,
        "config": tuned.to_dict(),
        "grid_size": len(cands),
        "raced": len(survivors),
        "model": model_report,
        "measurements": [m.to_dict() for m in results],
        "winner_median_ms": winner.median_ms,
        "default_median_ms": default_ms,
    }
    return tuned, report
