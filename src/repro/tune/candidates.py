"""Tuned-config records and the candidate grid (DESIGN.md §9.1).

``TunedConfig`` is the unit the autotuner races, persists, and the
``Index`` handle applies: the per-store knobs that trade launch overhead
against wasted pulls —

  * ``epoch_rounds`` (R)      — racing rounds fused per kernel launch,
  * ``pulls_per_round`` (P)   — block pulls folded per round (T = R·P),
  * ``batch_arms`` (B)        — arms racing per launch,
  * ``frontier_floor``        — smallest survivor bucket the frontier
                                shrinks to (0 = derived),
  * ``kernel_buffers``        — VMEM streaming slots in the Pallas kernel,
  * ``mode``                  — fused-epoch vs per-round driver,

plus the measured per-epoch / per-round wall costs the racer observed —
the estimates the serving plane's deadline-aware round selection runs on.

The grid is deliberately small and pow2-shaped: every member must be a
config the warm-start compile chain can serve without mid-traffic
recompiles, and the roofline pre-pass (seed.py) prunes it further before
anything is timed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.configs.base import BMOConfig

#: bump on any TunedConfig field change — stale sidecars then fail closed.
TUNED_VERSION = 1

#: BMOConfig fields a TunedConfig overrides when bound.
_BIND_FIELDS = ("epoch_rounds", "pulls_per_round", "batch_arms",
                "frontier_floor", "kernel_buffers")


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    epoch_rounds: int
    pulls_per_round: int
    batch_arms: int
    frontier_floor: int = 0
    kernel_buffers: int = 2
    mode: str = "auto"            # dispatch default when the spec says auto
    epoch_ms: float = 0.0         # measured mean wall per fused epoch
    round_ms: float = 0.0         # measured mean wall per racing round

    def bind(self, cfg: BMOConfig) -> BMOConfig:
        """Apply the racing knobs onto a store's build-time config (k, δ,
        metric, budgets stay the store's own — tuning never changes what
        the race certifies, only what it costs)."""
        return dataclasses.replace(
            cfg, **{f: getattr(self, f) for f in _BIND_FIELDS})

    def with_measured(self, *, epoch_ms: float,
                      round_ms: float) -> "TunedConfig":
        return dataclasses.replace(self, epoch_ms=float(epoch_ms),
                                   round_ms=float(round_ms))

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TunedConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: d[k] for k in fields})

    @classmethod
    def from_cfg(cls, cfg: BMOConfig, mode: str = "auto") -> "TunedConfig":
        """The identity candidate: the store's hand-set defaults. Always in
        the race, so tuning can only tie or win."""
        return cls(mode=mode,
                   **{f: getattr(cfg, f) for f in _BIND_FIELDS})


def candidate_grid(store, *, backend: str = "") -> List[TunedConfig]:
    """Enumerate the (R, P, B, floor, buffers, mode) grid for ``store``.

    Sparse boxes race on the per-round driver only (no corpus blocks to
    fuse), so their grid is the R sweep at mode="rounds". Dense/rotated
    boxes get the fused cross product plus one per-round candidate —
    cheap insurance for shapes where launch fusion does not pay.
    ``kernel_buffers`` only varies where the Pallas kernel actually runs
    (TPU); the ref/XLA interpreters ignore the knob, so racing it on CPU
    would just time noise. The identity candidate (the store's current
    config) is always first.
    """
    if not backend:
        import jax
        backend = jax.default_backend()
    cfg = store.cfg
    n = store.n_live
    out = [TunedConfig.from_cfg(cfg)]
    if store.kind == "sparse":
        for R in (2, 4, 8):
            out.append(TunedConfig(
                epoch_rounds=R, pulls_per_round=cfg.pulls_per_round,
                batch_arms=cfg.batch_arms, mode="rounds"))
        return _dedup(out)
    n_blocks = max(store.d // store.block, 1)
    bufs = (2, 4) if backend == "tpu" else (2,)
    for R in (2, 4, 8):
        for P in (1, 2, 4):
            if R * P > 4 * n_blocks:   # epoch pulls > 4 passes over the
                continue               # row's blocks: pure waste
            for B in (16, 32, 64):
                if B > n:
                    continue
                for floor in (0, 128):
                    for nb in bufs:
                        out.append(TunedConfig(
                            epoch_rounds=R, pulls_per_round=P,
                            batch_arms=B, frontier_floor=floor,
                            kernel_buffers=nb, mode="fused"))
    # one per-round fallback arm (launch fusion is not always a win)
    out.append(TunedConfig(
        epoch_rounds=cfg.epoch_rounds, pulls_per_round=cfg.pulls_per_round,
        batch_arms=cfg.batch_arms, mode="rounds"))
    return _dedup(out)


def _dedup(cands: List[TunedConfig]) -> List[TunedConfig]:
    seen, out = set(), []
    for c in cands:
        key = dataclasses.astuple(dataclasses.replace(
            c, epoch_ms=0.0, round_ms=0.0))
        if key not in seen:
            seen.add(key)
            out.append(c)
    return out


def bind_store(store, cfg: BMOConfig):
    """Rebind a (possibly sharded) store onto ``cfg`` without touching its
    arrays — the tuner-side twin of ``repro.api.handle._with_cfg`` (kept
    local: repro.tune must not import the api layer)."""
    if hasattr(store, "shards"):
        return dataclasses.replace(
            store, shards=[dataclasses.replace(s, cfg=cfg)
                           for s in store.shards])
    return dataclasses.replace(store, cfg=cfg)


def tuned_mode(tuned: Optional["TunedConfig"], spec_mode: str) -> str:
    """Dispatch-time mode resolution: an explicit spec mode always wins;
    "auto" defers to the tuned preference when one is installed."""
    if spec_mode != "auto" or tuned is None:
        return spec_mode
    return tuned.mode
