"""The measurement racer: successive halving over candidate configs
(DESIGN.md §9.4).

The tuner is the paper's own trick turned on itself: each candidate
config is an arm whose "distance" is its measured wall time per racing
batch, and we run a bandit race over the arms — successive halving
(Neufeld et al. 2014; LeJeune et al. 2019 use the same schedule for the
estimator race) rather than full CIs, because the arm count is tiny and
halving gives a deterministic measurement budget:

  level 0: every survivor pays 1 warmup race (compile pollution lands
           here, outside the clock) + ``reps`` timed races → keep the
           faster half;
  level l: survivors pay ``reps · 2^l`` timed races → keep half;
  final:   the minimum-median survivor wins.

Per-epoch / per-round costs are read from a *private* ``ObsContext``
swapped in around each candidate's races — the PR-6 observability
histograms are the measurement substrate, so the tuner measures exactly
what serving will later report, and the process-default metrics stay
unpolluted by tuning traffic.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Tuple

import jax
import numpy as np

from repro.obs import ObsContext, set_obs
from repro.tune.candidates import TunedConfig, bind_store
from repro.utils.hostsync import host_fetch

#: histogram kinds the blocking drivers record epoch walls under
_EPOCH_KINDS = ("fused_blocking", "sharded_fused_blocking")


@dataclasses.dataclass
class Measurement:
    cand: TunedConfig
    wall_ms: List[float]            # timed race walls (per rep)
    epoch_ms: float = 0.0           # mean wall per fused epoch
    round_ms: float = 0.0           # mean wall per racing round

    @property
    def median_ms(self) -> float:
        return float(np.median(self.wall_ms)) if self.wall_ms else float("inf")

    def to_dict(self) -> dict:
        return {"cand": self.cand.to_dict(), "wall_ms": list(self.wall_ms),
                "median_ms": self.median_ms, "epoch_ms": self.epoch_ms,
                "round_ms": self.round_ms}


def _race_once(store, queries, rng, mode: str) -> Tuple[float, float]:
    """One timed race; returns (wall_ms, max_rounds_paid)."""
    from repro.index.batched_race import index_knn
    t0 = time.perf_counter()
    res = index_knn(store, queries, rng, mode=mode)
    host_fetch(res.indices)         # block on device completion
    wall = (time.perf_counter() - t0) * 1e3
    return wall, float(np.max(host_fetch(res.rounds)))


def measure_candidate(store, cand: TunedConfig, queries, rng, *,
                      reps: int = 1, warmup: bool = True) -> Measurement:
    """Time ``reps`` races of ``store`` rebound onto ``cand``.

    The warmup race (not timed) eats every fresh-XLA compile the
    candidate's (B, T) specializations need; the timed reps then measure
    steady-state serving cost — the quantity the winner's sidecar
    promises. Epoch/round costs come from the private obs context's
    ``repro_race_epoch_ms`` histogram.
    """
    bound = bind_store(store, cand.bind(store.cfg))
    mode = cand.mode if cand.mode != "auto" else (
        "rounds" if store.kind == "sparse" else "fused")
    ctx = ObsContext("tune", enabled=False)     # metrics only, no events
    old = set_obs(ctx)
    try:
        if warmup:
            _race_once(bound, queries, rng, mode)
        walls, rounds_hi = [], 1.0
        for r in range(reps):
            wall, rounds = _race_once(
                bound, queries, jax.random.fold_in(rng, r + 1), mode)
            walls.append(wall)
            rounds_hi = max(rounds_hi, rounds)
    finally:
        set_obs(old)
    hist_sum = hist_count = 0.0
    for kind in _EPOCH_KINDS:
        h = ctx.registry.histogram("repro_race_epoch_ms",
                                   "wall time of one race epoch (ms)",
                                   kind=kind)
        hist_sum += h.sum
        hist_count += h.count
    n_races = reps + (1 if warmup else 0)
    epoch_ms = hist_sum / hist_count if hist_count else 0.0
    # rounds_hi rounds per race → per-round wall from the epoch histogram
    round_ms = (hist_sum / n_races) / max(rounds_hi, 1.0) if hist_count \
        else float(np.median(walls)) / max(rounds_hi, 1.0)
    return Measurement(cand=cand, wall_ms=walls, epoch_ms=epoch_ms,
                       round_ms=round_ms)


def race_candidates(store, cands: List[TunedConfig], queries, rng, *,
                    levels: int = 2, reps: int = 1,
                    ) -> Tuple[Measurement, List[Measurement]]:
    """Successive halving over ``cands``; returns (winner, all results).

    ``levels`` halving rounds double the rep count as the field narrows,
    so total measurement cost stays ~constant per level while the
    surviving arms get tighter estimates — the classic fixed-budget
    schedule. Measurements accumulate across levels (a survivor keeps its
    earlier reps; medians only sharpen).
    """
    field: List[Measurement] = []
    for c in cands:
        field.append(measure_candidate(store, c, queries, rng, reps=reps))
    results = list(field)           # every measurement, eliminated or not
    for level in range(1, max(levels, 1)):
        if len(field) <= 1:
            break
        field.sort(key=lambda m: m.median_ms)
        field = field[: max((len(field) + 1) // 2, 1)]
        for m in field:
            more = measure_candidate(
                store, m.cand, queries, jax.random.fold_in(rng, 1000 + level),
                reps=reps * (2 ** level), warmup=False)
            m.wall_ms.extend(more.wall_ms)
            if more.epoch_ms:
                m.epoch_ms = more.epoch_ms
            if more.round_ms:
                m.round_ms = more.round_ms
    field.sort(key=lambda m: m.median_ms)
    return field[0], results
