"""Store signatures — the cache key of a tuned configuration
(DESIGN.md §9.2).

A tuned config is only as good as the workload it was raced on. The
signature captures every store property that moves the cost landscape the
racer optimized over — corpus scale (pow2-bucketed, so inserts don't
invalidate a tuning until the scale actually doubles), dimensionality,
dtype, box kind (dense / rotated / sparse), the backing accelerator, the
shard count, and the corpus block width. Two stores with equal signatures
share a tuned config; a signature mismatch at load time means the sidecar
was tuned for a different workload and MUST be ignored (fall back to
build-time defaults bit-compatibly) rather than half-applied.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

from repro.core.datasets import next_pow2

#: bump when the signature fields change — old sidecars then fail closed.
SIGNATURE_SCHEME = 1


@dataclasses.dataclass(frozen=True)
class StoreSignature:
    scheme: int       # SIGNATURE_SCHEME at write time
    n_bucket: int     # next_pow2(n_live): scale bucket, insert-stable
    d: int            # corpus dimensionality (pre-padding)
    dtype: str        # corpus dtype ("float32", "bfloat16", …)
    kind: str         # dense | rotated | sparse
    backend: str      # jax.default_backend() at tune time (cpu/tpu/gpu)
    shards: int       # mesh width (1 = single shard)
    block: int        # corpus block width the kernels pull at

    def key(self) -> tuple:
        return dataclasses.astuple(self)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "StoreSignature":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: d[k] for k in fields})


def signature_of(store, backend: str = "") -> StoreSignature:
    """Signature of an ``IndexStore`` / ``ShardedIndexStore`` as served."""
    if not backend:
        import jax
        backend = jax.default_backend()
    shards = store.n_shards if hasattr(store, "shards") else 1
    leaf = store.shards[0] if hasattr(store, "shards") else store
    arr = leaf.x if leaf.x is not None else leaf.values
    return StoreSignature(
        scheme=SIGNATURE_SCHEME,
        n_bucket=next_pow2(max(store.n_live, 1)),
        d=store.d,
        dtype=str(arr.dtype),
        kind=store.kind,
        backend=backend,
        shards=shards,
        block=store.block,
    )
