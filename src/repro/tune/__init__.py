"""repro.tune — the self-racing autotuner (DESIGN.md §9).

Every hand-set performance constant in the serving stack — fused rounds
per launch R, pulls per round P, arms per launch B, the frontier's bucket
floor, the Pallas kernel's VMEM streaming depth, fused-vs-rounds dispatch
— is really a per-workload decision: the right values move with corpus
scale, dimensionality, dtype, sparsity, and the accelerator underneath.
This package turns the paper's own machinery on those constants:

  candidates.py — the (R, P, B, floor, buffers, mode) grid + TunedConfig
  seed.py       — roofline model pre-pass prunes the grid before timing
  racer.py      — successive-halving measurement race over survivors
  signature.py  — (n-bucket, d, dtype, kind, backend, shards, block) key
  sidecar.py    — tuned.json checkpoint sidecar + in-process cache
  autotune.py   — tune_store: the end-to-end pass

The api layer exposes it as ``Index.tune()`` (an admin op under the epoch
fence) and persists the winner with ``Index.save`` / applies it on
``Index.load`` when the signature still matches — see api/handle.py.
"""
from repro.tune.autotune import synth_queries, tune_store
from repro.tune.candidates import (TUNED_VERSION, TunedConfig, bind_store,
                                   candidate_grid, tuned_mode)
from repro.tune.racer import Measurement, measure_candidate, race_candidates
from repro.tune.seed import model_efficiency, seed_candidates
from repro.tune.sidecar import (TUNED_FILE, cache_clear, cache_get,
                                cache_put, load_tuned, save_tuned)
from repro.tune.signature import (SIGNATURE_SCHEME, StoreSignature,
                                  signature_of)

__all__ = [
    "Measurement", "SIGNATURE_SCHEME", "StoreSignature", "TUNED_FILE",
    "TUNED_VERSION", "TunedConfig", "bind_store", "cache_clear",
    "cache_get", "cache_put", "candidate_grid", "load_tuned",
    "measure_candidate", "model_efficiency", "race_candidates",
    "save_tuned", "seed_candidates", "signature_of", "synth_queries",
    "tune_store", "tuned_mode",
]
