"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]."""
from repro.configs.base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,                 # shared block MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,               # shared attn+MLP block after every 6 mamba layers
    mlp_act="gelu",
)

PLAN = ParallelPlan(fsdp=False, tp=True, sp=False, ep=False,
                    grad_accum=2, optimizer="adamw", param_dtype="float32")

SMOKE = CONFIG.scaled(n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
                      head_dim=32, d_ff=128, vocab_size=256, ssm_state=16,
                      ssm_head_dim=16, attn_every=2)
