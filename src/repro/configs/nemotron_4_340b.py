"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP [arXiv:2402.16819]."""
from repro.configs.base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    mlp_act="sq_relu",
)

PLAN = ParallelPlan(fsdp=True, tp=True, sp=True, ep=False,
                    grad_accum=16, optimizer="adafactor", param_dtype="bfloat16")

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=256, vocab_size=256)
