"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (vision tower stubbed)
[arXiv:2409.12191]."""
from repro.configs.base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope_type="mrope",
    mrope_sections=(16, 24, 24),   # sum = head_dim/2 = 64
    rope_theta=1e6,
)

PLAN = ParallelPlan(fsdp=False, tp=True, sp=False, ep=False,
                    grad_accum=2, optimizer="adamw", param_dtype="float32")

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab_size=256, head_dim=16,
                      mrope_sections=(4, 2, 2))
