"""The paper's own workload config: BMO-NN k-nearest-neighbour retrieval.

Matches the paper's two evaluation regimes:
  * dense:  Tiny-ImageNet-like  n=100k, d=12288 (§V, Figs 2/3)
  * sparse: 10x-genomics-like   n=100k, d=28672, 7% nnz (§V, Fig 4b)
"""
import dataclasses

from repro.configs.base import BMOConfig


@dataclasses.dataclass(frozen=True)
class BMONNWorkload:
    name: str
    n_points: int
    dim: int
    n_queries: int
    sparsity: float            # fraction of nonzeros (1.0 = dense)
    bmo: BMOConfig


DENSE = BMONNWorkload(
    name="bmo-nn-dense",
    n_points=100_000,
    dim=12_288,
    n_queries=1024,
    sparsity=1.0,
    bmo=BMOConfig(k=5, delta=0.01, block=128, batch_arms=32, metric="l2",
                  rotate=True),
)

SPARSE = BMONNWorkload(
    name="bmo-nn-sparse",
    n_points=100_000,
    dim=28_672,
    n_queries=1024,
    sparsity=0.07,
    bmo=BMOConfig(k=5, delta=0.01, block=1, batch_arms=32, metric="l1",
                  sparse=True),
)

SMOKE = BMONNWorkload(
    name="bmo-nn-smoke",
    n_points=256,
    dim=512,
    n_queries=8,
    sparsity=1.0,
    bmo=BMOConfig(k=3, delta=0.05, block=32, batch_arms=8, metric="l2"),
)
