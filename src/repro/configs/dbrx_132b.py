"""dbrx-132b [moe] — 16 experts top-4, fine-grained
[hf:databricks/dbrx-base]."""
from repro.configs.base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    n_experts_active=4,
    moe_d_ff=10752,
    rope_theta=5e5,
)

PLAN = ParallelPlan(fsdp=True, tp=True, sp=True, ep=True,
                    grad_accum=8, optimizer="adafactor", param_dtype="bfloat16")

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, moe_d_ff=128, vocab_size=256,
                      n_experts=4, n_experts_active=2)
