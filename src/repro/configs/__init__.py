from repro.configs.base import (
    BMOConfig, ModelConfig, ParallelPlan, ShapeConfig, SHAPES, TrainConfig,
)
from repro.configs.registry import ARCHS, get_arch, list_archs

__all__ = [
    "BMOConfig", "ModelConfig", "ParallelPlan", "ShapeConfig", "SHAPES",
    "TrainConfig", "ARCHS", "get_arch", "list_archs",
]
