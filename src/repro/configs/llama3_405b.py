"""llama3-405b [dense] — GQA, 128k vocab [arXiv:2407.21783]."""
from repro.configs.base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=5e5,
)

PLAN = ParallelPlan(fsdp=True, tp=True, sp=True, ep=False,
                    grad_accum=16, optimizer="adafactor", param_dtype="bfloat16")

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=256, vocab_size=256)
