"""granite-34b [dense] — llama-arch MQA code model [arXiv:2405.04324]."""
from repro.configs.base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,               # MQA
    d_ff=24576,
    vocab_size=49152,
)

PLAN = ParallelPlan(fsdp=True, tp=True, sp=True, ep=False,
                    grad_accum=16, optimizer="adamw", param_dtype="float32")

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
                      d_ff=128, vocab_size=256)
