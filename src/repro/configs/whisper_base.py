"""whisper-base [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=12,                 # 6 enc + 6 dec
    enc_layers=6,
    dec_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    mlp_act="gelu",
    rope_type="none",
    tie_embeddings=True,
    dec_seq_div=8,
)

PLAN = ParallelPlan(fsdp=False, tp=False, sp=False, ep=False,
                    grad_accum=1, optimizer="adamw", param_dtype="float32")

SMOKE = CONFIG.scaled(enc_layers=2, dec_layers=2, n_layers=4, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256)
