"""Config dataclasses: model, input shape, parallelism plan, run."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default: d_model // n_heads
    # --- MLP / attention flavor ---
    mlp_act: str = "swiglu"          # swiglu | gelu | sq_relu
    qkv_bias: bool = False
    # --- position encoding ---
    rope_theta: float = 1.0e4
    rope_type: str = "rope"          # rope | mrope | sinusoidal | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # --- MoE ---
    n_experts: int = 0
    n_experts_active: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    router_type: str = "softmax"     # softmax | sigmoid (deepseek-v3)
    moe_capacity_factor: float = 1.25  # expert capacity = tokens·k/E·factor;
                                     # ≥ E/k makes dispatch dropless
    moe_seq_chunk: int = 8192        # dispatch ≤ this many tokens/shard at once
    # --- MLA (deepseek-v3) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0               # multi-token-prediction extra depth
    # --- SSM / xLSTM ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    slstm_every: int = 0             # xlstm: every k-th layer is sLSTM (0 = none)
    # --- hybrid (zamba2) ---
    attn_every: int = 0              # shared attention block period (0 = never)
    # --- enc-dec (whisper) ---
    enc_layers: int = 0
    dec_layers: int = 0
    dec_seq_div: int = 8             # decoder seq = seq_len // dec_seq_div
    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attn_chunk: int = 1024           # q-block size for chunked attention (S > 8k)
    kv_quant: bool = False           # int8 KV cache (+per-token-head scales)
    attn_impl: str = "auto"          # auto | xla | pallas (fused kernel)

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced copy for smoke tests."""
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    fsdp: bool = False
    tp: bool = True
    sp: bool = False
    ep: bool = False
    grad_accum: int = 1
    remat: str = "full"              # none | full | dots
    optimizer: str = "adamw"         # adamw | adafactor
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    kv_len_shard: bool = False       # shard KV caches along seq (decode perf)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class BMOConfig:
    """Paper-technique hyper-parameters (Alg. 1/2 + §IV + App. D-A)."""

    k: int = 5                       # number of nearest neighbours
    delta: float = 0.01              # failure probability
    block: int = 128                 # TPU coordinate-block width (1 = paper's exact scheme)
    batch_arms: int = 32             # arms raced per round (paper App. D-A: 32)
    pulls_per_round: int = 2         # blocks pulled per selected arm per round
    init_pulls: int = 2              # initial blocks pulled on every arm
    metric: str = "l2"               # l2 | l1
    rotate: bool = False             # §IV-B randomized Hadamard pre-rotation
    sparse: bool = False             # §IV-A sparse Monte-Carlo box
    epsilon: float = 0.0             # >0 → PAC variant (Thm 2)
    sigma: Optional[float] = None    # sub-Gaussian bound; None = empirical (App. D-A)
    max_rounds: int = 0              # 0 = derived from d/block
    epoch_rounds: int = 4            # racing rounds fused per kernel launch
                                     # (epoch-fused serving driver; grows as
                                     # the survivor frontier shrinks)
    frontier_floor: int = 0          # smallest survivor-bucket width the
                                     # frontier may shrink to (0 = derived
                                     # from batch_arms/k; repro.tune sets it)
    kernel_buffers: int = 2          # VMEM streaming slots in the fused
                                     # Pallas kernel (2 = double buffering)
