"""qwen2.5-14b [dense] — GQA with QKV bias [hf:Qwen/Qwen2.5]."""
from repro.configs.base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
)

PLAN = ParallelPlan(fsdp=True, tp=True, sp=True, ep=False,
                    grad_accum=8, optimizer="adamw", param_dtype="float32")

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab_size=256)
