"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437]."""
from repro.configs.base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                 # dense FFN of the first_dense_layers
    vocab_size=129280,
    # MoE
    n_experts=256,
    n_experts_active=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=3,
    router_type="sigmoid",
    # MLA
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    head_dim=192,               # qk_nope + qk_rope
    mtp_depth=1,
)

PLAN = ParallelPlan(fsdp=True, tp=True, sp=True, ep=True,
                    grad_accum=16, optimizer="adafactor", param_dtype="bfloat16")

# DeepSeek-V3 routes droplessly (aux-loss-free balancing, "no token
# dropping", §4.2 of the tech report); at smoke scale droplessness is
# realized exactly with factor = E/k, so prefill/decode/full-pass logits are
# bit-consistent (test_decode_consistency). The real config keeps the
# capacity approximation — factor E/k = 32 would blow the dispatch buffer to
# E×T×d at 32k prefill.
SMOKE = CONFIG.scaled(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
    n_experts=8, n_experts_active=2, moe_d_ff=32, first_dense_layers=1,
    q_lora_rank=32, kv_lora_rank=16, qk_rope_dim=8, qk_nope_dim=16,
    v_head_dim=16, head_dim=24, mtp_depth=1, moe_capacity_factor=4.0)
