"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517]."""
from repro.configs.base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                      # no separate FFN; projections live in-block
    vocab_size=50304,
    ssm_conv=4,
    slstm_every=4,               # every 4th block is sLSTM (6 of 24)
    rope_type="none",
)

PLAN = ParallelPlan(fsdp=False, tp=True, sp=False, ep=False,
                    grad_accum=4, optimizer="adamw", param_dtype="float32")

# reduced config for CPU smoke tests
SMOKE = CONFIG.scaled(n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
                      vocab_size=256, slstm_every=2)
