"""Architecture registry: ``--arch <id>`` → (ModelConfig, ParallelPlan, SMOKE)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

from repro.configs.base import ModelConfig, ParallelPlan

_MODULES = {
    "xlstm-350m": "repro.configs.xlstm_350m",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "granite-34b": "repro.configs.granite_34b",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "llama3-405b": "repro.configs.llama3_405b",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "whisper-base": "repro.configs.whisper_base",
}

ARCHS = tuple(_MODULES)

# shapes skipped per arch (with reason), see DESIGN.md §Arch-applicability
SKIPS = {
    "long_500k": {
        "deepseek-v3-671b": "full attention (MLA) — quadratic history",
        "dbrx-132b": "full attention — quadratic history",
        "granite-34b": "full attention — quadratic history",
        "nemotron-4-340b": "full attention — quadratic history",
        "llama3-405b": "full attention — quadratic history",
        "qwen2.5-14b": "full attention — quadratic history",
        "qwen2-vl-2b": "full attention — quadratic history",
        "whisper-base": "full attention enc-dec — quadratic history",
    },
}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    config: ModelConfig
    plan: ParallelPlan
    smoke: ModelConfig


def get_arch(arch_id: str) -> ArchEntry:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch_id])
    return ArchEntry(arch_id, mod.CONFIG, mod.PLAN, mod.SMOKE)


def list_archs():
    return list(ARCHS)


def shape_skip_reason(arch_id: str, shape_name: str) -> Optional[str]:
    return SKIPS.get(shape_name, {}).get(arch_id)
