"""Straggler mitigation.

On a real pod the first-order mitigations are (a) replacing the slow slice
and (b) skipping the straggling data shard for a step; in a single-process
SPMD run we implement the *detection and policy* layer: per-step wall-clock
tracking with a rolling p50/p95, flagging of outlier steps, and a pluggable
policy callback (the training CLI wires it to logging + optional data-shard
skip). See DESIGN.md §4 for the at-scale design.
"""
from __future__ import annotations

import collections
import time
from typing import Callable, Optional

from repro.utils import get_logger

log = get_logger("repro.straggler")


class StragglerWatchdog:
    def __init__(self, *, window: int = 50, p95_factor: float = 2.0,
                 on_straggle: Optional[Callable[[int, float, float], None]] = None):
        self.times = collections.deque(maxlen=window)
        self.p95_factor = p95_factor
        self.on_straggle = on_straggle
        self._t0 = None
        self.flagged = []

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        dt = time.perf_counter() - self._t0
        if len(self.times) >= 10:
            srt = sorted(self.times)
            p50 = srt[len(srt) // 2]
            if dt > self.p95_factor * p50:
                self.flagged.append((step, dt, p50))
                log.warning("straggler step=%d dt=%.3fs p50=%.3fs", step, dt, p50)
                if self.on_straggle:
                    self.on_straggle(step, dt, p50)
        self.times.append(dt)
        return dt
