"""Elastic scaling: resume a checkpoint on a different mesh / device count.

Checkpoints are mesh-independent host arrays (checkpoint/manager.py), so
elasticity reduces to (1) rebuilding the mesh for the surviving device set,
(2) re-deriving shardings from the same logical rules, (3) device_put-ing the
restored state against them. Batch-size invariance across DP width is kept
by the step-keyed data pipeline (global batch fixed; per-device slice
changes). Tested by resuming an 8-device run on 4 devices (subprocess)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.sharding.spec import make_rules
from repro.train.steps import state_pspecs, to_named
from repro.utils import get_logger

log = get_logger("repro.elastic")


def best_mesh_shape(n_devices: int, prefer_model: int) -> Tuple[int, int]:
    """(data, model) with model | n_devices, model ≤ prefer_model, maximal."""
    model = min(prefer_model, n_devices)
    while n_devices % model != 0:
        model -= 1
    return n_devices // model, model


def make_elastic_mesh(prefer_model: int = 16) -> Mesh:
    n = len(jax.devices())
    shape = best_mesh_shape(n, prefer_model)
    return jax.make_mesh(shape, ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def reshard_state(model, plan, mesh: Mesh, state):
    """Re-derive shardings under the (possibly new) mesh and place state."""
    rules = make_rules(fsdp=plan.fsdp, tp=plan.tp, sp=plan.sp, ep=plan.ep,
                       multi_pod="pod" in mesh.axis_names)
    pspecs = state_pspecs(model, plan, rules)
    shardings = to_named(pspecs, mesh)
    placed = jax.tree_util.tree_map(jax.device_put, state, shardings)
    log.info("resharded state onto mesh %s", dict(zip(mesh.axis_names, mesh.devices.shape)))
    return placed, rules
