from repro.runtime.supervisor import Supervisor, FailureInjector
from repro.runtime.straggler import StragglerWatchdog

__all__ = ["Supervisor", "FailureInjector", "StragglerWatchdog"]
