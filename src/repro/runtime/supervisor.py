"""Fault-tolerant step-loop supervisor: run → crash → restore → resume.

The Supervisor owns the (checkpoint manager, loader, step function) triple
and drives training with automatic restart from the last published
checkpoint on any exception, up to ``max_failures``. A FailureInjector makes
the path testable deterministically (tests kill the loop mid-run and assert
bit-identical convergence vs an uninterrupted run, thanks to the
step-keyed deterministic data pipeline).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax

from repro.checkpoint import CheckpointManager
from repro.runtime.straggler import StragglerWatchdog
from repro.utils import get_logger

log = get_logger("repro.supervisor")


class FailureInjector:
    """Raises RuntimeError at the configured global steps (once each)."""

    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)

    def maybe_fail(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class Supervisor:
    ckpt: CheckpointManager
    train_step: Callable            # (state, batch) -> (state, metrics)
    loader: Callable                # step -> batch
    init_state: Callable            # () -> fresh state
    state_shardings: Optional[object] = None
    ckpt_every: int = 50
    max_failures: int = 8
    injector: Optional[FailureInjector] = None

    def run(self, total_steps: int, *, on_metrics=None):
        failures = 0
        watchdog = StragglerWatchdog()
        while True:
            try:
                state, meta = (None, None)
                like = jax.eval_shape(self.init_state)
                state, meta = self.ckpt.restore_latest(
                    like, shardings=self.state_shardings)
                if state is None:
                    state = self.init_state()
                    start = 0
                    log.info("fresh start")
                else:
                    start = int(meta["step"]) + 1
                    log.info("resumed from step %d", start - 1)
                for step in range(start, total_steps):
                    if self.injector:
                        self.injector.maybe_fail(step)
                    watchdog.start()
                    batch = self.loader(step)
                    state, metrics = self.train_step(state, batch)
                    if on_metrics is not None:
                        on_metrics(step, metrics)
                    watchdog.stop(step)
                    if (step + 1) % self.ckpt_every == 0 or step == total_steps - 1:
                        self.ckpt.save(step, state)
                self.ckpt.wait()
                return state
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — restartable failure domain
                failures += 1
                log.warning("step loop failed (%s); restart %d/%d",
                            e, failures, self.max_failures)
                if failures > self.max_failures:
                    raise
