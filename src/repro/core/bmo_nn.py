"""BMO-NN (paper Algorithm 2): k-nearest neighbours via BMO-UCB, for the
three Monte-Carlo boxes of the paper:

  * dense   (§III):   uniform coordinate/block sampling, ℓ1 or ℓ2²,
  * rotated (§IV-B):  dense box on x' = H D x (ℓ2 only; the rotation makes
                      coordinates exchangeable — which also justifies the
                      TPU block sampling, see DESIGN.md §2),
  * sparse  (§IV-A):  support-union importance sampling (Eq. 12), ℓ1.

θ_i = ρ(q, x_i)/d throughout (the paper's mean normalization).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BMOConfig
from repro.core.datasets import DenseDataset, SparseDataset, hadamard_rotate
from repro.core.ucb import RaceResult, race_topk
from repro.kernels import ops as kops


class KNNResult(NamedTuple):
    indices: jax.Array     # (Q, k)
    values: jax.Array      # (Q, k) θ estimates (ρ/d)
    coord_ops: jax.Array   # (Q,) coordinate-wise distance computations
    rounds: jax.Array      # (Q,)
    n_exact: jax.Array     # (Q,)


# ---------------------------------------------------------------------------
# dense / rotated boxes
# ---------------------------------------------------------------------------


def _dense_pull_fn(ds: DenseDataset, q: jax.Array, cfg: BMOConfig, impl: str):
    nb = ds.n_blocks

    def pull(arm_idx, rng):
        B = arm_idx.shape[0]
        blk = jax.random.randint(rng, (B, cfg.pulls_per_round), 0, nb)
        return kops.block_pull(ds.x, q, arm_idx, blk, block=ds.block,
                               metric=cfg.metric, impl=impl)

    return pull


def _dense_exact_fn(ds: DenseDataset, q: jax.Array, cfg: BMOConfig, impl: str):
    def exact(arm_idx):
        rows = ds.x[arm_idx]                       # (B, d_pad)
        dist = kops.pairwise_dist(q[None], rows, metric=cfg.metric, impl=impl)
        return dist[0] / ds.d                       # θ units

    return exact


def query_dense(ds: DenseDataset, q: jax.Array, cfg: BMOConfig, rng: jax.Array,
                *, impl: str = "auto", eliminate: bool = True) -> RaceResult:
    """k-NN of one query against a dense corpus. ``q`` already padded."""
    max_pulls = ds.d_pad // ds.block               # = d/B blocks ≙ d coords
    return race_topk(
        _dense_pull_fn(ds, q, cfg, impl),
        _dense_exact_fn(ds, q, cfg, impl),
        n=ds.n,
        max_pulls=max_pulls,
        pull_cost=float(ds.block),
        exact_cost=float(ds.d),
        cfg=cfg, rng=rng, eliminate=eliminate,
    )


# ---------------------------------------------------------------------------
# sparse box (§IV-A, Eq. 12)
# ---------------------------------------------------------------------------


def _sparse_lookup(indices_row, values_row, t):
    """value of the row at coordinate t (0 if absent) + membership flag."""
    pos = jnp.searchsorted(indices_row, t)
    pos = jnp.clip(pos, 0, indices_row.shape[0] - 1)
    found = indices_row[pos] == t
    return jnp.where(found, values_row[pos], 0.0), found


def sparse_pull_one(ds: SparseDataset, q_idx, q_val, q_nnz, arm, key):
    """One Eq. 12 sample of θ̂ for (query, arm). Module-level so both the
    per-query racer here and index.batched_race can vmap over it."""
    d = ds.d
    k1, k2, k3 = jax.random.split(key, 3)
    ai, av, an = ds.indices[arm], ds.values[arm], ds.nnz[arm]
    tot = (q_nnz + an).astype(jnp.float32)
    from_query = jax.random.uniform(k1) < q_nnz / jnp.maximum(tot, 1.0)
    # sample a support coordinate from the chosen side
    tq = q_idx[jax.random.randint(k2, (), 0, jnp.maximum(q_nnz, 1))]
    ta = ai[jax.random.randint(k3, (), 0, jnp.maximum(an, 1))]
    t = jnp.where(from_query, tq, ta)
    # both sides' values at t
    va, found_a = _sparse_lookup(ai, av, t)
    vq, found_q = _sparse_lookup(q_idx, q_val, t)
    in_other = jnp.where(from_query, found_a, found_q)
    mult = tot / (2.0 * d) * (1.0 + (~in_other).astype(jnp.float32))
    # Eq. 12 value (ℓ1 coordinate distance), θ normalized by d already
    val = mult * jnp.abs(vq - va)
    # degenerate both-sides-empty case (tombstoned/zero rows racing a zero
    # query): the support union is empty so θ = 0 exactly; the sampled
    # coordinate above came from padding and must not contribute
    return jnp.where(tot > 0, val, 0.0)


def _sparse_pull_fn(ds: SparseDataset, q_idx, q_val, q_nnz, cfg: BMOConfig):
    def pull(arm_idx, rng):
        B = arm_idx.shape[0]
        P = cfg.pulls_per_round
        keys = jax.random.split(rng, B * P).reshape(B, P, 2)
        return jax.vmap(lambda a, ks: jax.vmap(
            lambda kk: sparse_pull_one(ds, q_idx, q_val, q_nnz, a, kk))(ks))(
            arm_idx, keys).astype(jnp.float32)

    return pull


def sparse_exact_theta(ds: SparseDataset, q_idx, q_val, arm_idx):
    """θ_i = ‖q − x_i‖₁ / d via support-merge lookups: Σ_{t∈Sq}|q_t − x_t| +
    Σ_{t∈Si, t∉Sq} |x_t|.  Cost ≈ n_q + n_i lookups (the paper's
    sparsity-aware exact baseline)."""

    def one(arm):
        ai, av = ds.indices[arm], ds.values[arm]
        xa_at_q, _ = jax.vmap(lambda t: _sparse_lookup(ai, av, t))(q_idx)
        term1 = jnp.sum(jnp.abs(q_val - xa_at_q) * (q_idx < ds.d))
        _, in_q = jax.vmap(lambda t: _sparse_lookup(q_idx, q_val, t))(ai)
        term2 = jnp.sum(jnp.abs(av) * (~in_q) * (ai < ds.d))
        return (term1 + term2) / ds.d

    return jax.vmap(one)(arm_idx)


def query_sparse(ds: SparseDataset, q_idx, q_val, q_nnz, cfg: BMOConfig,
                 rng: jax.Array, *, eliminate: bool = True) -> RaceResult:
    """k-NN of one sparse query (padded index/value rows) — ℓ1 only."""
    exact_cost = (ds.nnz + q_nnz).astype(jnp.float32)
    # an arm is 'exactly evaluable' after ~support-size pulls (cost parity
    # with the sparse exact computation), min 8 to keep CIs meaningful
    max_pulls = jnp.maximum(exact_cost, 8.0)
    return race_topk(
        _sparse_pull_fn(ds, q_idx, q_val, q_nnz, cfg),
        lambda arm_idx: sparse_exact_theta(ds, q_idx, q_val, arm_idx),
        n=ds.n,
        max_pulls=max_pulls,
        pull_cost=1.0,
        exact_cost=exact_cost,
        cfg=cfg, rng=rng, eliminate=eliminate,
        max_pulls_static=int(ds.m + q_idx.shape[0]),
    )


# ---------------------------------------------------------------------------
# multi-query drivers (Algorithm 2 iterates queries; embarrassingly parallel)
# ---------------------------------------------------------------------------


def knn(corpus, queries, cfg: BMOConfig, rng: jax.Array, *,
        impl: str = "auto", eliminate: bool = True,
        exclude_self: Optional[jax.Array] = None) -> KNNResult:
    """k-NN of each query row against the corpus.

    corpus: (n, d) array (dense/rotated) or SparseDataset (sparse box).
    queries: (Q, d) array, or (q_idx, q_val, q_nnz) padded triplet for sparse.
    ``cfg.rotate`` applies the §IV-B Hadamard rotation to corpus+queries
    (ℓ2 only; distances preserved).
    """
    if cfg.sparse:
        assert isinstance(corpus, SparseDataset)
        q_idx, q_val, q_nnz = queries

        def run_one(args):
            qi, qv, qn, key = args
            r = query_sparse(corpus, qi, qv, qn, cfg, key, eliminate=eliminate)
            return KNNResult(r.topk, r.topk_values, r.coord_ops, r.rounds, r.n_exact)

        Q = q_idx.shape[0]
        keys = jax.random.split(rng, Q)
        return jax.lax.map(run_one, (q_idx, q_val, q_nnz, keys))

    x = jnp.asarray(corpus, jnp.float32)
    qs = jnp.asarray(queries, jnp.float32)
    if cfg.rotate:
        assert cfg.metric == "l2", "rotation preserves only ℓ2"
        rng, sub = jax.random.split(rng)
        both, _ = hadamard_rotate(jnp.concatenate([x, qs], 0), sub, use_kernel=impl)
        x, qs = both[: x.shape[0]], both[x.shape[0]:]
    ds = DenseDataset.build(x, block=cfg.block)
    qs = ds.pad_query(qs)

    def run_one(args):
        q, key = args
        r = query_dense(ds, q, cfg, key, impl=impl, eliminate=eliminate)
        return KNNResult(r.topk, r.topk_values, r.coord_ops, r.rounds, r.n_exact)

    Q = qs.shape[0]
    keys = jax.random.split(rng, Q)
    return jax.lax.map(run_one, (qs, keys))


def knn_graph(x, cfg: BMOConfig, rng: jax.Array, *, impl: str = "auto",
              eliminate: bool = True) -> KNNResult:
    """Algorithm 2 proper: k-NN of every point among the others. Implemented
    as knn() with k+1 then dropping self-matches."""
    cfg1 = dataclasses.replace(cfg, k=cfg.k + 1)
    res = knn(x, x, cfg1, rng, impl=impl, eliminate=eliminate)
    Q = res.indices.shape[0]
    self_row = jnp.arange(Q)[:, None]
    is_self = res.indices == self_row
    # keep k non-self entries per row (self, when found, is dropped;
    # otherwise drop the worst)
    rank = jnp.argsort(jnp.where(is_self, jnp.inf, res.values), axis=1)[:, : cfg.k]
    take = jnp.take_along_axis
    return KNNResult(take(res.indices, rank, 1), take(res.values, rank, 1),
                     res.coord_ops, res.rounds, res.n_exact)
