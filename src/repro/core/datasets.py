"""Dataset containers for BMO-NN: dense (blocked layout) and sparse
(padded-CSR) corpora, plus the §IV-B randomized-Hadamard rotation."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def next_pow2(x: int) -> int:
    return 1 << (x - 1).bit_length()


@dataclasses.dataclass
class DenseDataset:
    """Corpus (n, d), padded so d is a multiple of the sampling block."""

    x: jax.Array               # (n, d_pad) float32
    d: int                     # true dimension (θ normalizer)
    block: int                 # sampling block width

    @property
    def n(self) -> int:
        return self.x.shape[0]

    @property
    def d_pad(self) -> int:
        return self.x.shape[1]

    @property
    def n_blocks(self) -> int:
        return self.d_pad // self.block

    @classmethod
    def build(cls, x, block: int = 128) -> "DenseDataset":
        x = jnp.asarray(x, jnp.float32)
        n, d = x.shape
        pad = (-d) % block
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad)))
        return cls(x=x, d=d, block=block)

    def pad_query(self, q) -> jax.Array:
        q = jnp.asarray(q, jnp.float32)
        pad = self.d_pad - q.shape[-1]
        if pad:
            q = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
        return q


@dataclasses.dataclass
class SparseDataset:
    """Padded-CSR corpus for the §IV-A sparse Monte-Carlo box (ℓ1).

    ``indices`` rows are sorted, padded with d (a sentinel larger than any
    real coordinate); ``values`` padded with 0. Membership tests and value
    lookups are binary searches — the TPU-friendly analogue of the paper's
    O(1) hash-map (same estimator distribution, see DESIGN.md)."""

    indices: jax.Array         # (n, m) int32, sorted, pad = d
    values: jax.Array          # (n, m) float32, pad = 0
    nnz: jax.Array             # (n,) int32
    d: int

    @property
    def n(self) -> int:
        return self.indices.shape[0]

    @property
    def m(self) -> int:
        return self.indices.shape[1]

    @classmethod
    def build(cls, dense_or_coo, d: Optional[int] = None) -> "SparseDataset":
        """From a dense (n, d) numpy array (zeros dropped)."""
        x = np.asarray(dense_or_coo)
        n, d_ = x.shape
        d = d or d_
        nnz = (x != 0).sum(axis=1)
        m = int(max(nnz.max(), 1))
        indices = np.full((n, m), d, np.int32)
        values = np.zeros((n, m), np.float32)
        for i in range(n):
            idx = np.nonzero(x[i])[0]
            indices[i, : len(idx)] = idx
            values[i, : len(idx)] = x[i, idx]
        return cls(indices=jnp.asarray(indices), values=jnp.asarray(values),
                   nnz=jnp.asarray(nnz, jnp.int32), d=d)


def hadamard_rotate(x: jax.Array, rng: jax.Array, *, use_kernel: str = "auto"):
    """§IV-B: x' = H D x per row (D = random ±1 diag, H = normalized FWHT).
    Pads d to the next power of two (paper: 'zero padding'). Preserves
    pairwise ℓ2 distances up to the common padding. Returns (x', signs)."""
    from repro.kernels import ops as kops
    n, d = x.shape
    dp = next_pow2(d)
    if dp != d:
        x = jnp.pad(x, ((0, 0), (0, dp - d)))
    signs = jax.random.rademacher(rng, (dp,), jnp.float32)
    return kops.fwht(x * signs[None, :], impl=use_kernel), signs
