"""Distributed BMO-NN on the production mesh — now a thin wrapper over the
``repro.index.sharded`` subsystem (DESIGN.md §5), which owns the shard-local
racing + certified all-gather top-k merge this module pioneered.

Sharding: arms (corpus rows) over the data axis — each data row of the mesh
races its own n/D arms via the cross-query batched driver
(``index.sharded.local_dense_race``); coordinates over the model axis —
every pull samples one block per model shard (stratified) and ``pmean``s the
partial block-means, so a single pull costs block×M coordinate reads spread
across the TP group. Queries are replicated across data shards and
coordinate-sharded.

Final merge: every shard's certified local top-k is exact-evaluated (see
sharded.py on why the merge needs exact values), ``all_gather``ed over the
data axis and reduced to the global top-k. Collectives per round: one
(Q, B, P) fp32 pmean over "model"; at the end one (D, Q, 2k) gather over
"data" — the collective pattern the roofline analysis studies.

This path stays a single jittable program (launch/dryrun.py lowers it for
roofline cells); the *persistent* sharded index in ``index/sharded.py`` is
the stateful sibling with the host-side epoch loop.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import BMOConfig
from repro.index.sharded import (flat_axis_index, guard_local_topk,
                                 local_dense_race, merge_local_topk)
from repro.index.batched_race import _dense_exact_theta


class DistKNNResult(NamedTuple):
    indices: jax.Array    # (Q, k) global corpus indices
    values: jax.Array     # (Q, k)
    coord_ops: jax.Array  # () total coordinate-wise computations
    rounds: jax.Array     # () max rounds across shards


def _local_knn(x_loc, qs_loc, rng, *, cfg: BMOConfig, d: int, n_loc: int,
               dp_axes, impl: str):
    """Body run per device under shard_map: the shard-local batched race of
    the index subsystem, with pulls additionally stratified over "model"."""
    Q = qs_loc.shape[0]
    shard = flat_axis_index(dp_axes)
    rng = jax.random.fold_in(rng, shard)
    alive = jnp.ones((n_loc,), bool)
    prior = jnp.zeros((n_loc,), jnp.float32)
    res = local_dense_race(x_loc, qs_loc, alive, prior, rng, cfg=cfg,
                           block=cfg.block, d=d, impl=impl, eliminate=True,
                           prior_weight=0.0, model_axis="model")
    # exact-evaluate the certified local top-k so the merge compares exact
    # θ values (partial over the model axis → psum), then gather + reduce
    part = _dense_exact_theta(x_loc, qs_loc, res.indices, cfg.metric, d)
    vals = guard_local_topk(res.indices, jax.lax.psum(part, "model"), alive)
    topk_g = res.indices.astype(jnp.int32) + shard * n_loc
    merged_idx, merged_vals = merge_local_topk(vals, topk_g, dp_axes, cfg.k)

    axes = ("model",) + ((dp_axes,) if isinstance(dp_axes, str)
                         else tuple(dp_axes))
    total_ops = jax.lax.psum(jnp.sum(res.coord_ops)
                             + float(cfg.k * x_loc.shape[1]) * Q, axes)
    max_rounds = jax.lax.pmax(jnp.max(res.rounds), axes)
    return merged_idx, merged_vals, total_ops, max_rounds


def distributed_knn(x, queries, cfg: BMOConfig, mesh: Mesh, rng, *,
                    impl: str = "auto", multi_pod: Optional[bool] = None):
    """x (n, d) sharded P(dp, "model"); queries (Q, d) sharded P(None,
    "model"). Returns DistKNNResult replicated."""
    if multi_pod is None:
        multi_pod = "pod" in mesh.axis_names
    dp_axes = ("pod", "data") if multi_pod else "data"
    n, d = x.shape
    dp_size = int(np.prod([mesh.shape[a] for a in
                           ((dp_axes,) if isinstance(dp_axes, str) else dp_axes)]))
    n_loc = n // dp_size
    # each shard races at δ/D so the per-interval budget matches the
    # single-machine union bound over all n arms (sharded.py)
    import dataclasses

    from repro.core.confidence import shard_delta
    cfg_loc = dataclasses.replace(cfg, delta=shard_delta(cfg.delta, dp_size))

    fn = functools.partial(_local_knn, cfg=cfg_loc, d=d, n_loc=n_loc,
                           dp_axes=dp_axes, impl=impl)
    sm = jax.shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp_axes, "model"), P(None, "model"), P()),
        out_specs=(P(), P(), P(), P()),
        check_vma=False,
    )
    idx, vals, ops, rounds = sm(x, queries, rng)
    return DistKNNResult(idx, vals, ops, rounds)
