"""Distributed BMO-NN on the production mesh (see DESIGN.md §2).

Sharding: arms (corpus rows) over the data axis — each data row of the mesh
races its own n/D arms; coordinates over the model axis — every pull samples
one block per model shard (stratified) and `pmean`s the partial block-means,
so a single pull costs block×M coordinate reads spread across the TP group.
Queries are replicated across data shards and coordinate-sharded.

Final merge: every shard's certified local top-k is `all_gather`ed over the
data axis and reduced to the global top-k (the global top-k is contained in
the union of per-shard top-ks). Collectives per round: one (B, P) fp32 pmean
over "model"; at the end one (D, Q, 2k) gather over "data" — this is the
collective pattern the roofline analysis studies.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import BMOConfig
from repro.core.ucb import race_topk
from repro.kernels import ops as kops


class DistKNNResult(NamedTuple):
    indices: jax.Array    # (Q, k) global corpus indices
    values: jax.Array     # (Q, k)
    coord_ops: jax.Array  # () total coordinate-wise computations
    rounds: jax.Array     # () max rounds across shards


def _axis_size(axes):
    return jax.lax.psum(1, axes)


def _flat_axis_index(axes):
    """Flattened index across one or more mesh axes (row-major)."""
    if isinstance(axes, str):
        return jax.lax.axis_index(axes)
    idx = jnp.zeros((), jnp.int32)
    for ax in axes:
        idx = idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    return idx


def _local_knn(x_loc, qs_loc, rng, *, cfg: BMOConfig, d: int, n_loc: int,
               dp_axes, impl: str):
    """Body run per device under shard_map."""
    d_loc = x_loc.shape[1]
    block = cfg.block
    assert d_loc % block == 0, (d_loc, block)
    nb_loc = d_loc // block
    M = jax.lax.psum(1, "model")

    def make_pull(q_loc):
        def pull(arm_idx, key):
            key = jax.random.fold_in(key, jax.lax.axis_index("model"))
            blk = jax.random.randint(key, (arm_idx.shape[0], cfg.pulls_per_round),
                                     0, nb_loc)
            vals = kops.block_pull(x_loc, q_loc, arm_idx, blk, block=block,
                                   metric=cfg.metric, impl=impl)
            return jax.lax.pmean(vals, "model")
        return pull

    def make_exact(q_loc):
        def exact(arm_idx):
            rows = x_loc[arm_idx]
            part = kops.pairwise_dist(q_loc[None], rows, metric=cfg.metric,
                                      impl=impl)[0]
            return jax.lax.psum(part, "model") / d
        return exact

    def run_query(args):
        q_loc, key = args
        res = race_topk(
            make_pull(q_loc), make_exact(q_loc),
            n=n_loc,
            max_pulls=nb_loc,
            pull_cost=float(block),        # per model shard; psum'd below
            exact_cost=float(d_loc),
            cfg=cfg, rng=key, eliminate=True,
        )
        return res.topk, res.topk_values, res.coord_ops, res.rounds

    Q = qs_loc.shape[0]
    keys = jax.random.split(rng, Q)
    topk_i, topk_v, ops, rounds = jax.lax.map(run_query, (qs_loc, keys))

    # local arm ids -> global corpus ids
    shard = _flat_axis_index(dp_axes)
    topk_g = topk_i.astype(jnp.int32) + shard * n_loc

    # merge across the data axis
    vals_all = jax.lax.all_gather(topk_v, dp_axes, tiled=True)   # (D*Q? no: (D, Q, k)) tiled -> (D*Q, k)
    idx_all = jax.lax.all_gather(topk_g, dp_axes, tiled=True)
    D = vals_all.shape[0] // Q
    vals_all = vals_all.reshape(D, Q, cfg.k).transpose(1, 0, 2).reshape(Q, D * cfg.k)
    idx_all = idx_all.reshape(D, Q, cfg.k).transpose(1, 0, 2).reshape(Q, D * cfg.k)
    neg, pos = jax.lax.top_k(-vals_all, cfg.k)
    merged_idx = jnp.take_along_axis(idx_all, pos, axis=1)
    total_ops = jax.lax.psum(jnp.sum(ops), ("model",) + (
        (dp_axes,) if isinstance(dp_axes, str) else tuple(dp_axes)))
    max_rounds = jax.lax.pmax(jnp.max(rounds), (
        (dp_axes,) if isinstance(dp_axes, str) else tuple(dp_axes)))
    return merged_idx, -neg, total_ops, max_rounds


def distributed_knn(x, queries, cfg: BMOConfig, mesh: Mesh, rng, *,
                    impl: str = "auto", multi_pod: Optional[bool] = None):
    """x (n, d) sharded P(dp, "model"); queries (Q, d) sharded P(None,
    "model"). Returns DistKNNResult replicated."""
    if multi_pod is None:
        multi_pod = "pod" in mesh.axis_names
    dp_axes = ("pod", "data") if multi_pod else "data"
    n, d = x.shape
    dp_size = int(np.prod([mesh.shape[a] for a in
                           ((dp_axes,) if isinstance(dp_axes, str) else dp_axes)]))
    n_loc = n // dp_size

    fn = functools.partial(_local_knn, cfg=cfg, d=d, n_loc=n_loc,
                           dp_axes=dp_axes, impl=impl)
    sm = jax.shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp_axes, "model"), P(None, "model"), P()),
        out_specs=(P(), P(), P(), P()),
        check_vma=False,
    )
    idx, vals, ops, rounds = sm(x, queries, rng)
    return DistKNNResult(idx, vals, ops, rounds)
