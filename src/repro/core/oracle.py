"""Exact k-NN oracles (the paper's 'exact computation' baseline)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.datasets import SparseDataset
from repro.kernels import ops as kops


class OracleResult(NamedTuple):
    indices: jax.Array    # (Q, k)
    values: jax.Array     # (Q, k) θ = ρ/d
    coord_ops: jax.Array  # () total coordinate-wise distance computations


def exact_knn(corpus, queries, k: int, metric: str = "l2", *,
              impl: str = "auto", batch: int = 256) -> OracleResult:
    """Brute force: full (Q, n) distance matrix + top-k. Costs Q·n·d."""
    x = jnp.asarray(corpus, jnp.float32)
    qs = jnp.asarray(queries, jnp.float32)
    Q, d = qs.shape
    n = x.shape[0]
    idx_out, val_out = [], []
    for s in range(0, Q, batch):
        dist = kops.pairwise_dist(qs[s:s + batch], x, metric=metric, impl=impl)
        neg, idx = jax.lax.top_k(-dist, k)
        idx_out.append(idx)
        val_out.append(-neg / d)
    return OracleResult(jnp.concatenate(idx_out), jnp.concatenate(val_out),
                        jnp.asarray(float(Q) * n * d))


def exact_knn_sparse(ds: SparseDataset, q_idx, q_val, q_nnz, k: int) -> OracleResult:
    """Sparsity-aware exact ℓ1 baseline: cost Σ_i (n_q + n_i) per query."""
    from repro.core.bmo_nn import sparse_exact_theta

    def one(qi, qv):
        theta = sparse_exact_theta(ds, qi, qv, jnp.arange(ds.n))
        neg, idx = jax.lax.top_k(-theta, k)
        return idx, -neg

    idx, val = jax.lax.map(lambda a: one(a[0], a[1]), (q_idx, q_val))
    # cost: for each (query, arm) pair, n_q + n_i lookups
    ops_total = (q_idx.shape[0] * jnp.sum(ds.nnz.astype(jnp.float32))
                 + jnp.sum(q_nnz.astype(jnp.float32)) * ds.n)
    return OracleResult(idx, val, ops_total)
