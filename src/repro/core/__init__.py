"""The paper's primary contribution: bandit-based Monte-Carlo optimization
(BMO-UCB racing engine, BMO-NN k-nearest neighbours with dense / rotated /
sparse Monte-Carlo boxes, PAC variant, BMO k-means, and the mesh-distributed
engine)."""

from repro.core.ucb import RaceResult, race_topk
from repro.core.bmo_nn import KNNResult, knn, knn_graph
from repro.core.oracle import exact_knn, exact_knn_sparse
from repro.core.datasets import DenseDataset, SparseDataset, hadamard_rotate

__all__ = [
    "RaceResult", "race_topk", "KNNResult", "knn", "knn_graph",
    "exact_knn", "exact_knn_sparse", "DenseDataset", "SparseDataset",
    "hadamard_rotate",
]
