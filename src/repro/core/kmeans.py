"""BMO k-means (paper §V-A): Lloyd's algorithm where the assignment step
(nearest centroid of each point = n independent 1-NN problems with k arms)
runs through BMO-UCB. The update step is the standard O(nd) mean."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle


class KMeansResult(NamedTuple):
    centroids: jax.Array      # (k, d)
    assignment: jax.Array     # (n,)
    coord_ops: jax.Array      # () assignment-step coordinate computations
    exact_ops: jax.Array      # () what exact assignment would have cost


def assign_bmo(points, centroids, cfg: BMOConfig, rng, *, impl="auto"):
    """(n,) nearest-centroid ids via BMO-UCB + per-point coordinate ops."""
    acfg = dataclasses.replace(cfg, k=1)
    res = bmo_nn.knn(centroids, points, acfg, rng, impl=impl)
    return res.indices[:, 0], jnp.sum(res.coord_ops)


def assign_exact(points, centroids, *, impl="auto"):
    res = oracle.exact_knn(centroids, points, 1, "l2", impl=impl)
    return res.indices[:, 0], res.coord_ops


def lloyd_update(points, assignment, k: int):
    n, d = points.shape
    one_hot = jax.nn.one_hot(assignment, k, dtype=points.dtype)      # (n, k)
    sums = one_hot.T @ points                                        # (k, d)
    counts = jnp.sum(one_hot, axis=0)[:, None]
    return jnp.where(counts > 0, sums / jnp.maximum(counts, 1), 0.0)


def kmeans(points, k: int, iters: int, cfg: BMOConfig, rng, *,
           use_bmo: bool = True, impl: str = "auto") -> KMeansResult:
    points = jnp.asarray(points, jnp.float32)
    n, d = points.shape
    rng, sub = jax.random.split(jax.random.PRNGKey(0) if rng is None else rng)
    init_idx = jax.random.choice(sub, n, (k,), replace=False)
    centroids = points[init_idx]
    coord_ops = jnp.zeros(())
    assignment = jnp.zeros((n,), jnp.int32)
    for _ in range(iters):
        rng, sub = jax.random.split(rng)
        if use_bmo:
            assignment, ops = assign_bmo(points, centroids, cfg, sub, impl=impl)
        else:
            assignment, ops = assign_exact(points, centroids, impl=impl)
        coord_ops = coord_ops + ops
        centroids = lloyd_update(points, assignment, k)
    exact_ops = jnp.asarray(float(iters) * n * k * d)
    return KMeansResult(centroids, assignment, coord_ops, exact_ops)
