"""Confidence intervals and running-moment updates for BMO-UCB (paper §II-C).

The paper's CI (Eq. 3):  C_{i,T} = sqrt(2 σ_i² log(2/δ') / T), collapsing to 0
once the arm is exactly evaluated, with δ' = δ / (n · MAX_PULLS)  (Lemma 1).
σ_i is a bound on the sub-Gaussian norm of the arm's Monte-Carlo samples; in
practice (paper App. D-A) we track each arm's empirical variance with a
Welford accumulator and use it as σ_i², floored to avoid degenerate early
estimates.
"""
from __future__ import annotations

import jax.numpy as jnp


def delta_prime(delta: float, n: int, max_pulls: int) -> float:
    """Per-interval failure budget from Lemma 1's union bound."""
    return delta / (n * max(max_pulls, 1))


def shard_delta(delta: float, shards: int) -> float:
    """Per-shard failure budget: δ/S, so the S shard-local top-k
    contracts union-bound back to the global δ (DESIGN.md §6.2). Every
    shard-fanout split MUST go through this helper — the delta-ledger
    lint rule enumerates its call sites as the machine-checked split
    table (DESIGN.md §12.2)."""
    return delta / max(shards, 1)


def hoeffding_radius(sigma_sq, count, log_term):
    """C = sqrt(2 σ² log(2/δ') / T); ``log_term`` = log(2/δ') precomputed."""
    c = jnp.maximum(count, 1.0)
    return jnp.sqrt(2.0 * sigma_sq * log_term / c)


def welford_merge(mean, count, m2, b_mean, b_count, b_m2, mask):
    """Merge pre-reduced batch statistics into running (mean, count, m2)
    (Chan's parallel Welford update).

    mean/count/m2:        current per-arm stats.
    b_mean/b_count/b_m2:  batch stats — e.g. the (mean, M2) pair a fused
                          epoch kernel reduced on-chip over its R·P pulls.
    mask:                 1.0 for real updates, 0.0 for padded/masked arms.
    Returns new (mean, count, m2) — unchanged where mask = 0.
    """
    tot = count + b_count
    delta = b_mean - mean
    new_mean = mean + delta * (b_count / jnp.maximum(tot, 1.0))
    new_m2 = m2 + b_m2 + jnp.square(delta) * count * b_count / jnp.maximum(
        tot, 1.0)
    keep = mask > 0
    return (jnp.where(keep, new_mean, mean),
            jnp.where(keep, tot, count),
            jnp.where(keep, new_m2, m2))


def welford_batch_update(mean, count, m2, batch_vals, batch_mask):
    """Merge a batch of P raw samples per arm into running (mean, count, m2).

    mean/count/m2: (B,) current stats for the B arms being updated.
    batch_vals:    (B, P) new samples.
    batch_mask:    (B,) 1.0 for real updates, 0.0 for padded/masked arms.
    Returns new (mean, count, m2) — unchanged where mask = 0.
    """
    P = batch_vals.shape[1]
    b_mean = jnp.mean(batch_vals, axis=1)
    b_m2 = jnp.sum(jnp.square(batch_vals - b_mean[:, None]), axis=1)
    return welford_merge(mean, count, m2, b_mean, float(P), b_m2, batch_mask)


def empirical_sigma_sq(m2, count, floor_sq, global_var, shrink_weight: float = 4.0):
    """σ̂² per arm: empirical variance *shrunk toward the pooled global
    variance* with ``shrink_weight`` pseudo-observations.

    Paper App. D-A estimates 'a global σ for all arms from a few initial
    samples and update[s] it after every pull', then uses per-arm empirical
    variance. Pure per-arm variance from a handful of block-samples is
    chi-square-noisy (occasionally near 0 → CI collapse → wrong accepts);
    the shrinkage keeps early CIs honest and converges to the per-arm
    estimate as counts grow.
    """
    var = (m2 + shrink_weight * global_var) / jnp.maximum(
        count - 1.0 + shrink_weight, 1.0)
    return jnp.maximum(var, floor_sq)


def empirical_sigma_sq_prior(m2, count, floor_sq, global_var, prior_var,
                             prior_weight: float, shrink_weight: float = 4.0):
    """σ̂² with an additional *per-arm* warm-start prior (index serving):
    the build-time block statistics enter as ``prior_weight`` pseudo-
    observations of variance ``prior_var`` alongside the usual pooled-global
    shrinkage. With ``prior_weight = 0`` this is exactly
    ``empirical_sigma_sq``. The prior only shapes the variance estimate —
    CI widths still scale with the *real* sample count, so warm starts tighten
    early rounds without ever faking evidence.
    """
    var = (m2 + prior_weight * prior_var + shrink_weight * global_var) / \
        jnp.maximum(count - 1.0 + prior_weight + shrink_weight, 1.0)
    return jnp.maximum(var, floor_sq)


def pooled_variance(m2, count):
    """Global pooled variance Σ m2_i / Σ (count_i − 1)."""
    num = jnp.sum(m2)
    den = jnp.sum(jnp.maximum(count - 1.0, 0.0))
    return num / jnp.maximum(den, 1.0)


def hoeffding_radius_masked(sigma_sq, count, log_term, valid):
    """Compacted-state CI radius: padding entries (``valid`` = False) get a
    zero radius so LCB = UCB = mean — combined with their pre-rejected
    status in the masked acceptance step they can never influence a race."""
    return jnp.where(valid, hoeffding_radius(sigma_sq, count, log_term), 0.0)
