"""BMO-UCB (paper Algorithm 1), batched TPU-native racing formulation.

The routine is generic over the Monte-Carlo box, exactly like the paper's
formulation: it takes a ``pull_fn`` (sample the arm estimator) and an
``exact_fn`` (evaluate the arm mean exactly at cost MAX_PULLS pulls), plus
the CI machinery of core.confidence.

Faithfulness notes (see DESIGN.md §2):
  * Per round we pull the ``batch_arms`` lowest-LCB candidates,
    ``pulls_per_round`` samples each — the paper's own batched
    implementation (App. D-A) with (32, 256) — instead of 1 arm × 1 pull.
  * An arm whose pull count reaches MAX_PULLS is evaluated exactly and its
    CI collapses to 0 (Alg. 1 line 13).
  * Acceptance: arm i is accepted when UCB_i < min_{j≠i, j not accepted}
    LCB_j (Alg. 1 line 7), applied vectorized so several arms can be
    certified in one round.
  * PAC variant (Thm 2): with ``epsilon > 0`` the *selected* (lowest-LCB)
    arm is also accepted once its CI half-width < ε/2.
  * ``eliminate=True`` additionally discards arms with LCB above the k-th
    smallest UCB (safe under the same CI event; racing-style). This is a
    beyond-paper optimization — benchmarks run both settings.

Returned stats count the paper's metric: number of coordinate-wise distance
computations (pull cost × samples + MAX_PULLS-equivalents for exact evals).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BMOConfig
from repro.core import confidence as conf

INF = jnp.inf


class RaceState(NamedTuple):
    mean: jax.Array        # (n,) running estimate of θ_i
    count: jax.Array       # (n,) pulls so far (in estimator samples)
    m2: jax.Array          # (n,) Welford sum of squared deviations
    exact: jax.Array       # (n,) bool: mean is exact, CI = 0
    accepted: jax.Array    # (n,) bool
    rejected: jax.Array    # (n,) bool (only when eliminate=True)
    accept_order: jax.Array  # (n,) int32 round at which accepted (else big)
    coord_ops: jax.Array   # () float64-ish: coordinate-wise distance comps
    rounds: jax.Array      # () int32
    rng: jax.Array


class RaceResult(NamedTuple):
    topk: jax.Array        # (k,) arm indices, sorted by estimated θ
    topk_values: jax.Array # (k,) θ estimates for those arms
    coord_ops: jax.Array
    rounds: jax.Array
    n_exact: jax.Array
    state: RaceState


def acceptance_step(mean, ci, exact, accepted, rejected, k: int, *,
                    epsilon: float = 0.0, eliminate: bool = True):
    """One vectorized Alg. 1 acceptance/rejection pass over 1-D arm state.

    Shared by the per-query racer below and index.batched_race (which vmaps
    it across the query axis). Returns ``(accept_new, rejected_new)`` —
    the mask of arms newly certified this round (capped at the k still
    needed, lowest means first) and the updated rejection mask.
    """
    n = mean.shape[0]
    candidate = ~accepted & ~rejected
    lcb = jnp.where(candidate, mean - ci, INF)
    ucb = mean + ci

    # min LCB excluding self among candidates — via min/argmin reductions:
    # XLA CPU's fast TopK rewrite breaks when a top_k output is sliced to a
    # scalar (falls back to a full sort), and this runs every round.
    min1 = jnp.min(lcb)
    argmin1 = jnp.argmin(lcb)
    min2 = jnp.min(jnp.where(jnp.arange(n) == argmin1, INF, lcb))
    min_excl = jnp.where(jnp.arange(n) == argmin1, min2, min1)

    accept_cert = candidate & (ucb < min_excl)
    # exact-tie progress rule: the lowest-LCB arm, if exact, is accepted
    # when it cannot be beaten (<=); deterministic index tie-break.
    accept_tie = candidate & exact & (jnp.arange(n) == argmin1) & (ucb <= min_excl)
    accept_new = accept_cert | accept_tie
    if epsilon > 0:  # PAC rule (Thm 2): selected arm with CI < ε/2
        accept_pac = candidate & (jnp.arange(n) == argmin1) & (ci < epsilon / 2)
        accept_new = accept_new | accept_pac

    # never accept more than the k we still need, lowest means first.
    # top_k(k) instead of a full argsort: only the k best candidates can
    # ever be kept, and partial selection is ~100x cheaper than the full
    # sort on CPU (the dominant per-round cost at serving scale).
    still_needed = k - jnp.sum(accepted)
    _, best = jax.lax.top_k(-jnp.where(accept_new, mean, INF), k)
    keep = jnp.zeros((n,), bool).at[best].set(
        jnp.arange(k) < still_needed)
    accept_new = accept_new & keep

    rejected_new = rejected
    if eliminate:
        # arm can't be top-k if its LCB > k-th smallest UCB (over non-rejected).
        # max-reduce over the k smallest instead of slicing out [k-1]: the
        # slice form defeats XLA's TopK rewrite (full-sort fallback).
        ucb_alive = jnp.where(~rejected, ucb, INF)
        kth_ucb = jnp.max(-jax.lax.top_k(-ucb_alive, k)[0])
        rejected_new = rejected | (candidate & ~accept_new & ((mean - ci) > kth_ucb))
    return accept_new, rejected_new


def acceptance_step_masked(mean, ci, exact, accepted, rejected, valid, k: int,
                           *, epsilon: float = 0.0, eliminate: bool = True):
    """Compacted-state (index/frontier.py) variant of ``acceptance_step``:
    state arrays hold a *bucketed survivor frontier* — width W ≪ n — whose
    padding entries carry ``valid`` = False. Padding is treated as
    pre-rejected, so it can never be accepted, never sets the min-LCB bar,
    and never occupies one of the k UCB slots of the eliminate rule.
    Returns ``(accept_new, rejected_new)`` over the W-wide buffers;
    ``rejected_new`` includes the padding."""
    return acceptance_step(mean, ci, exact, accepted, rejected | ~valid, k,
                           epsilon=epsilon, eliminate=eliminate)


def topk_from_state(mean, ci, accepted, rejected, k: int):
    """Final ranking: accepted arms first (by mean), then best remaining by
    LCB; rejected arms last. Returns (topk indices, topk means), sorted."""
    score = jnp.where(accepted, mean - 1e9, jnp.where(rejected, INF, mean - ci))
    _, topk = jax.lax.top_k(-score, k)
    order = jnp.argsort(mean[topk])
    topk = topk[order]
    return topk, mean[topk]


def topk_from_state_masked(mean, ci, accepted, rejected, valid, ids, k: int):
    """Compacted-state variant of ``topk_from_state``: ranks the W-wide
    frontier buffers (padding pre-rejected via ``valid``) and translates the
    winning *positions* back to original arm/slot ids through ``ids``."""
    pos, vals = topk_from_state(mean, ci, accepted, rejected | ~valid, k)
    return ids[pos], vals


def race_topk(
    pull_fn: Callable,          # (arm_idx (B,), rng) -> (B, P) sample values
    exact_fn: Callable,         # (arm_idx (B,)) -> (B,) exact θ
    n: int,
    max_pulls,                  # pulls that constitute an exact evaluation; scalar or (n,)
    pull_cost: float,           # coordinate-ops per sample (block width)
    exact_cost,                 # coordinate-ops per exact evaluation (d); scalar or (n,)
    cfg: BMOConfig,
    rng: jax.Array,
    eliminate: bool = True,
    max_pulls_static: int = 0,  # static upper bound when max_pulls is traced
) -> RaceResult:
    k = cfg.k
    B = min(cfg.batch_arms, n)
    P = cfg.pulls_per_round
    max_pulls_arr = jnp.broadcast_to(jnp.asarray(max_pulls, jnp.float32), (n,))
    exact_cost_arr = jnp.broadcast_to(jnp.asarray(exact_cost, jnp.float32), (n,))
    max_pulls_hi = max_pulls_static or int(np.max(np.asarray(max_pulls)))
    log_term = float(np.log(2.0 / conf.delta_prime(cfg.delta, n, max_pulls_hi)))
    sigma_override = cfg.sigma

    # hard cap: everything pulled to exact plus slack
    max_rounds = cfg.max_rounds or int(
        2 * math.ceil(n * max_pulls_hi / max(B * P, 1)) + n + 16)

    def init_state(rng):
        # initial pulls on every arm (paper App. D-A inits with 32 pulls/arm).
        # One *wide* pull over all n arms per rep — a single vectorized
        # gather/reduce instead of n/B sequential rounds (§Perf iteration 1:
        # the chunked init dominated both wall-clock and collective count).
        n_init = max(cfg.init_pulls, 2)
        mean = jnp.zeros((n,), jnp.float32)
        count = jnp.zeros((n,), jnp.float32)
        m2 = jnp.zeros((n,), jnp.float32)
        all_arms = jnp.arange(n)
        reps = max(1, n_init // P)

        def rep_body(carry, _):
            mean, count, m2, rng = carry
            rng, sub = jax.random.split(rng)
            vals = pull_fn(all_arms, sub)                 # (n, P)
            mean, count, m2 = conf.welford_batch_update(
                mean, count, m2, vals, jnp.ones((n,), jnp.float32))
            return (mean, count, m2, rng), None

        (mean, count, m2, rng), _ = jax.lax.scan(
            rep_body, (mean, count, m2, rng), None, length=reps)
        coord_ops = jnp.asarray(n * reps * P * pull_cost, jnp.float32)
        return RaceState(
            mean=mean, count=count, m2=m2,
            exact=jnp.zeros((n,), bool),
            accepted=jnp.zeros((n,), bool),
            rejected=jnp.zeros((n,), bool),
            accept_order=jnp.full((n,), np.iinfo(np.int32).max, jnp.int32),
            coord_ops=coord_ops,
            rounds=jnp.zeros((), jnp.int32),
            rng=rng,
        )

    def ci_radius(st: RaceState):
        if sigma_override is not None:
            sig_sq = jnp.full((n,), float(sigma_override) ** 2, jnp.float32)
        else:
            global_var = conf.pooled_variance(st.m2, st.count)
            sig_sq = conf.empirical_sigma_sq(st.m2, st.count, 1e-12, global_var)
        c = conf.hoeffding_radius(sig_sq, st.count, log_term)
        return jnp.where(st.exact, 0.0, c)

    def cond(st: RaceState):
        return (jnp.sum(st.accepted) < k) & (st.rounds < max_rounds)

    def body(st: RaceState):
        ci = ci_radius(st)
        lcb = st.mean - ci
        ucb = st.mean + ci
        candidate = ~st.accepted & ~st.rejected

        # ---- selection: B lowest-LCB candidates that still need pulls -----
        need_pulls = candidate & ~st.exact
        sel_score = jnp.where(need_pulls, lcb, INF)
        _, sel = jax.lax.top_k(-sel_score, B)             # (B,)
        sel_valid = jnp.take(need_pulls, sel)

        rng, sub = jax.random.split(st.rng)
        vals = pull_fn(sel, sub)                          # (B, P)
        cm, cc, c2 = st.mean[sel], st.count[sel], st.m2[sel]
        nm, nc, n2 = conf.welford_batch_update(cm, cc, c2, vals,
                                               sel_valid.astype(jnp.float32))
        mean = st.mean.at[sel].set(nm)
        count = st.count.at[sel].set(nc)
        m2 = st.m2.at[sel].set(n2)
        coord_ops = st.coord_ops + jnp.sum(sel_valid) * P * pull_cost

        # ---- exact evaluation for arms that crossed MAX_PULLS -------------
        # lazily: most rounds cross nothing, so the full-row reads sit under
        # a cond and cost neither bandwidth nor flops then (§Perf iteration)
        crossed = (count[sel] >= max_pulls_arr[sel]) & sel_valid & ~st.exact[sel]
        exact_vals = jax.lax.cond(
            jnp.any(crossed),
            lambda s: exact_fn(s),
            lambda s: jnp.zeros((B,), jnp.float32),
            sel)
        mean = mean.at[sel].set(jnp.where(crossed, exact_vals, mean[sel]))
        exact = st.exact.at[sel].set(st.exact[sel] | crossed)
        coord_ops = coord_ops + jnp.sum(crossed * exact_cost_arr[sel])

        st2 = st._replace(mean=mean, count=count, m2=m2, exact=exact,
                          coord_ops=coord_ops, rng=rng)

        # ---- acceptance / rejection ---------------------------------------
        ci = ci_radius(st2)
        accept_new, rejected = acceptance_step(
            st2.mean, ci, st2.exact, st2.accepted, st2.rejected, k,
            epsilon=cfg.epsilon, eliminate=eliminate)
        accepted = st2.accepted | accept_new
        accept_order = jnp.where(
            accept_new, st2.rounds, st2.accept_order)

        return st2._replace(accepted=accepted, rejected=rejected,
                            accept_order=accept_order,
                            rounds=st2.rounds + 1)

    st = init_state(rng)
    st = jax.lax.while_loop(cond, body, st)

    # output: accepted arms first (by mean), then best remaining by LCB
    ci = ci_radius(st)
    topk, _ = topk_from_state(st.mean, ci, st.accepted, st.rejected, k)
    return RaceResult(
        topk=topk,
        topk_values=st.mean[topk],
        coord_ops=st.coord_ops,
        rounds=st.rounds,
        n_exact=jnp.sum(st.exact),
        state=st,
    )
