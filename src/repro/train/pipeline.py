"""GPipe-style pipeline parallelism over a mesh "stage" axis.

Layers are split into n_stages contiguous groups; microbatches stream
through the pipeline with `ppermute` handoffs inside `shard_map`. The
schedule runs T = n_micro + n_stages − 1 ticks; each tick every stage
applies its layer group to its current activation and passes the result to
its successor. Autodiff flows through the ppermutes, so the same function
trains (bubble fraction = (S−1)/T, the GPipe tradeoff).

Intended mesh at >512-chip scale: (pod, stage, data, model) — see DESIGN.md.
Tested on host meshes in tests/test_pipeline.py.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn: Callable, stage_params, x_micro, mesh: Mesh,
                   axis: str = "stage"):
    """stage_fn(params_for_stage, x) -> x;
    stage_params: pytree with leading dim = n_stages (sharded over `axis`);
    x_micro: (n_micro, mb, ...) microbatched input (replicated).
    Returns (n_micro, mb, ...) outputs after all stages."""
    n_stages = mesh.shape[axis]

    def body(params_local, x_all):
        # params_local: (1, ...) — this stage's slice; x_all replicated
        params_me = jax.tree_util.tree_map(lambda t: t[0], params_local)
        stage = jax.lax.axis_index(axis)
        n_micro = x_all.shape[0]
        T = n_micro + n_stages - 1
        mb_shape = x_all.shape[1:]

        carry_in = jnp.zeros(mb_shape, x_all.dtype)   # current input register
        outputs = jnp.zeros_like(x_all)

        def tick(t, state):
            carry_in, outputs = state
            # stage 0 feeds microbatch t (if still in range)
            feed = jnp.where(t < n_micro, t, 0)
            x0 = x_all[feed]
            x_in = jnp.where(stage == 0, x0, carry_in)
            y = stage_fn(params_me, x_in)
            # pass y to the next stage (ring; last stage's send is ignored)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            nxt = jax.lax.ppermute(y, axis, perm)
            # last stage emits microbatch (t - (n_stages - 1))
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = (stage == n_stages - 1) & (t >= n_stages - 1)
            outputs = jax.lax.dynamic_update_slice(
                outputs,
                jnp.where(emit, y, outputs[out_idx])[None],
                (out_idx,) + (0,) * len(mb_shape))
            return nxt, outputs

        _, outputs = jax.lax.fori_loop(0, T, tick, (carry_in, outputs))
        # only the last stage's buffer is meaningful — zero the rest and
        # psum so the output is replicated across the stage axis
        outputs = jnp.where(stage == n_stages - 1, outputs, 0.0)
        return jax.lax.psum(outputs, axis)

    in_specs = (jax.tree_util.tree_map(lambda _: P(axis), stage_params), P())
    return jax.shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=P(),
                         check_vma=False)(stage_params, x_micro)


def split_stages(stacked_params, n_stages: int):
    """(L, ...) stacked layer params -> (n_stages, L/n_stages, ...)."""
    def r(t):
        L = t.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return t.reshape((n_stages, L // n_stages) + t.shape[1:])

    return jax.tree_util.tree_map(r, stacked_params)
