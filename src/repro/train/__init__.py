from repro.train.steps import (
    abstract_train_state, init_train_state, make_train_step, state_pspecs,
)
from repro.train.loss import lm_loss

__all__ = ["abstract_train_state", "init_train_state", "make_train_step",
           "state_pspecs", "lm_loss"]
