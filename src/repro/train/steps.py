"""Train-step construction: grad accumulation, clipping, optimizer, optional
int8 gradient compression, and the sharding wiring for the production mesh."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelPlan, TrainConfig
from repro.optim import make_optimizer, warmup_cosine
from repro.optim.compress import clip_by_global_norm
from repro.sharding.context import activation_sharding
from repro.sharding.spec import Rules, init_params, make_rules, param_pspecs
from repro.train.loss import lm_loss


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------


def init_train_state(model, plan: ParallelPlan, tcfg: TrainConfig, rng):
    """Host-side init (small models / tests). For the dry-run use
    abstract_train_state."""
    specs = model.param_specs(dtype=_dtype(plan.param_dtype))
    params = init_params(specs, rng)
    opt = make_optimizer(plan.optimizer, tcfg).init(params)
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(model, plan: ParallelPlan, tcfg: TrainConfig):
    specs = model.param_specs(dtype=_dtype(plan.param_dtype))
    params = jax.tree_util.tree_map(
        lambda s: s.sds, specs, is_leaf=lambda x: hasattr(x, "sds"))
    opt = jax.eval_shape(lambda p: make_optimizer(plan.optimizer, tcfg).init(p), params)
    return {"params": params, "opt": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------


def state_pspecs(model, plan: ParallelPlan, rules: Rules):
    """PartitionSpecs for the full train state (params + optimizer mirrors)."""
    specs = model.param_specs(dtype=_dtype(plan.param_dtype))
    p_specs = param_pspecs(specs, rules)

    if plan.optimizer in ("adamw",):
        opt = {"m": p_specs, "v": p_specs}
    elif plan.optimizer == "sgd":
        opt = {}
    else:  # adafactor: r drops last dim, c drops second-to-last
        def r_spec(spec_leaf, pspec):
            dims = list(pspec) + [None] * (len(spec_leaf.shape) - len(pspec))
            if len(spec_leaf.shape) >= 2:
                return P(*dims[:-1])
            return P(*dims)

        def c_spec(spec_leaf, pspec):
            dims = list(pspec) + [None] * (len(spec_leaf.shape) - len(pspec))
            if len(spec_leaf.shape) >= 2:
                return P(*(dims[:-2] + dims[-1:]))
            return P(*dims)

        opt = jax.tree_util.tree_map(
            lambda s, ps: ({"r": r_spec(s, ps), "c": c_spec(s, ps)}
                           if len(s.shape) >= 2 else {"v": ps}),
            specs, p_specs, is_leaf=lambda x: hasattr(x, "sds"))
    return {"params": p_specs, "opt": opt, "step": P()}


def batch_pspecs(input_specs: Dict[str, Any], rules: Rules):
    """Batch-axis sharding for every model input (positions3 has batch at
    dim 1; everything else at dim 0). Divisibility-checked per shape."""
    out = {}
    for k, v in input_specs.items():
        axes = (None, "batch") if k == "positions3" else ("batch",)
        axes = axes + (None,) * (len(v.shape) - len(axes))
        out[k] = rules.pspec(axes, v.shape)
    return out


def to_named(tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda ps: NamedSharding(mesh, ps), tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# the step
# ---------------------------------------------------------------------------


def make_train_step(model, plan: ParallelPlan, tcfg: TrainConfig, mesh: Mesh,
                    *, rules: Optional[Rules] = None, multi_pod: bool = False,
                    grad_accum: Optional[int] = None):
    """Returns (train_step, state_shardings_fn). train_step(state, batch) is
    pjit-ready; wrap with jax.jit(in_shardings=..., donate_argnums=0)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    rules = rules or make_rules(fsdp=plan.fsdp, tp=plan.tp, sp=plan.sp,
                                ep=plan.ep, multi_pod=multi_pod,
                                axis_sizes=axis_sizes,
                                kv_len_shard=plan.kv_len_shard)
    optimizer = make_optimizer(plan.optimizer, tcfg)
    schedule = warmup_cosine(tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)
    ga = grad_accum if grad_accum is not None else plan.grad_accum
    compute_dtype = _dtype(plan.compute_dtype)
    dp_spec = rules.mesh_axes("batch")

    def loss_fn(params, mb):
        return lm_loss(model, params, mb, remat=plan.remat,
                       compute_dtype=compute_dtype, mesh=mesh, ep=plan.ep,
                       dp_spec=dp_spec)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if ga <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, metrics

        def split(x):
            return x.reshape((ga, x.shape[0] // ga) + x.shape[1:])

        def split3(x):  # positions3: (3, B, S)
            return x.reshape((x.shape[0], ga, x.shape[1] // ga) + x.shape[2:]).swapaxes(0, 1)

        mbs = {k: (split3(v) if k == "positions3" else split(v))
               for k, v in batch.items()}

        # fp32 accumulation for fp32-param plans; bf16-param (adafactor)
        # plans accumulate in bf16 — halves the largest training buffer at
        # 100B+ scale, and adafactor's rms-normalized update absorbs the
        # accumulation noise (see DESIGN.md §4)
        acc_dtype = jnp.float32 if plan.param_dtype == "float32" else jnp.bfloat16

        def body(acc, mb):
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(acc_dtype) / ga, acc, grads)
            return acc, metrics

        zero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dtype), params)
        grads, metrics_stack = jax.lax.scan(body, zero, mbs)
        metrics = jax.tree_util.tree_map(jnp.mean, metrics_stack)
        return grads, metrics

    def train_step(state, batch):
        with activation_sharding(rules, mesh):
            grads, metrics = compute_grads(state["params"], batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = schedule(state["step"])
        params, opt = optimizer.update(grads, state["opt"], state["params"],
                                       state["step"], lr)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return {"params": params, "opt": opt, "step": state["step"] + 1}, metrics

    return train_step, rules
