"""Losses. Cross entropy is computed in fp32 with a stable logsumexp; works
with a vocab-sharded logits tensor under pjit (XLA inserts the reductions)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_index: int = -100):
    """logits (B, S, V) any float dtype; labels (B, S) int32.
    Returns (mean loss fp32, n_valid).

    The label-pick uses a one-hot contraction rather than take_along_axis:
    with a vocab-sharded logits tensor (TP), the one-hot product stays
    elementwise-sharded and reduces with a psum, whereas a gather would
    force an all-gather of the full logits."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    V = logits.shape[-1]
    onehot = jax.nn.one_hot(labels, V, dtype=jnp.float32)
    ll = jnp.sum(lf * onehot, axis=-1)
    nll = lse - ll
    mask = (labels != ignore_index).astype(jnp.float32)
    n = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / n, n


def lm_loss(model, params, batch, *, remat="full", compute_dtype=jnp.bfloat16,
            mesh=None, ep=False, dp_spec=None, aux_weight=0.01, mtp_weight=0.3):
    """Unified next-token loss across model families. Returns (loss, metrics).

    ``labels`` in the batch are already aligned (labels[t] = target for
    logits[t]); the data pipeline produces them by shifting."""
    family = model.cfg.family
    from jax.sharding import PartitionSpec as P
    kw = {}
    if family == "moe":
        kw = dict(mesh=mesh, ep=ep, dp_spec=dp_spec if dp_spec is not None else P())
    out = model.apply(params, batch, remat=remat, compute_dtype=compute_dtype, **kw)
    logits, extra = out
    labels = batch["labels"]
    loss, n = cross_entropy(logits, labels)
    metrics = {"ce": loss, "tokens": n}
    if family == "moe":
        aux = extra["aux_loss"] / max(model.cfg.n_layers - model.cfg.first_dense_layers, 1)
        loss = loss + aux_weight * aux
        metrics["aux"] = aux
        if extra.get("mtp_logits") is not None:
            # MTP predicts token t+2 at position t: shift labels by one more
            mtp_labels = jnp.concatenate(
                [labels[:, 1:], jnp.full_like(labels[:, :1], -100)], axis=1)
            mtp_ce, _ = cross_entropy(extra["mtp_logits"], mtp_labels)
            loss = loss + mtp_weight * mtp_ce
            metrics["mtp_ce"] = mtp_ce
    metrics["loss"] = loss
    return loss, metrics
