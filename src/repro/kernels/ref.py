"""Pure-jnp oracles for every Pallas kernel (the correctness references the
kernel sweep tests assert against, and the fast XLA path on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fwht_ref(x: jax.Array) -> jax.Array:
    """Normalized fast Walsh–Hadamard transform along the last axis.
    x (..., d), d a power of two. Decimation-in-frequency butterfly."""
    d = x.shape[-1]
    assert d & (d - 1) == 0, f"d={d} not a power of two"
    orig_shape = x.shape
    orig_dtype = x.dtype
    y = x.astype(jnp.float32).reshape(-1, d)
    r = y.shape[0]
    blocks = 1
    while blocks < d:
        y = y.reshape(r, blocks, 2, d // (2 * blocks))
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.concatenate([a + b, a - b], axis=-1)
        blocks *= 2
    y = (y.reshape(orig_shape) / np.sqrt(d)).astype(orig_dtype)
    return y


def block_pull_ref(x: jax.Array, q: jax.Array, arm_idx: jax.Array,
                   blk_idx: jax.Array, block: int, metric: str = "l2") -> jax.Array:
    """Sampled coordinate-block distances (the paper's Monte-Carlo pull,
    block form).  x (n, d_pad); q (d_pad,); arm_idx (B,); blk_idx (B, P).
    Returns (B, P) per-block mean coordinate-wise distances."""
    n, d_pad = x.shape
    nb = d_pad // block
    xb = x.reshape(n, nb, block)
    qb = q.reshape(nb, block)
    rows = xb[arm_idx[:, None], blk_idx]          # (B, P, block)
    qs = qb[blk_idx]                              # (B, P, block)
    diff = rows.astype(jnp.float32) - qs.astype(jnp.float32)
    if metric == "l1":
        v = jnp.sum(jnp.abs(diff), axis=-1)
    else:
        v = jnp.sum(diff * diff, axis=-1)
    return (v / block).astype(jnp.float32)


def block_pull_multi_ref(x: jax.Array, qs: jax.Array, arm_idx: jax.Array,
                         blk_idx: jax.Array, block: int,
                         metric: str = "l2") -> jax.Array:
    """Cross-query batched pull (the index-serving hot loop): one gather
    serves every query's arm frontier.  x (n, d_pad); qs (Q, d_pad);
    arm_idx (Q, B); blk_idx (Q, B, P).  Returns (Q, B, P)."""
    n, d_pad = x.shape
    Q = qs.shape[0]
    nb = d_pad // block
    xb = x.reshape(n, nb, block)
    qb = qs.reshape(Q, nb, block)
    rows = xb[arm_idx[:, :, None], blk_idx]              # (Q, B, P, block)
    qrows = qb[jnp.arange(Q)[:, None, None], blk_idx]    # (Q, B, P, block)
    diff = rows.astype(jnp.float32) - qrows.astype(jnp.float32)
    if metric == "l1":
        v = jnp.sum(jnp.abs(diff), axis=-1)
    else:
        v = jnp.sum(diff * diff, axis=-1)
    return (v / block).astype(jnp.float32)


def fused_epoch_pull_ref(x: jax.Array, qs: jax.Array, arm_idx: jax.Array,
                         blk_idx: jax.Array, block: int,
                         metric: str = "l2") -> jax.Array:
    """Round-fused epoch pull (kernels/fused_race.py): T = R·P block pulls
    per selected arm, reduced to per-arm Welford batch statistics.
    x (n, d_pad); qs (Q, d_pad); arm_idx (Q, B); blk_idx (Q, B, T).
    Returns (Q, B, 2) fp32: (mean, M2) of each arm's T pulled values."""
    vals = block_pull_multi_ref(x, qs, arm_idx, blk_idx, block, metric)
    mean = jnp.mean(vals, axis=-1)
    m2 = jnp.sum(jnp.square(vals - mean[..., None]), axis=-1)
    return jnp.stack([mean, m2], axis=-1)


def pairwise_dist_ref(qs: jax.Array, x: jax.Array, metric: str = "l2",
                      chunk: int = 2048) -> jax.Array:
    """Exact distances. qs (Q, d), x (n, d) -> (Q, n) SUM-form distances
    (ℓ2² or ℓ1), accumulated in fp32 over d-chunks."""
    Q, d = qs.shape
    n = x.shape[0]
    out = jnp.zeros((Q, n), jnp.float32)
    for start in range(0, d, chunk):
        qc = qs[:, start:start + chunk].astype(jnp.float32)
        xc = x[:, start:start + chunk].astype(jnp.float32)
        if metric == "l1":
            out = out + jnp.sum(jnp.abs(qc[:, None, :] - xc[None, :, :]), axis=-1)
        else:
            # MXU-form: ‖q‖² + ‖x‖² − 2 q·x
            out = out + (jnp.sum(qc * qc, -1)[:, None] + jnp.sum(xc * xc, -1)[None, :]
                         - 2.0 * qc @ xc.T)
    return out
