"""Pallas TPU kernel: the *round-fused* BMO racing pull (DESIGN.md §4).

``block_pull_multi`` launches once per racing round: (Q, B, P) programs, each
fetching one corpus block, with all selection/CI bookkeeping back on the host
side of the launch. At serving scale the launch+bookkeeping overhead per
round dominates once most arms are rejected. This kernel fuses a whole
*epoch* — R rounds × P pulls — into one launch:

  grid = (Q, B): one program per (query, selected arm). Each program streams
  its arm's T = R·P sampled corpus blocks HBM→VMEM with *double-buffered*
  async DMA (the next block is in flight while the current one reduces
  against the query row) and folds every pulled block-mean distance into a
  per-arm Welford accumulator (count is the static T; mean/M2 live in VMEM
  scratch). Output is (Q, B, 2): the epoch's (mean, M2) batch statistics,
  merged into the running per-arm state by ``confidence.welford_merge``.

HBM traffic per program is exactly T·block elements of corpus plus one query
row (reused across the B inner grid steps — the index map pins it per q, so
Pallas's pipeline keeps it resident). Acceptance/selection run once per
epoch at the launch boundary, cutting host-side (Q, n) bookkeeping and
launch count by R× — see index/frontier.py for the other half of the story.

The (arm, block) index operands are scalar-prefetched so the DMA source
addresses are known before the body runs; corpus stays in ANY/HBM memory
space and is never materialized in VMEM beyond the two streaming slots.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific grid spec (scalar prefetch); interpret mode supports it
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

N_BUF = 2  # default streaming depth: one slot reduces while one streams


def _fused_epoch_kernel(arm_ref, blk_ref, x_ref, q_ref, o_ref, buf, sem, *,
                        block: int, metric: str, n_buf: int):
    qid = pl.program_id(0)
    b = pl.program_id(1)
    arm = arm_ref[qid, b]
    T = blk_ref.shape[2]

    def dma(slot, t):
        blk = blk_ref[qid, b, t]
        return pltpu.make_async_copy(
            x_ref.at[arm, pl.ds(blk * block, block)],
            buf.at[slot, 0],
            sem.at[slot],
        )

    dma(0, 0).start()

    def body(t, carry):
        mean, m2 = carry
        cur = jax.lax.rem(t, n_buf)

        # stream the next block while the current one is reduced
        @pl.when(t + 1 < T)
        def _():
            dma(jax.lax.rem(t + 1, n_buf), t + 1).start()

        dma(cur, t).wait()
        blk = blk_ref[qid, b, t]
        qv = q_ref[0, pl.ds(blk * block, block)].astype(jnp.float32)
        diff = buf[cur, 0, :].astype(jnp.float32) - qv
        if metric == "l1":
            v = jnp.sum(jnp.abs(diff)) / block
        else:
            v = jnp.sum(diff * diff) / block

        # running Welford over the epoch's T pulls
        delta = v - mean
        mean = mean + delta / (t + 1).astype(jnp.float32)
        m2 = m2 + delta * (v - mean)
        return mean, m2

    mean, m2 = jax.lax.fori_loop(0, T, body, (0.0, 0.0))
    o_ref[0, 0, 0] = mean
    o_ref[0, 0, 1] = m2


def fused_epoch_pull_pallas(x: jax.Array, qs: jax.Array, arm_idx: jax.Array,
                            blk_idx: jax.Array, *, block: int,
                            metric: str = "l2", n_buf: int = N_BUF,
                            interpret: bool = False) -> jax.Array:
    """x (n, d_pad); qs (Q, d_pad); arm_idx (Q, B) int32; blk_idx (Q, B, T)
    int32, T = rounds·pulls_per_round.  Returns (Q, B, 2) fp32: per-arm
    (mean, M2) Welford statistics of the T pulled block distances.
    ``n_buf`` VMEM slots stream the corpus blocks (2 = classic double
    buffering; deeper queues hide longer DMA latencies at the cost of
    n_buf·block·itemsize scratch per program — a ``repro.tune`` arm)."""
    n, d_pad = x.shape
    Q, B, T = blk_idx.shape
    assert d_pad % block == 0 and arm_idx.shape == (Q, B)
    assert n_buf >= 2, f"need at least 2 streaming slots, got {n_buf}"

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Q, B),
        in_specs=[
            # corpus stays off-chip; blocks are DMA'd manually
            pl.BlockSpec(memory_space=pltpu.ANY),
            # one query row per program, constant across the B inner steps
            pl.BlockSpec((1, d_pad), lambda q, i, arm, blk: (q, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 2), lambda q, i, arm, blk: (q, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_buf, 1, block), x.dtype),
            pltpu.SemaphoreType.DMA((n_buf,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_fused_epoch_kernel, block=block, metric=metric,
                          n_buf=n_buf),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, B, 2), jnp.float32),
        interpret=interpret,
    )(arm_idx.astype(jnp.int32), blk_idx.astype(jnp.int32), x, qs)
