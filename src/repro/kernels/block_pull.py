"""Pallas TPU kernel: the BMO Monte-Carlo *pull* — sampled coordinate-block
distances between a query and a batch of selected arms.

This is the paper's hot loop adapted to the TPU memory system: instead of
per-coordinate scalar gathers (CPU-friendly, TPU-hostile), each pull fetches
one lane-aligned width-``block`` slice of the arm's row from HBM into VMEM.
The BlockSpec index_map is driven by *scalar-prefetched* (arm, block) index
operands, so HBM traffic per pull is exactly ``block`` elements — the whole
point of the adaptive subsampling.

grid = (B, P): one program per (selected arm, pull).

The multi-query variant (``block_pull_multi_pallas``) extends the grid to
(Q, B, P) for the index-serving path: one launch races every active query's
arm frontier, so per-round kernel overhead is paid once instead of Q times
and the scalar-prefetched index operands cover the whole batch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific grid spec (scalar prefetch); interpret mode supports it
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _pull_kernel(arm_ref, blk_ref, x_ref, q_ref, o_ref, *, block: int, metric: str):
    diff = x_ref[...].astype(jnp.float32) - q_ref[...].astype(jnp.float32)
    if metric == "l1":
        v = jnp.sum(jnp.abs(diff))
    else:
        v = jnp.sum(diff * diff)
    o_ref[0, 0] = v / block


def block_pull_pallas(x: jax.Array, q: jax.Array, arm_idx: jax.Array,
                      blk_idx: jax.Array, *, block: int, metric: str = "l2",
                      interpret: bool = False) -> jax.Array:
    """x (n, d_pad); q (d_pad,); arm_idx (B,) int32; blk_idx (B, P) int32.
    Returns (B, P) fp32 per-block mean coordinate-wise distances."""
    n, d_pad = x.shape
    B, P = blk_idx.shape
    assert d_pad % block == 0
    q2 = q.reshape(1, d_pad)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, block), lambda i, p, arm, blk: (arm[i], blk[i, p])),
            pl.BlockSpec((1, block), lambda i, p, arm, blk: (0, blk[i, p])),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, p, arm, blk: (i, p)),
    )
    return pl.pallas_call(
        functools.partial(_pull_kernel, block=block, metric=metric),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, P), jnp.float32),
        interpret=interpret,
    )(arm_idx.astype(jnp.int32), blk_idx.astype(jnp.int32), x, q2)


def _pull_multi_kernel(arm_ref, blk_ref, x_ref, q_ref, o_ref, *, block: int,
                       metric: str):
    diff = x_ref[...].astype(jnp.float32) - q_ref[...].astype(jnp.float32)
    if metric == "l1":
        v = jnp.sum(jnp.abs(diff))
    else:
        v = jnp.sum(diff * diff)
    o_ref[0, 0, 0] = v / block


def block_pull_multi_pallas(x: jax.Array, qs: jax.Array, arm_idx: jax.Array,
                            blk_idx: jax.Array, *, block: int,
                            metric: str = "l2",
                            interpret: bool = False) -> jax.Array:
    """x (n, d_pad); qs (Q, d_pad); arm_idx (Q, B) int32; blk_idx (Q, B, P)
    int32.  Returns (Q, B, P) fp32 per-block mean coordinate-wise distances."""
    n, d_pad = x.shape
    Q, B, P = blk_idx.shape
    assert d_pad % block == 0 and arm_idx.shape == (Q, B)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Q, B, P),
        in_specs=[
            pl.BlockSpec((1, block),
                         lambda q, i, p, arm, blk: (arm[q, i], blk[q, i, p])),
            pl.BlockSpec((1, block),
                         lambda q, i, p, arm, blk: (q, blk[q, i, p])),
        ],
        out_specs=pl.BlockSpec((1, 1, 1), lambda q, i, p, arm, blk: (q, i, p)),
    )
    return pl.pallas_call(
        functools.partial(_pull_multi_kernel, block=block, metric=metric),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, B, P), jnp.float32),
        interpret=interpret,
    )(arm_idx.astype(jnp.int32), blk_idx.astype(jnp.int32), x, qs)
