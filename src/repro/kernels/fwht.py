"""Pallas TPU kernel: fast Walsh–Hadamard transform (normalized), used by the
§IV-B randomized-rotation Monte-Carlo box.

Tiling: grid over row-blocks; each program holds an (R, d) tile in VMEM and
runs the log2(d) decimation-in-frequency butterfly in-register. d ≤ 32k rows
fit VMEM comfortably at R = 8 (8 × 32768 × 4B = 1 MiB)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _fwht_kernel(x_ref, o_ref, *, d: int):
    y = x_ref[...].astype(jnp.float32)          # (R, d)
    r = y.shape[0]
    blocks = 1
    while blocks < d:
        y = y.reshape(r, blocks, 2, d // (2 * blocks))
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.concatenate([a + b, a - b], axis=-1)
        blocks *= 2
    o_ref[...] = (y.reshape(r, d) / np.sqrt(d)).astype(o_ref.dtype)


def fwht_pallas(x: jax.Array, *, row_block: int = 8, interpret: bool = False) -> jax.Array:
    """x (n, d) with d a power of two -> FWHT(x) along the last axis."""
    n, d = x.shape
    assert d & (d - 1) == 0, f"d={d} not a power of two"
    rb = min(row_block, n)
    pad = (-n) % rb
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    grid = (xp.shape[0] // rb,)
    out = pl.pallas_call(
        functools.partial(_fwht_kernel, d=d),
        grid=grid,
        in_specs=[pl.BlockSpec((rb, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp)
    return out[:n] if pad else out
