"""Jit'd dispatch wrappers around the Pallas kernels.

``impl``:
  * "auto"      — Pallas-compiled on TPU, jnp reference on CPU (XLA-fused;
                  the interpreter would be orders of magnitude slower),
  * "kernel"    — Pallas compiled (real TPU lowering),
  * "interpret" — Pallas interpret mode (CPU-executable kernel body; what the
                  kernel sweep tests use against the refs),
  * "ref"       — pure-jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.block_pull import block_pull_multi_pallas, block_pull_pallas
from repro.kernels.fused_race import fused_epoch_pull_pallas
from repro.kernels.fwht import fwht_pallas
from repro.kernels.pairwise_dist import pairwise_dist_pallas


def _resolve(impl: str) -> str:
    if impl != "auto":
        return impl
    return "kernel" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("impl",))
def fwht(x: jax.Array, impl: str = "auto") -> jax.Array:
    impl = _resolve(impl)
    if impl == "ref":
        return kref.fwht_ref(x)
    return fwht_pallas(x, interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("block", "metric", "impl"))
def block_pull(x, q, arm_idx, blk_idx, *, block: int, metric: str = "l2",
               impl: str = "auto"):
    impl = _resolve(impl)
    if impl == "ref":
        return kref.block_pull_ref(x, q, arm_idx, blk_idx, block, metric)
    return block_pull_pallas(x, q, arm_idx, blk_idx, block=block, metric=metric,
                             interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("block", "metric", "impl"))
def block_pull_multi(x, qs, arm_idx, blk_idx, *, block: int, metric: str = "l2",
                     impl: str = "auto"):
    """Cross-query batched pull: arm_idx (Q, B), blk_idx (Q, B, P) → (Q, B, P)."""
    impl = _resolve(impl)
    if impl == "ref":
        return kref.block_pull_multi_ref(x, qs, arm_idx, blk_idx, block, metric)
    return block_pull_multi_pallas(x, qs, arm_idx, blk_idx, block=block,
                                   metric=metric, interpret=(impl == "interpret"))


@functools.partial(jax.jit,
                   static_argnames=("block", "metric", "impl", "n_buf"))
def fused_epoch_pull(x, qs, arm_idx, blk_idx, *, block: int,
                     metric: str = "l2", impl: str = "auto",
                     n_buf: int = 2):
    """Round-fused epoch pull: arm_idx (Q, B), blk_idx (Q, B, R·P) →
    (Q, B, 2) per-arm (mean, M2) Welford batch statistics. ``n_buf`` is
    the Pallas kernel's VMEM streaming depth (``BMOConfig.kernel_buffers``,
    a ``repro.tune`` knob on real hardware; the jnp reference ignores it)."""
    impl = _resolve(impl)
    if impl == "ref":
        return kref.fused_epoch_pull_ref(x, qs, arm_idx, blk_idx, block, metric)
    return fused_epoch_pull_pallas(x, qs, arm_idx, blk_idx, block=block,
                                   metric=metric, n_buf=n_buf,
                                   interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("metric", "impl"))
def pairwise_dist(qs, x, *, metric: str = "l2", impl: str = "auto"):
    impl = _resolve(impl)
    if impl == "ref":
        return kref.pairwise_dist_ref(qs, x, metric)
    m = metric
    if impl == "kernel" and metric == "l2":
        m = "l2_dot"  # MXU form on real hardware
    return pairwise_dist_pallas(qs, x, metric=m, interpret=(impl == "interpret"))
