"""Pallas TPU kernel: tiled exact pairwise distances (the BMO exact-evaluation
fallback and the brute-force baseline).

Two variants:
  * elementwise (ℓ1 / ℓ2): grid (Q/bq, n/bn, d/bd); a (bq, bn, bd) broadcast
    tile is reduced over bd and accumulated into the (bq, bn) output block
    across the d-grid (arbitrary/sequential innermost dimension).
  * MXU ℓ2 ("l2_dot"): accumulates −2·q xᵀ with jnp.dot (runs on the MXU)
    and adds ‖q‖² + ‖x‖² row/col norms on the last d-step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_kernel(q_ref, x_ref, o_ref, *, metric: str, nd: int):
    kd = pl.program_id(2)

    @pl.when(kd == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    qt = q_ref[...].astype(jnp.float32)            # (bq, bd)
    xt = x_ref[...].astype(jnp.float32)            # (bn, bd)
    if metric == "l1":
        part = jnp.sum(jnp.abs(qt[:, None, :] - xt[None, :, :]), axis=-1)
    elif metric == "l2_dot":
        # each bd slice contributes ‖q_s‖² + ‖x_s‖² − 2 q_s·x_sᵀ (MXU form)
        part = (-2.0 * jnp.dot(qt, xt.T, preferred_element_type=jnp.float32)
                + jnp.sum(qt * qt, -1)[:, None] + jnp.sum(xt * xt, -1)[None, :])
    else:
        d = qt[:, None, :] - xt[None, :, :]
        part = jnp.sum(d * d, axis=-1)
    o_ref[...] += part


def pairwise_dist_pallas(qs: jax.Array, x: jax.Array, *, metric: str = "l2",
                         bq: int = 8, bn: int = 128, bd: int = 512,
                         interpret: bool = False) -> jax.Array:
    """qs (Q, d), x (n, d) -> (Q, n) fp32 sum-form distances (ℓ2² or ℓ1)."""
    Q, d = qs.shape
    n = x.shape[0]
    bq, bn, bd = min(bq, Q), min(bn, n), min(bd, d)
    pq, pn, pd = (-Q) % bq, (-n) % bn, (-d) % bd
    qp = jnp.pad(qs, ((0, pq), (0, pd))) if (pq or pd) else qs
    xp = jnp.pad(x, ((0, pn), (0, pd))) if (pn or pd) else x
    nd = qp.shape[1] // bd
    grid = (qp.shape[0] // bq, xp.shape[0] // bn, nd)
    out = pl.pallas_call(
        functools.partial(_dist_kernel, metric=metric, nd=nd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j, kd: (i, kd)),
            pl.BlockSpec((bn, bd), lambda i, j, kd: (j, kd)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, kd: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], xp.shape[0]), jnp.float32),
        interpret=interpret,
    )(qp, xp)
    return out[:Q, :n]
