"""Pallas TPU kernel: fused flash attention.

The §Roofline analysis shows every LM cell memory-bound on unfused
attention intermediates (scores/probabilities round-tripping HBM in the
XLA-scan lowering of online softmax). This kernel is the fix on real
hardware: the (bq, bk) score tile, running max/normalizer and the output
accumulator all live in VMEM scratch across the (sequential) KV-block grid
dimension; HBM traffic is exactly q + k + v + out.

grid = (B, H, nq, nk), nk innermost/sequential. Scratch persists across nk:
  m (bq,)   running row max
  l (bq,)   running normalizer
  acc (bq, D) output accumulator
Causal masking handled by absolute positions (q_offset for decode).
Validated against models.common._sdpa in interpret mode (tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                  bq: int, bk: int, nk: int, causal: bool, q_offset: int,
                  sm_scale: float):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, -jnp.inf)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)           # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)           # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)           # (bk, Dv)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale

    iq = pl.program_id(2)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    if causal:
        s = jnp.where(k_pos <= q_pos, s, -1e30)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    scale = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * scale + jnp.sum(p, axis=1)
    acc_s[...] = acc_s[...] * scale[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...] /
                       jnp.maximum(l_s[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, q_offset: int = 0,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q (B, H, Sq, D); k, v (B, H, Sk, D) [GQA: repeat kv heads in the
    wrapper]. Returns (B, H, Sq, Dv)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    Dv = v.shape[-1]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    grid = (B, H, nq, nk)
    sm_scale = 1.0 / np.sqrt(D)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
        q_offset=q_offset, sm_scale=sm_scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, Dv), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")) if pltpu else None,
        interpret=interpret,
    )(q, k, v)
