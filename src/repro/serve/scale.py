"""repro.serve.scale — autoscaling hints from request-plane telemetry
(ROADMAP "replica write-log shipping + autoscaling", first slice).

A ``ScalePolicy`` consumes the queue-depth / latency fields the plane adds
to ``ServeStats`` (schema v2) and emits *recommendations* — it never
touches the index itself. The launcher applies them behind ``--autoscale``
(recommendation-only by default; ``--autoscale-apply`` executes
``add_replicas``), so capacity decisions stay observable and reversible.

The default ``QueueDepthPolicy`` is deliberately boring: sustained queue
depth (or p95 latency over target) scales *out*; a sustained idle queue
scales back *in*; a shard-imbalanced index is told to ``reshard`` before
replicating, because replicas multiply an imbalance instead of fixing it.
Hysteresis comes from requiring ``sustain`` consecutive observations and a
``cooldown`` between actions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.api import ServeStats

ACTIONS = ("none", "add_replicas", "reshard", "fallback_untuned", "retune",
           "evict_namespace", "rebalance")


@dataclasses.dataclass(frozen=True)
class ScaleDecision:
    """One recommendation: do ``action`` with parameter ``value``.
    ``target`` names the namespace a fleet-granularity action applies to
    (empty for whole-plane actions)."""

    action: str = "none"          # none | add_replicas | reshard |
                                  # fallback_untuned | retune |
                                  # evict_namespace | rebalance
    value: int = 0                # target replica count / shard count
    reason: str = ""
    target: str = ""              # namespace for fleet-granularity actions

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(f"unknown action {self.action!r} "
                             f"(want one of {ACTIONS})")


class ScalePolicy:
    """Interface: feed one ``ServeStats`` snapshot per observation window,
    get a ``ScaleDecision`` back. Implementations keep their own hysteresis
    state; ``recommend`` must stay side-effect-free w.r.t. the index."""

    def recommend(self, stats: ServeStats) -> ScaleDecision:
        raise NotImplementedError


@dataclasses.dataclass
class QueueDepthPolicy(ScalePolicy):
    """Watermark policy over plane queue depth and terminal p95 latency."""

    high_queue: int = 8            # queue depth that signals saturation
    low_queue: int = 0             # queue depth that signals idle capacity
    p95_target_ms: Optional[float] = None   # latency SLO (None = ignore)
    imbalance: float = 2.0         # max/mean shard coord-ops → reshard
    sustain: int = 3               # consecutive hot/cold windows to act
    cooldown: int = 3              # windows to hold after any action
    max_replicas: int = 4
    max_shards: int = 8
    _hot: int = dataclasses.field(default=0, repr=False)
    _cold: int = dataclasses.field(default=0, repr=False)
    _hold: int = dataclasses.field(default=0, repr=False)

    def recommend(self, stats: ServeStats) -> ScaleDecision:
        if self._hold > 0:
            self._hold -= 1
            return ScaleDecision(reason="cooldown")
        hot = stats.plane_queue_depth >= self.high_queue
        # p95 is 0.0 (never None/NaN) on an empty latency window since
        # schema v3, so the SLO comparison is unconditional and an empty
        # window can never read as hot
        if (self.p95_target_ms is not None
                and (stats.plane_latency_p95_ms or 0.0) > self.p95_target_ms):
            hot = True
        cold = (stats.plane_queue_depth <= self.low_queue
                and stats.plane_active == 0)
        self._hot = self._hot + 1 if hot else 0
        self._cold = self._cold + 1 if (cold and not hot) else 0

        if self._hot >= self.sustain:
            self._hot = 0
            self._hold = self.cooldown
            ops = stats.shard_coord_ops
            if ops and sum(ops) > 0:
                mean = sum(ops) / len(ops)
                if mean > 0 and max(ops) / mean >= self.imbalance:
                    target = min(2 * len(ops), self.max_shards)
                    if target > len(ops):
                        return ScaleDecision(
                            "reshard", target,
                            f"queue {stats.plane_queue_depth} high and "
                            f"shard load imbalanced "
                            f"(max/mean {max(ops) / mean:.2f})")
            if stats.replicas < self.max_replicas:
                return ScaleDecision(
                    "add_replicas", stats.replicas + 1,
                    f"queue depth {stats.plane_queue_depth} "
                    f"(p95 {stats.plane_latency_p95_ms}) sustained "
                    f"{self.sustain} windows")
            return ScaleDecision(reason="saturated at max_replicas")
        if self._cold >= self.sustain and stats.replicas > 1:
            self._cold = 0
            self._hold = self.cooldown
            return ScaleDecision(
                "add_replicas", stats.replicas - 1,
                f"idle {self.sustain} windows at {stats.replicas} replicas")
        return ScaleDecision(reason="steady")


class RecallGuardPolicy(ScalePolicy):
    """Correctness guard: consume the SLO engine's recall alerts
    (DESIGN.md §10.3). A burning recall SLO means audited traffic is
    violating the paper's 1-δ contract — overwhelmingly a suspect tuned
    config (the build-time defaults are the conservative reference), so
    the guard first recommends ``fallback_untuned`` (serve every query on
    build defaults) and then ``retune`` (flag the tuned config for a
    re-race). It never escalates past those two — a recall violation that
    survives the fallback is a bug, not a capacity problem.

    Stateless w.r.t. hysteresis on purpose: the burn-rate rules already
    provide multi-window debouncing; duplicating it here would only slow
    the response to served wrong answers."""

    def __init__(self, sink, *, slo: str = "recall"):
        self.sink = sink              # repro.obs.slo.AlertSink
        self.slo = slo

    def recommend(self, stats: ServeStats) -> ScaleDecision:
        burning = self.sink.active(self.slo)
        if not burning:
            return ScaleDecision(reason="recall SLO healthy")
        worst = max(burning, key=lambda a: a.burn_long)
        why = (f"recall SLO burning ({worst.rule}: "
               f"{worst.burn_long:.1f}x of delta budget {worst.budget:g})")
        if not stats.serving_fallback:
            return ScaleDecision("fallback_untuned", 1, why)
        if not stats.retune_requested:
            return ScaleDecision("retune", 1, why + "; fallback active")
        return ScaleDecision(
            reason=why + "; fallback active, re-tune already flagged")


@dataclasses.dataclass
class FleetPressurePolicy(ScalePolicy):
    """Namespace-granularity pressure policy over the schema-v6 fleet
    rollup fields (``ns_queue_depth``, ``fleet_namespaces_resident``).

    Two signals, two levers:

      * a COLD namespace being starved while the residency set is full
        (its queue is deep but it is not among the resident set's hot
        namespaces) → ``evict_namespace`` the resident namespace with the
        LEAST queued demand, freeing a residency slot for the starved one
        to reload into on its next admission;
      * sustained aggregate skew (one namespace holding more than
        ``skew`` of all queued demand) → ``rebalance`` so the placement
        plan re-packs device windows around the live footprint.

    Recommendation-only like every ScalePolicy: the Fleet executes
    ``evict_namespace``/``rebalance`` via ``apply_fleet``.
    """

    high_queue: int = 4            # per-namespace depth that reads as demand
    skew: float = 0.5              # one namespace's share of queued demand
    sustain: int = 3               # consecutive windows before acting
    cooldown: int = 3
    _hot: int = dataclasses.field(default=0, repr=False)
    _hold: int = dataclasses.field(default=0, repr=False)

    def recommend(self, stats: ServeStats) -> ScaleDecision:
        if self._hold > 0:
            self._hold -= 1
            return ScaleDecision(reason="cooldown")
        depth = stats.ns_queue_depth or {}
        total = sum(depth.values())
        hot = total > 0 and max(depth.values()) >= self.high_queue
        self._hot = self._hot + 1 if hot else 0
        if self._hot < self.sustain:
            return ScaleDecision(reason="steady")
        self._hot = 0
        self._hold = self.cooldown
        worst = max(depth, key=depth.get)
        coldest = min(depth, key=depth.get)
        if depth[worst] / max(total, 1) >= self.skew:
            return ScaleDecision(
                "rebalance", 0,
                f"namespace {worst!r} holds {depth[worst]}/{total} queued "
                f"tickets (skew >= {self.skew:g})", target=worst)
        return ScaleDecision(
            "evict_namespace", 0,
            f"queued demand across {len(depth)} namespaces with "
            f"{stats.fleet_namespaces_resident} resident — freeing the "
            f"least-demanded slot", target=coldest)


def apply_fleet(fleet, decision: ScaleDecision) -> bool:
    """Execute a fleet-granularity decision on the live ``Fleet``.
    Returns True iff it acted (an eviction refused by the in-flight
    guard counts as not acted)."""
    if decision.action == "evict_namespace" and decision.target:
        return fleet.evict(decision.target)
    if decision.action == "rebalance":
        fleet.rebalance()
        return True
    return False


def apply_guard(index, decision: ScaleDecision) -> bool:
    """Execute a recall-guard decision on the live handle. Returns True
    iff it acted. (``add_replicas``/``reshard`` stay with the launcher —
    those are capacity ops; these two are correctness ops.)"""
    if decision.action == "fallback_untuned":
        index.force_untuned(True)
        return True
    if decision.action == "retune":
        index.request_retune(decision.reason)
        return True
    return False
