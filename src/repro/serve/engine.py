"""Batched serving engine: continuous batch of decode slots + the BMO-NN
retrieval hook (kNN-LM-style interpolation, paper technique at serving time).

This is deliberately a *small* engine (slot-based static batching, greedy
sampling): the point is end-to-end runnability of (prefill → decode →
retrieve → interpolate) on the same substrate the dry-run proves out at mesh
scale.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BMOConfig, ParallelPlan
from repro.serve.steps import init_cache, make_decode_step, make_prefill_step


@dataclasses.dataclass
class KNNLMConfig:
    lam: float = 0.25          # interpolation weight toward the kNN dist
    temperature: float = 1.0
    bmo: BMOConfig = dataclasses.field(default_factory=lambda: BMOConfig(k=8))


class ServeEngine:
    def __init__(self, model, params, plan: ParallelPlan, mesh, *,
                 batch_size: int, max_seq: int,
                 knn_lm: Optional[KNNLMConfig] = None,
                 datastore=None):
        self.model = model
        self.params = params
        self.mesh = mesh
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.prefill_step, self.rules = make_prefill_step(model, plan, mesh)
        self.prefill_step = jax.jit(self.prefill_step, donate_argnums=2)
        self.knn_lm = knn_lm
        self.datastore = datastore      # (keys (N, d), next_token_ids (N,))
        if knn_lm is not None:
            # hidden-state decode (DenseLM exposes return_hidden)
            def _decode(params, cache, tokens):
                logits, new_cache, hidden = model.decode_step(
                    params, cache, tokens, return_hidden=True)
                return logits, new_cache, hidden[:, -1].astype(jnp.float32)

            self.decode_step = jax.jit(_decode, donate_argnums=1)
        else:
            def _decode(params, cache, tokens):
                logits, new_cache = model.decode_step(params, cache, tokens)
                return logits, new_cache, None

            self.decode_step = jax.jit(_decode, donate_argnums=1)
        self.cache = init_cache(model, batch_size, max_seq)

    # -- kNN-LM hook (the paper's technique in the serving path) ------------
    def _knn_logits(self, hidden, rng):
        from repro.core import bmo_nn
        keys, next_ids = self.datastore
        res = bmo_nn.knn(keys, hidden, self.knn_lm.bmo, rng)
        V = self.model.cfg.vocab_size
        # distance-weighted vote over retrieved next-tokens
        w = jax.nn.softmax(-jnp.asarray(res.values) / self.knn_lm.temperature, axis=-1)
        toks = next_ids[res.indices]                      # (B, k)
        knn_probs = jnp.zeros((hidden.shape[0], V), jnp.float32)
        knn_probs = knn_probs.at[jnp.arange(hidden.shape[0])[:, None], toks].add(w)
        return jnp.log(knn_probs + 1e-9), res.coord_ops

    def generate(self, prompts: np.ndarray, max_new_tokens: int, rng=None):
        """prompts (B, S0) int32 -> (B, max_new_tokens) int32 greedy tokens.
        With knn_lm enabled, decode logits are interpolated with the BMO-NN
        retrieval distribution."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        B = prompts.shape[0]
        assert B == self.batch_size
        logits, cache = self.prefill_step(self.params, {"tokens": jnp.asarray(prompts)},
                                          self.cache)
        tok = jnp.argmax(logits[:, -1].astype(jnp.float32), -1).astype(jnp.int32)[:, None]
        out = [tok]
        retrieval_ops = 0.0
        for _ in range(max_new_tokens - 1):
            logits, cache, hidden = self.decode_step(self.params, cache, tok)
            mix = jax.nn.log_softmax(logits[:, -1].astype(jnp.float32))
            if self.knn_lm is not None and self.datastore is not None:
                rng, sub = jax.random.split(rng)
                knn_logits, ops = self._knn_logits(hidden, sub)
                retrieval_ops += float(jnp.sum(ops))
                lam = self.knn_lm.lam
                mix = jnp.logaddexp(
                    jnp.log1p(-lam) + mix,
                    jnp.log(lam) + jax.nn.log_softmax(knn_logits))
            tok = jnp.argmax(mix, -1).astype(jnp.int32)[:, None]
            out.append(tok)
        self.cache = cache
        return np.asarray(jnp.concatenate(out, axis=1)), retrieval_ops
