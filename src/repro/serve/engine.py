"""Batched serving engine: continuous batch of decode slots + the BMO-NN
retrieval hook (kNN-LM-style interpolation, paper technique at serving time).

This is deliberately a *small* engine (slot-based static batching, greedy
sampling): the point is end-to-end runnability of (prefill → decode →
retrieve → interpolate) on the same substrate the dry-run proves out at mesh
scale.

Retrieval goes through one ``repro.api.Index`` handle (DESIGN.md §6) built
at engine construction or passed in pre-built/loaded: the corpus layout,
cached rotation, CI warm-start priors, the query LRU (exact repeats free,
near repeats CI-warm-started) and the next-token payload all live behind
the handle. Since PR 5 the engine *owns a request plane*
(``repro.serve.plane.RequestPlane``, DESIGN.md §7) over that handle:
external callers submit/stream anytime tickets against ``engine.plane``
while the decode loop's per-step retrieval goes through the blocking
``plane.query`` shim (submit + drain — same cache and counter semantics
the old direct ``Index.query`` hot path had). With ``index_append=True``
the engine inserts each step's (hidden, next-token) pairs back into the
index — the datastore grows during decode, true kNN-LM behaviour — with
tombstone debt amortized by the handle's ``CompactionPolicy``.
``engine.stats`` is the plane's typed ``ServeStats`` (queue/latency
telemetry included, schema v2).

Admin operations (live re-sharding, replica fan-out) are the handle's:
``engine.index.reshard(S')`` / ``engine.index.add_replicas(r)`` work on the
running engine — the epoch fence invalidates the cache, remaps the payload
and fences in-flight plane tickets without a save/load cycle.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (CachePolicy, CompactionPolicy, Index, QueryCache,
                       ServeStats)
from repro.configs.base import BMOConfig, ParallelPlan
from repro.serve.plane import PlaneConfig, RequestPlane
from repro.serve.steps import init_cache, make_decode_step, make_prefill_step

__all__ = ["KNNLMConfig", "QueryCache", "ServeEngine"]


@dataclasses.dataclass
class KNNLMConfig:
    lam: float = 0.25          # interpolation weight toward the kNN dist
    temperature: float = 1.0
    bmo: BMOConfig = dataclasses.field(default_factory=lambda: BMOConfig(k=8))
    cache_size: int = 256      # query LRU entries (0 disables)
    compact_threshold: float = 0.5  # auto-compact when tombstones cross this
                                    # (>=1 disables)
    index_shards: int = 0      # >1: build a ShardedIndexStore spanning that
                               # many mesh devices (DESIGN.md §5)
    near_threshold: float = 0.95    # cosine sim above which a cache miss is
                                    # a *near* repeat: its race is CI-warm-
                                    # started from the cached neighbour's
                                    # result (0 disables)
    near_prior_scale: float = 0.25  # variance-prior tightening applied to
                                    # the cached neighbour's top-k arms
    plane: PlaneConfig = dataclasses.field(default_factory=PlaneConfig)
                                    # request-plane scheduler knobs
                                    # (admission bound, fairness, fence)

    def cache_policy(self) -> CachePolicy:
        return CachePolicy(capacity=self.cache_size,
                           near_threshold=self.near_threshold,
                           near_prior_scale=self.near_prior_scale)

    def compaction_policy(self) -> CompactionPolicy:
        return CompactionPolicy(threshold=self.compact_threshold)


class ServeEngine:
    def __init__(self, model, params, plan: ParallelPlan, mesh, *,
                 batch_size: int, max_seq: int,
                 knn_lm: Optional[KNNLMConfig] = None,
                 datastore=None, index=None, index_append: bool = False,
                 plane: Optional[RequestPlane] = None,
                 plane_namespace: Optional[str] = None):
        """``datastore``: (keys (N, d), next_token_ids (N,)) — preprocessed
        into an ``Index`` at construction. ``index``: a pre-built
        ``repro.api.Index`` handle — or a raw (Sharded)IndexStore, wrapped
        on the way in (pass next-token ids via ``datastore=(None, ids)``).
        ``index_append``: insert each decode step's (hidden, token) pairs
        back into the index. ``plane``: inject an externally owned
        ``RequestPlane`` (e.g. a fleet's shared plane from
        ``Fleet.serve()``) instead of building a private one — the decode
        loop's retrieval then multiplexes with fleet traffic under the
        same admission/fairness machinery. ``plane_namespace``: the
        namespace label the decode loop's retrieval tickets carry on a
        fleet plane (None on a single-index plane)."""
        self.model = model
        self.params = params
        self.mesh = mesh
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.prefill_step, self.rules = make_prefill_step(model, plan, mesh)
        self.prefill_step = jax.jit(self.prefill_step, donate_argnums=2)
        self.knn_lm = knn_lm
        self.index: Optional[Index] = None
        self.index_append = index_append
        if knn_lm is not None and (index is not None or datastore is not None):
            next_ids = datastore[1] if datastore is not None else None
            if next_ids is not None:
                next_ids = np.asarray(next_ids, np.int32)
            if isinstance(index, Index):
                handle = index
                if next_ids is not None:
                    handle.attach_payload(next_ids)
            elif index is not None:
                handle = Index.open(index, payload=next_ids,
                                    cache=knn_lm.cache_policy(),
                                    compaction=knn_lm.compaction_policy())
            else:
                keys = datastore[0]
                handle = Index.build(
                    np.asarray(keys), knn_lm.bmo, jax.random.PRNGKey(7),
                    shards=max(knn_lm.index_shards, 1), payload=next_ids,
                    cache=knn_lm.cache_policy(),
                    compaction=knn_lm.compaction_policy())
            if handle.payload is None:
                # uncovered slots vote token 0 — make that explicit
                handle.attach_payload(np.zeros((handle.capacity,), np.int32))
            self.index = handle
        self.plane_namespace = plane_namespace
        if plane is not None:
            self.plane: Optional[RequestPlane] = plane
        else:
            self.plane = (RequestPlane(self.index, knn_lm.plane)
                          if self.index is not None else None)
        if knn_lm is not None:
            # hidden-state decode (DenseLM exposes return_hidden)
            def _decode(params, cache, tokens):
                logits, new_cache, hidden = model.decode_step(
                    params, cache, tokens, return_hidden=True)
                return logits, new_cache, hidden[:, -1].astype(jnp.float32)

            self.decode_step = jax.jit(_decode, donate_argnums=1)
        else:
            def _decode(params, cache, tokens):
                logits, new_cache = model.decode_step(params, cache, tokens)
                return logits, new_cache, None

            self.decode_step = jax.jit(_decode, donate_argnums=1)
        self.cache = init_cache(model, batch_size, max_seq)

    # -- kNN-LM hook (the paper's technique in the serving path) ------------
    @property
    def stats(self) -> ServeStats:
        """The plane's typed serving counters (``repro.api.ServeStats``,
        schema v2): cache hits/misses, races, near-repeat warm-starts,
        compactions, reshards, replica fan-out, request-plane queue depth /
        shed counts / terminal latency percentiles — plus, behind a sharded
        index, per-shard load telemetry. ``stats.as_dict()`` is the stable
        JSON schema; the pre-PR-4 stringly keys still work through
        ``stats["knn_cache_hits"]``-style access."""
        if self.plane is not None:
            return self.plane.stats
        return self.index.stats if self.index is not None else ServeStats()

    def _knn_logits(self, hidden, rng):
        # blocking submit+drain shim over the plane: the decode loop wants
        # the fully certified answer, external anytime traffic shares the
        # same scheduler (and the same query LRU) via engine.plane. The
        # reserved tenant keeps the decode loop's admission queue private —
        # external backpressure can shed external tickets, never this one.
        res = self.plane.query(np.asarray(hidden, np.float32), rng=rng,
                               tenant="__engine__",
                               namespace=self.plane_namespace)
        ops = float(np.asarray(res.coord_ops).sum())
        V = self.model.cfg.vocab_size
        # distance-weighted vote over retrieved next-tokens
        w = jax.nn.softmax(-jnp.asarray(res.values) / self.knn_lm.temperature,
                           axis=-1)
        toks = jnp.asarray(self.index.payload)[jnp.asarray(res.indices)]
        knn_probs = jnp.zeros((hidden.shape[0], V), jnp.float32)
        knn_probs = knn_probs.at[
            jnp.arange(hidden.shape[0])[:, None], toks].add(w)
        return jnp.log(knn_probs + 1e-9), ops

    def _append_to_index(self, hidden, tok):
        """Fold this step's (hidden, next-token) pairs into the live index.
        The handle does the bookkeeping the engine used to: payload
        alignment through growth/compaction remaps, cache invalidation via
        the epoch fence, and the CompactionPolicy amortizing tombstone
        debt into decode steps."""
        self.index.insert(np.asarray(hidden), payload=np.asarray(tok)[:, 0])
        self.index.maybe_compact()

    def generate(self, prompts: np.ndarray, max_new_tokens: int, rng=None):
        """prompts (B, S0) int32 -> (B, max_new_tokens) int32 greedy tokens.
        With knn_lm enabled, decode logits are interpolated with the BMO-NN
        retrieval distribution."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        B = prompts.shape[0]
        assert B == self.batch_size
        logits, cache = self.prefill_step(self.params, {"tokens": jnp.asarray(prompts)},
                                          self.cache)
        tok = jnp.argmax(logits[:, -1].astype(jnp.float32), -1).astype(jnp.int32)[:, None]
        out = [tok]
        retrieval_ops = 0.0
        for _ in range(max_new_tokens - 1):
            logits, cache, hidden = self.decode_step(self.params, cache, tok)
            mix = jax.nn.log_softmax(logits[:, -1].astype(jnp.float32))
            if self.knn_lm is not None and self.index is not None:
                rng, sub = jax.random.split(rng)
                knn_logits, ops = self._knn_logits(hidden, sub)
                retrieval_ops += float(jnp.sum(ops))
                lam = self.knn_lm.lam
                mix = jnp.logaddexp(
                    jnp.log1p(-lam) + mix,
                    jnp.log(lam) + jax.nn.log_softmax(knn_logits))
            tok = jnp.argmax(mix, -1).astype(jnp.int32)[:, None]
            if (self.knn_lm is not None and self.index is not None
                    and self.index_append):
                self._append_to_index(hidden, tok)
            out.append(tok)
        self.cache = cache
        return np.asarray(jnp.concatenate(out, axis=1)), retrieval_ops
