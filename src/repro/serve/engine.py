"""Batched serving engine: continuous batch of decode slots + the BMO-NN
retrieval hook (kNN-LM-style interpolation, paper technique at serving time).

This is deliberately a *small* engine (slot-based static batching, greedy
sampling): the point is end-to-end runnability of (prefill → decode →
retrieve → interpolate) on the same substrate the dry-run proves out at mesh
scale.

Retrieval goes through a held ``repro.index.IndexStore`` built once at
engine construction (or passed in pre-built/loaded from disk): the corpus
layout, cached rotation, and CI warm-start priors are amortized across every
decode step, and each step's whole batch races in ONE batched launch
(index.batched_race) instead of per-query ``lax.map``. With
``index_append=True`` the engine inserts each step's (hidden, next-token)
pairs back into the index — the datastore grows during decode, true kNN-LM
behaviour.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BMOConfig, ParallelPlan
from repro.serve.steps import init_cache, make_decode_step, make_prefill_step


@dataclasses.dataclass
class KNNLMConfig:
    lam: float = 0.25          # interpolation weight toward the kNN dist
    temperature: float = 1.0
    bmo: BMOConfig = dataclasses.field(default_factory=lambda: BMOConfig(k=8))


class ServeEngine:
    def __init__(self, model, params, plan: ParallelPlan, mesh, *,
                 batch_size: int, max_seq: int,
                 knn_lm: Optional[KNNLMConfig] = None,
                 datastore=None, index=None, index_append: bool = False):
        """``datastore``: (keys (N, d), next_token_ids (N,)) — preprocessed
        into an IndexStore at construction. ``index``: a pre-built/loaded
        IndexStore instead (pass next-token ids per slot via
        ``datastore=(None, ids)``). ``index_append``: insert each decode
        step's (hidden, token) pairs back into the index."""
        self.model = model
        self.params = params
        self.mesh = mesh
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.prefill_step, self.rules = make_prefill_step(model, plan, mesh)
        self.prefill_step = jax.jit(self.prefill_step, donate_argnums=2)
        self.knn_lm = knn_lm
        self.datastore = datastore      # (keys (N, d), next_token_ids (N,))
        self.index = None
        self.index_append = index_append
        self._next_ids = None           # (capacity,) slot-aligned payload
        if knn_lm is not None and (index is not None or datastore is not None):
            from repro.index import build_index
            next_ids = None
            if index is None:
                keys, next_ids = datastore
                index = build_index(jnp.asarray(keys), knn_lm.bmo,
                                    jax.random.PRNGKey(7))
            elif datastore is not None:
                next_ids = datastore[1]
            self.index = index
            self._next_ids = np.zeros((index.capacity,), np.int32)
            if next_ids is not None:
                next_ids = np.asarray(next_ids, np.int32)
                if len(next_ids) > index.capacity:
                    raise ValueError(
                        f"next-token payload ({len(next_ids)}) exceeds index "
                        f"capacity ({index.capacity}) — wrong index for this "
                        "datastore?")
                if len(next_ids) < index.n_live:
                    raise ValueError(
                        f"next-token payload ({len(next_ids)}) does not cover "
                        f"the index's {index.n_live} live slots — uncovered "
                        "slots would silently vote token 0")
                self._next_ids[: len(next_ids)] = next_ids
        if knn_lm is not None:
            # hidden-state decode (DenseLM exposes return_hidden)
            def _decode(params, cache, tokens):
                logits, new_cache, hidden = model.decode_step(
                    params, cache, tokens, return_hidden=True)
                return logits, new_cache, hidden[:, -1].astype(jnp.float32)

            self.decode_step = jax.jit(_decode, donate_argnums=1)
        else:
            def _decode(params, cache, tokens):
                logits, new_cache = model.decode_step(params, cache, tokens)
                return logits, new_cache, None

            self.decode_step = jax.jit(_decode, donate_argnums=1)
        self.cache = init_cache(model, batch_size, max_seq)

    # -- kNN-LM hook (the paper's technique in the serving path) ------------
    def _knn_logits(self, hidden, rng):
        from repro.index import index_knn
        res = index_knn(self.index, hidden, rng)        # one batched race
        V = self.model.cfg.vocab_size
        # distance-weighted vote over retrieved next-tokens
        w = jax.nn.softmax(-jnp.asarray(res.values) / self.knn_lm.temperature, axis=-1)
        toks = jnp.asarray(self._next_ids)[res.indices]   # (B, k)
        knn_probs = jnp.zeros((hidden.shape[0], V), jnp.float32)
        knn_probs = knn_probs.at[jnp.arange(hidden.shape[0])[:, None], toks].add(w)
        return jnp.log(knn_probs + 1e-9), res.coord_ops

    def _append_to_index(self, hidden, tok):
        """Fold this step's (hidden, next-token) pairs into the live index."""
        from repro.index import insert
        self.index, slots = insert(self.index, np.asarray(hidden))
        if self.index.capacity > len(self._next_ids):
            grown = np.zeros((self.index.capacity,), np.int32)
            grown[: len(self._next_ids)] = self._next_ids
            self._next_ids = grown
        self._next_ids[slots] = np.asarray(tok)[:, 0]

    def generate(self, prompts: np.ndarray, max_new_tokens: int, rng=None):
        """prompts (B, S0) int32 -> (B, max_new_tokens) int32 greedy tokens.
        With knn_lm enabled, decode logits are interpolated with the BMO-NN
        retrieval distribution."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        B = prompts.shape[0]
        assert B == self.batch_size
        logits, cache = self.prefill_step(self.params, {"tokens": jnp.asarray(prompts)},
                                          self.cache)
        tok = jnp.argmax(logits[:, -1].astype(jnp.float32), -1).astype(jnp.int32)[:, None]
        out = [tok]
        retrieval_ops = 0.0
        for _ in range(max_new_tokens - 1):
            logits, cache, hidden = self.decode_step(self.params, cache, tok)
            mix = jax.nn.log_softmax(logits[:, -1].astype(jnp.float32))
            if self.knn_lm is not None and self.index is not None:
                rng, sub = jax.random.split(rng)
                knn_logits, ops = self._knn_logits(hidden, sub)
                retrieval_ops += float(jnp.sum(ops))
                lam = self.knn_lm.lam
                mix = jnp.logaddexp(
                    jnp.log1p(-lam) + mix,
                    jnp.log(lam) + jax.nn.log_softmax(knn_logits))
            tok = jnp.argmax(mix, -1).astype(jnp.int32)[:, None]
            if (self.knn_lm is not None and self.index is not None
                    and self.index_append):
                self._append_to_index(hidden, tok)
            out.append(tok)
        self.cache = cache
        return np.asarray(jnp.concatenate(out, axis=1)), retrieval_ops
