"""Batched serving engine: continuous batch of decode slots + the BMO-NN
retrieval hook (kNN-LM-style interpolation, paper technique at serving time).

This is deliberately a *small* engine (slot-based static batching, greedy
sampling): the point is end-to-end runnability of (prefill → decode →
retrieve → interpolate) on the same substrate the dry-run proves out at mesh
scale.

Retrieval goes through a held ``repro.index.IndexStore`` built once at
engine construction (or passed in pre-built/loaded from disk): the corpus
layout, cached rotation, and CI warm-start priors are amortized across every
decode step, and each step's whole batch races in ONE batched launch
(index.batched_race) instead of per-query ``lax.map``. With
``index_append=True`` the engine inserts each step's (hidden, next-token)
pairs back into the index — the datastore grows during decode, true kNN-LM
behaviour.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BMOConfig, ParallelPlan
from repro.core.datasets import next_pow2
from repro.serve.steps import init_cache, make_decode_step, make_prefill_step


@dataclasses.dataclass
class KNNLMConfig:
    lam: float = 0.25          # interpolation weight toward the kNN dist
    temperature: float = 1.0
    bmo: BMOConfig = dataclasses.field(default_factory=lambda: BMOConfig(k=8))
    cache_size: int = 256      # query LRU entries (0 disables)
    compact_threshold: float = 0.5  # auto-compact when tombstones cross this
                                    # (>=1 disables)
    index_shards: int = 0      # >1: build a ShardedIndexStore spanning that
                               # many mesh devices (DESIGN.md §5)
    near_threshold: float = 0.95    # cosine sim above which a cache miss is
                                    # a *near* repeat: its race is CI-warm-
                                    # started from the cached neighbour's
                                    # result (0 disables)
    near_prior_scale: float = 0.25  # variance-prior tightening applied to
                                    # the cached neighbour's top-k arms


class QueryCache:
    """LRU of query-hash → cached top-k (ROADMAP: serving traffic repeats
    queries). Keys are the raw query bytes — only *exact* repeats hit and
    short-circuit the race, which is the safe contract for a δ-PAC result.
    A *near* repeat (cosine similarity to a cached query above a threshold)
    still races, but ``get_near`` hands the caller the cached neighbour's
    result so the race's CI variance priors can be seeded from it
    (ROADMAP: near-repeat warm starts — priors tighten early rounds without
    faking evidence; see ``confidence.empirical_sigma_sq_prior``). Any index
    mutation invalidates the whole cache: slot ids and the live set both
    shift under insert/delete/compact. IndexStores are immutable (every
    mutation builds a new instance), so the engine detects mutation by
    identity at lookup time — external ``engine.index = delete(...)``-style
    updates are caught too, not just the engine's own appends."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self._od: collections.OrderedDict = collections.OrderedDict()
        self._vecs: collections.OrderedDict = collections.OrderedDict()
        self._mat = None       # cached (keys, stacked unit vectors) for
                               # get_near; rebuilt lazily after any mutation

    @staticmethod
    def key(row: np.ndarray) -> bytes:
        return np.ascontiguousarray(row, np.float32).tobytes()

    def get(self, key: bytes):
        hit = self._od.get(key)
        if hit is not None:
            self._od.move_to_end(key)
            self.hits += 1
            return hit
        self.misses += 1
        return None

    def get_near(self, row: np.ndarray, threshold: float):
        """Best cached entry with cosine(row, cached query) ≥ threshold, or
        None. Called only on exact misses, so a match is a genuinely *near*
        (never identical-bytes) neighbour. O(entries·d) numpy scan — the
        cache is small by construction."""
        if not self._vecs or threshold <= 0:
            return None
        norm = float(np.linalg.norm(row))
        if norm == 0.0:
            return None
        if self._mat is None:
            self._mat = (list(self._vecs.keys()),
                         np.stack(list(self._vecs.values())))
        keys, mat = self._mat
        sims = mat @ (np.asarray(row, np.float32) / norm)
        j = int(np.argmax(sims))
        if sims[j] < threshold:
            return None
        return self._od[keys[j]]

    def put(self, key: bytes, value, vec: Optional[np.ndarray] = None) -> None:
        self._od[key] = value
        self._od.move_to_end(key)
        if vec is not None:
            norm = float(np.linalg.norm(vec))
            if norm > 0:
                self._vecs[key] = np.asarray(vec, np.float32) / norm
                self._vecs.move_to_end(key)
                self._mat = None
        while len(self._od) > self.capacity:
            old, _ = self._od.popitem(last=False)
            if self._vecs.pop(old, None) is not None:
                self._mat = None

    def __len__(self) -> int:
        return len(self._od)

    def clear(self) -> None:
        self._od.clear()
        self._vecs.clear()
        self._mat = None


class ServeEngine:
    def __init__(self, model, params, plan: ParallelPlan, mesh, *,
                 batch_size: int, max_seq: int,
                 knn_lm: Optional[KNNLMConfig] = None,
                 datastore=None, index=None, index_append: bool = False):
        """``datastore``: (keys (N, d), next_token_ids (N,)) — preprocessed
        into an IndexStore at construction. ``index``: a pre-built/loaded
        IndexStore instead (pass next-token ids per slot via
        ``datastore=(None, ids)``). ``index_append``: insert each decode
        step's (hidden, token) pairs back into the index."""
        self.model = model
        self.params = params
        self.mesh = mesh
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.prefill_step, self.rules = make_prefill_step(model, plan, mesh)
        self.prefill_step = jax.jit(self.prefill_step, donate_argnums=2)
        self.knn_lm = knn_lm
        self.datastore = datastore      # (keys (N, d), next_token_ids (N,))
        self.index = None
        self.index_append = index_append
        self._next_ids = None           # (capacity,) slot-aligned payload
        self.query_cache = (QueryCache(knn_lm.cache_size)
                            if knn_lm is not None and knn_lm.cache_size > 0
                            else None)
        self._cache_index = None        # IndexStore the cache was filled from
        self._stats = {"knn_races": 0, "knn_raced_queries": 0,
                       "index_compactions": 0, "knn_near_hits": 0}
        self._shard_coord_ops = self._shard_rounds = None
        if knn_lm is not None and (index is not None or datastore is not None):
            from repro.index import build_index, build_sharded_index
            next_ids = build_gids = None
            if index is None:
                keys, next_ids = datastore
                if knn_lm.index_shards > 1:
                    # one index spanning the mesh (DESIGN.md §5): the build
                    # returns the global slot of each corpus row, which is
                    # how the slot-aligned payload stays aligned
                    index, build_gids = build_sharded_index(
                        np.asarray(keys), knn_lm.bmo, jax.random.PRNGKey(7),
                        shards=knn_lm.index_shards)
                else:
                    index = build_index(jnp.asarray(keys), knn_lm.bmo,
                                        jax.random.PRNGKey(7))
            elif datastore is not None:
                next_ids = datastore[1]
            self.index = index
            if hasattr(index, "shards"):
                self._shard_coord_ops = np.zeros(index.n_shards)
                self._shard_rounds = np.zeros(index.n_shards)
            self._next_ids = np.zeros((index.capacity,), np.int32)
            if next_ids is not None:
                next_ids = np.asarray(next_ids, np.int32)
                if len(next_ids) > index.capacity:
                    raise ValueError(
                        f"next-token payload ({len(next_ids)}) exceeds index "
                        f"capacity ({index.capacity}) — wrong index for this "
                        "datastore?")
                if len(next_ids) < index.n_live:
                    raise ValueError(
                        f"next-token payload ({len(next_ids)}) does not cover "
                        f"the index's {index.n_live} live slots — uncovered "
                        "slots would silently vote token 0")
                if build_gids is not None:
                    self._next_ids[build_gids] = next_ids
                elif hasattr(index, "shards") and \
                        len(next_ids) != index.capacity:
                    # a sharded index's live global ids are non-contiguous,
                    # so a shorter prefix CANNOT cover them — uncovered
                    # slots would silently vote token 0
                    raise ValueError(
                        f"a pre-built sharded index needs a capacity-length "
                        f"({index.capacity}) gid-aligned payload, got "
                        f"{len(next_ids)}")
                else:
                    # pre-built/loaded indexes take the payload already
                    # slot-aligned
                    self._next_ids[: len(next_ids)] = next_ids
        if knn_lm is not None:
            # hidden-state decode (DenseLM exposes return_hidden)
            def _decode(params, cache, tokens):
                logits, new_cache, hidden = model.decode_step(
                    params, cache, tokens, return_hidden=True)
                return logits, new_cache, hidden[:, -1].astype(jnp.float32)

            self.decode_step = jax.jit(_decode, donate_argnums=1)
        else:
            def _decode(params, cache, tokens):
                logits, new_cache = model.decode_step(params, cache, tokens)
                return logits, new_cache, None

            self.decode_step = jax.jit(_decode, donate_argnums=1)
        self.cache = init_cache(model, batch_size, max_seq)

    # -- kNN-LM hook (the paper's technique in the serving path) ------------
    @property
    def stats(self) -> dict:
        """Serving counters: query-cache hits/misses, races run, raced
        queries (cache misses that actually paid a race), near-repeat
        warm-starts, compactions — plus, behind a sharded index, cumulative
        per-shard coordinate-ops and max rounds (load-balance telemetry)."""
        out = dict(self._stats)
        if self.query_cache is not None:
            out["knn_cache_hits"] = self.query_cache.hits
            out["knn_cache_misses"] = self.query_cache.misses
            out["knn_cache_entries"] = len(self.query_cache)
        if self._shard_coord_ops is not None:
            out["knn_shard_coord_ops"] = self._shard_coord_ops.tolist()
            out["knn_shard_rounds"] = self._shard_rounds.tolist()
        return out

    def _seeded_priors(self, hid: np.ndarray, miss: list):
        """Near-repeat warm starts (ROADMAP): per-query CI variance priors
        for the missed rows, tightened on the cached neighbour's top-k arms
        wherever a cached query sits within the cosine threshold. Priors
        only shape the variance estimate — CI widths still scale with real
        sample counts — so a wrong near-match slows nothing down and the
        result stays a fresh δ-PAC race."""
        thr = self.knn_lm.near_threshold
        if thr <= 0 or len(self.query_cache) == 0:
            return None
        base = np.asarray(self.index.prior_var, np.float32)
        rows, found = [], False
        for i in miss:
            near = self.query_cache.get_near(hid[i], thr)
            if near is None:
                rows.append(base)
            else:
                seeded = base.copy()
                seeded[near[0]] *= self.knn_lm.near_prior_scale
                rows.append(seeded)
                found = True
                self._stats["knn_near_hits"] += 1
        return np.stack(rows) if found else None

    def _record_race(self, res, n_queries: int):
        self._stats["knn_races"] += 1
        self._stats["knn_raced_queries"] += n_queries
        if self._shard_coord_ops is not None and hasattr(res, "shard_rounds"):
            self._shard_coord_ops += np.asarray(res.shard_coord_ops)
            self._shard_rounds = np.maximum(self._shard_rounds,
                                            np.asarray(res.shard_rounds))

    def _knn_topk(self, hidden, rng):
        """Top-k per row through the query LRU: only cache-missing rows race
        (padded to a power-of-two sub-batch so the jitted executables stay
        warm), hits are served from memory at zero coordinate-ops."""
        from repro.index import index_knn
        B = hidden.shape[0]
        k = self.index.cfg.k
        if self.query_cache is None:    # no cache: race the batch directly
            res = index_knn(self.index, jnp.asarray(hidden), rng)
            self._record_race(res, B)
            return (np.asarray(res.indices), np.asarray(res.values),
                    float(np.asarray(res.coord_ops).sum()))
        hid = np.asarray(hidden, np.float32)
        idx = np.zeros((B, k), np.int32)
        vals = np.zeros((B, k), np.float32)
        if self._cache_index is not self.index:
            self.query_cache.clear()    # index mutated since the cache filled
            self._cache_index = self.index
        miss, keys = [], [QueryCache.key(row) for row in hid]
        for i in range(B):
            got = self.query_cache.get(keys[i])
            if got is None:
                miss.append(i)
            else:
                idx[i], vals[i] = got
        ops = 0.0
        if miss:
            sub = hid[miss]
            prior_hint = self._seeded_priors(hid, miss)
            pad = next_pow2(len(miss)) - len(miss)
            if pad:
                sub = np.concatenate([sub, np.repeat(sub[:1], pad, 0)], 0)
                if prior_hint is not None:
                    prior_hint = np.concatenate(
                        [prior_hint, np.repeat(prior_hint[:1], pad, 0)], 0)
            res = index_knn(self.index, jnp.asarray(sub), rng,
                            prior_hint=prior_hint)
            r_idx = np.asarray(res.indices)
            r_vals = np.asarray(res.values)
            for j, i in enumerate(miss):
                idx[i], vals[i] = r_idx[j], r_vals[j]
                self.query_cache.put(keys[i], (r_idx[j], r_vals[j]),
                                     vec=hid[i])
            ops = float(np.asarray(res.coord_ops)[: len(miss)].sum())
            self._record_race(res, len(miss))
        return idx, vals, ops

    def _knn_logits(self, hidden, rng):
        idx, vals, ops = self._knn_topk(hidden, rng)
        V = self.model.cfg.vocab_size
        # distance-weighted vote over retrieved next-tokens
        w = jax.nn.softmax(-jnp.asarray(vals) / self.knn_lm.temperature, axis=-1)
        toks = jnp.asarray(self._next_ids)[jnp.asarray(idx)]   # (B, k)
        knn_probs = jnp.zeros((hidden.shape[0], V), jnp.float32)
        knn_probs = knn_probs.at[jnp.arange(hidden.shape[0])[:, None], toks].add(w)
        return jnp.log(knn_probs + 1e-9), ops

    def _remap_payload(self, old_ids: np.ndarray) -> None:
        """Reindex the slot-aligned payload through an old→new global-id map
        (the ``compact`` contract — also returned by sharded growth and
        re-shard events)."""
        remapped = np.zeros((len(old_ids),), np.int32)
        live = old_ids >= 0
        remapped[live] = self._next_ids[old_ids[live]]
        self._next_ids = remapped

    def _append_to_index(self, hidden, tok):
        """Fold this step's (hidden, next-token) pairs into the live index;
        mutation shifts the live set, so cached top-k is invalidated, and
        tombstone debt is amortized here (ROADMAP: auto-compaction folded
        into decode steps)."""
        if hasattr(self.index, "shards"):
            from repro.index import sharded_insert, sharded_maybe_compact
            self.index, slots, grow_ids = sharded_insert(
                self.index, np.asarray(hidden))
            if grow_ids is not None:    # stride grew → global ids shifted
                self._remap_payload(grow_ids)
            self._next_ids[slots] = np.asarray(tok)[:, 0]
            self.index, old_ids = sharded_maybe_compact(
                self.index, threshold=self.knn_lm.compact_threshold)
        else:
            from repro.index import insert, maybe_compact
            self.index, slots = insert(self.index, np.asarray(hidden))
            if self.index.capacity > len(self._next_ids):
                grown = np.zeros((self.index.capacity,), np.int32)
                grown[: len(self._next_ids)] = self._next_ids
                self._next_ids = grown
            self._next_ids[slots] = np.asarray(tok)[:, 0]
            self.index, old_ids = maybe_compact(
                self.index, threshold=self.knn_lm.compact_threshold)
        if old_ids is not None:
            self._remap_payload(old_ids)
            self._stats["index_compactions"] += 1
        if self.query_cache is not None:
            self.query_cache.clear()
            self._cache_index = self.index  # release the pre-mutation store

    def generate(self, prompts: np.ndarray, max_new_tokens: int, rng=None):
        """prompts (B, S0) int32 -> (B, max_new_tokens) int32 greedy tokens.
        With knn_lm enabled, decode logits are interpolated with the BMO-NN
        retrieval distribution."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        B = prompts.shape[0]
        assert B == self.batch_size
        logits, cache = self.prefill_step(self.params, {"tokens": jnp.asarray(prompts)},
                                          self.cache)
        tok = jnp.argmax(logits[:, -1].astype(jnp.float32), -1).astype(jnp.int32)[:, None]
        out = [tok]
        retrieval_ops = 0.0
        for _ in range(max_new_tokens - 1):
            logits, cache, hidden = self.decode_step(self.params, cache, tok)
            mix = jax.nn.log_softmax(logits[:, -1].astype(jnp.float32))
            if self.knn_lm is not None and self.index is not None:
                rng, sub = jax.random.split(rng)
                knn_logits, ops = self._knn_logits(hidden, sub)
                retrieval_ops += float(jnp.sum(ops))
                lam = self.knn_lm.lam
                mix = jnp.logaddexp(
                    jnp.log1p(-lam) + mix,
                    jnp.log(lam) + jax.nn.log_softmax(knn_logits))
            tok = jnp.argmax(mix, -1).astype(jnp.int32)[:, None]
            if (self.knn_lm is not None and self.index is not None
                    and self.index_append):
                self._append_to_index(hidden, tok)
            out.append(tok)
        self.cache = cache
        return np.asarray(jnp.concatenate(out, axis=1)), retrieval_ops
