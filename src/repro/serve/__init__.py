from repro.serve.steps import make_decode_step, make_prefill_step, init_cache
from repro.serve.engine import ServeEngine

__all__ = ["make_decode_step", "make_prefill_step", "init_cache", "ServeEngine"]
