from repro.serve.steps import make_decode_step, make_prefill_step, init_cache
from repro.serve.engine import ServeEngine
from repro.serve.plane import PlaneConfig, RequestPlane
from repro.serve.scale import QueueDepthPolicy, ScaleDecision, ScalePolicy

__all__ = ["make_decode_step", "make_prefill_step", "init_cache",
           "ServeEngine", "PlaneConfig", "RequestPlane", "QueueDepthPolicy",
           "ScaleDecision", "ScalePolicy"]
