"""repro.serve.plane — the async request plane over one ``Index`` handle
or a whole namespace fleet (DESIGN.md §7, §11).

``Index.query`` is a blocking, run-to-certification batch call: one hard
query (or one greedy caller) gates everyone sharing the engine. The plane
replaces that surface with admission → deadline-aware micro-batching →
anytime streaming:

  * ``submit(queries, spec) -> Ticket``: admission control. Exact-repeat
    rows are served from the handle's query LRU at submit (zero cost);
    the rest waits in a bounded per-tenant queue — beyond the bound the
    ticket is *shed with a reason* instead of queueing unboundedly.
  * Between scheduler epochs, admitted requests from many tickets are
    coalesced into pow2 race batches (join-at-epoch-boundary) driven
    through ``Index.race`` one epoch at a time; a ticket leaves its group
    the moment it terminates (leave-on-terminal) and its rows are retired
    so the survivors inherit the pull budget.
  * ``poll/stream(ticket) -> AnytimeResult``: the current partial top-k
    with CI radii and the certified-prefix length. A request terminates on
    wall-clock ``Deadline``, ``EffortBudget``, or full certification —
    whichever comes first — always returning the best *certified-prefix*
    answer with an honest uncertainty report.
  * Fairness: admission round-robins across tenants, so one adversarial
    heavy tenant cannot starve the rest of the batch slots.
  * Mutation fence: every group is pinned to the store epoch it started
    against. When a mutation bumps ``Index.epoch`` mid-race, in-flight
    groups either complete against the old (immutable) store or are
    re-admitted against the new one — controlled by
    ``PlaneConfig.on_mutation`` — and a result never mixes epochs.

The scheduler is cooperative (``step()`` runs one epoch across all active
groups); ``drain()``, ``stream()`` and the blocking ``query()`` shim drive
it. ``stats`` extends the handle's ``ServeStats`` with queue/latency
telemetry (schema v2) that ``repro.serve.scale`` policies consume.

Namespace routing (PR 9, DESIGN.md §11): the plane is decoupled from "the
one index". Construct it with ``router=`` (a ``repro.fleet.Fleet``) and
tickets carry a ``namespace`` label: ``submit(..., namespace="users")``
resolves the backing ``Index`` through the router at admission (which
transparently reloads an evicted namespace), the per-tenant fairness /
shed / quota machinery keys on ``(tenant, namespace)``, race groups never
mix namespaces, and per-namespace counters ride the metrics registry under
a ``namespace`` label (``repro_plane_ns_*``). A plane built the classic
way — ``RequestPlane(index)`` — behaves exactly as before.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.api import Index, QuerySpec, ServeStats
from repro.api.cache import QueryCache
from repro.api.stream import (DONE, QUEUED, R_BUDGET, R_CERTIFIED,
                              R_DEADLINE, R_SHED, RACING, SHED,
                              AnytimeResult, Ticket, percentile)
from repro.core.datasets import next_pow2
from repro.obs import get_obs
from repro.utils import get_logger, host_fetch

log = get_logger("repro.serve.plane")

ON_MUTATION = ("complete", "readmit")

#: monotone plane sequence — the ``plane="pN"`` metric label and trace-id
#: prefix that keep multiple planes apart in one shared obs context
_plane_seq = itertools.count()


@dataclasses.dataclass(frozen=True)
class PlaneConfig:
    """Scheduler knobs. Defaults favour small-host serving; the bench
    (`tools/bench_serve_plane.py`) sweeps them under open-loop load."""

    max_queue: int = 64            # pending tickets per tenant before shed
    max_group_queries: int = 64    # query rows coalesced per race batch
    max_active_groups: int = 4     # concurrent race groups
    on_mutation: str = "complete"  # complete | readmit in-flight groups
    chunk_rounds: int = 0          # sparse rounds per epoch (0 = heuristic)
    latency_window: int = 4096     # terminal latencies kept for percentiles
    # -- shadow δ-audit (DESIGN.md §10) -----------------------------------
    audit_rate: float = 0.0        # fraction of terminal tickets audited
    audit_reservoir: int = 256     # pending audits per tenant before drop
    audit_dir: Optional[str] = None   # flight-recorder bundle directory
    audit_seed: int = 0            # sampling RNG seed (reproducible audits)

    def __post_init__(self):
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.max_group_queries < 1:
            raise ValueError("max_group_queries must be >= 1, got "
                             f"{self.max_group_queries}")
        if self.max_active_groups < 1:
            raise ValueError("max_active_groups must be >= 1, got "
                             f"{self.max_active_groups} (0 would make "
                             "blocking queries spin forever unadmitted)")
        if self.on_mutation not in ON_MUTATION:
            raise ValueError(f"unknown on_mutation {self.on_mutation!r} "
                             f"(want one of {ON_MUTATION})")
        if self.latency_window < 1:
            raise ValueError("latency_window must be >= 1, got "
                             f"{self.latency_window}")
        if not 0.0 <= self.audit_rate <= 1.0:
            raise ValueError("audit_rate must be in [0, 1], got "
                             f"{self.audit_rate}")
        if self.audit_reservoir < 1:
            raise ValueError("audit_reservoir must be >= 1, got "
                             f"{self.audit_reservoir}")


class _Member(object):
    """One ticket's miss rows inside a race group."""

    def __init__(self, entry: "_Entry", rows: List[int], offset: int):
        self.entry = entry
        self.rows = rows              # ticket-row indices raced here
        self.offset = offset          # first group row of this member


class _Entry(object):
    """Plane-internal ticket state (the public handle is ``.ticket``)."""

    def __init__(self, ticket: Ticket, queries, rng, spec: QuerySpec,
                 is_sparse: bool, index: Index,
                 namespace: Optional[str] = None):
        self.ticket = ticket
        self.queries = queries
        self.rng = rng
        self.spec = spec
        self.is_sparse = is_sparse
        self.index = index            # the backing handle, resolved at submit
        self.namespace = namespace    # routing label (None = default index)
        Q = ticket.n_queries
        self.cached_rows: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self.cache_epoch = -1         # store epoch the cached rows are from
        # frozen certified prefix per row: once an entry certifies it is
        # never revoked nor reordered (anytime-monotonicity by construction)
        self.cert_ids: List[List[int]] = [[] for _ in range(Q)]
        self.cert_vals: List[List[float]] = [[] for _ in range(Q)]
        self.group: Optional["_Group"] = None
        self.member: Optional[_Member] = None
        self.coord_ops = np.zeros((Q,), np.float64)
        self.rounds = np.zeros((Q,), np.int64)
        self.epoch = 0                # store epoch the result is valid for
        self.queue_span = None        # open plane.queue span (obs tracer)

    @property
    def miss_rows(self) -> List[int]:
        return [i for i in range(self.ticket.n_queries)
                if i not in self.cached_rows]


class _Group(object):
    """One coalesced race batch: a RaceSession plus its member tickets.
    Pinned to ONE backing index (groups never mix namespaces) and the
    store epoch it launched against."""

    def __init__(self, session, members: List[_Member], store_epoch: int,
                 index: Index):
        self.session = session
        self.members = members
        self.store_epoch = store_epoch
        self.index = index


class RequestPlane:
    """The async request plane over one ``repro.api.Index`` handle — or,
    with ``router=`` (a ``repro.fleet.Fleet``), over every namespace the
    router serves, multiplexed through one shared scheduler."""

    def __init__(self, index: Optional[Index] = None,
                 config: Optional[PlaneConfig] = None,
                 *, obs=None, router=None):
        if index is None and router is None:
            raise ValueError("RequestPlane needs an index, a router "
                             "(repro.fleet.Fleet), or both")
        self.index = index
        self.router = router
        if router is not None and hasattr(router, "attach_plane"):
            router.attach_plane(self)   # wires the eviction in-flight guard
        self.config = config if config is not None else PlaneConfig()
        self.obs = obs if obs is not None else get_obs()
        self.plane_id = f"p{next(_plane_seq)}"
        # admission queues keyed by (tenant, namespace): the PR-5 fairness/
        # shed machinery applies unchanged at the pair granularity, so one
        # hot namespace cannot starve a cold one even under a single tenant
        self._queues: "collections.OrderedDict[tuple, collections.deque]" = \
            collections.OrderedDict()
        self._groups: List[_Group] = []
        self._next_id = 0
        self._entries: Dict[int, _Entry] = {}
        self._latencies: collections.deque = collections.deque(
            maxlen=self.config.latency_window)
        # the metrics registry is the single source of truth for the plane
        # counters (DESIGN.md §8.2): ``stats`` and the exporters read the
        # SAME series, so they can never disagree
        reg = self.obs.registry
        lbl = {"plane": self.plane_id}
        self._submitted = reg.counter(
            "repro_plane_submitted_total", "tickets submitted", **lbl)
        self._admitted = reg.counter(
            "repro_plane_admitted_total",
            "tickets admitted into a race group", **lbl)
        self._completed = reg.counter(
            "repro_plane_completed_total",
            "tickets finished (any terminal reason)", **lbl)
        self._shed = reg.counter(
            "repro_plane_shed_total",
            "tickets shed at admission (backpressure)", **lbl)
        self._deadline_exits = reg.counter(
            "repro_plane_deadline_exits_total",
            "tickets terminated at the wall-clock deadline", **lbl)
        self._budget_exits = reg.counter(
            "repro_plane_budget_exits_total",
            "tickets terminated at the effort budget", **lbl)
        self._readmitted = reg.counter(
            "repro_plane_readmitted_total",
            "tickets re-raced after a mutation fence", **lbl)
        self._epochs = reg.counter(
            "repro_plane_epochs_total", "scheduler epochs run", **lbl)
        self._g_queue = reg.gauge(
            "repro_plane_queue_depth", "tickets waiting for admission",
            **lbl)
        self._g_active = reg.gauge(
            "repro_plane_active", "tickets currently racing", **lbl)
        self._h_latency = reg.histogram(
            "repro_plane_latency_ms", "terminal ticket latency (ms)", **lbl)
        self._h_epoch = reg.histogram(
            "repro_plane_epoch_ms", "wall time of one scheduler epoch (ms)",
            **lbl)
        # shadow δ-auditor (DESIGN.md §10): sampling happens at _finish
        # (cheap — one RNG draw + array copies into a bounded reservoir);
        # the brute-force oracle runs OFF the critical path, only from
        # audit_step()/audit_flush() or an idle step()
        self.auditor = None
        if self.config.audit_rate > 0.0 and (index is not None
                                             or router is not None):
            from repro.obs.audit import DeltaAuditor, FlightRecorder
            recorder = (FlightRecorder(self.config.audit_dir)
                        if self.config.audit_dir else None)
            self.auditor = DeltaAuditor(
                index, router=router, rate=self.config.audit_rate,
                obs=self.obs, recorder=recorder,
                seed=self.config.audit_seed,
                reservoir=self.config.audit_reservoir, labels=lbl)

    # -- routing -------------------------------------------------------------

    def _resolve(self, namespace: Optional[str]) -> Index:
        """The backing ``Index`` for a namespace label. ``None`` routes to
        the plane's default index; a label goes through the router, which
        transparently reloads an evicted namespace (lazy open-on-access)
        and bumps its LRU recency."""
        if namespace is None:
            if self.index is None:
                raise ValueError(
                    "this plane routes by namespace (router-only) — "
                    "pass namespace= to submit()")
            return self.index
        if self.router is None:
            raise ValueError(
                f"namespace={namespace!r} submitted to a plane without a "
                "router — construct RequestPlane(router=fleet) to serve "
                "namespaces")
        return self.router.resolve(namespace)

    def _qkey(self, entry: _Entry) -> tuple:
        return (entry.ticket.tenant, entry.namespace)

    def _max_queue(self, namespace: Optional[str]) -> int:
        """Per-namespace admission bound: the router's override when it has
        one, else the plane-wide ``PlaneConfig.max_queue``."""
        if namespace is not None and self.router is not None:
            mq = self.router.namespace_max_queue(namespace)
            if mq is not None:
                return mq
        return self.config.max_queue

    def _ns_metrics(self, namespace: str):
        """Lazily-registered per-namespace series (registry lookups are
        dict gets — repeat calls return the same series)."""
        reg = self.obs.registry
        lbl = {"plane": self.plane_id, "namespace": namespace}
        return (reg.counter("repro_plane_ns_submitted_total",
                            "tickets submitted per namespace", **lbl),
                reg.counter("repro_plane_ns_completed_total",
                            "tickets finished per namespace", **lbl),
                reg.gauge("repro_plane_ns_queue_depth",
                          "tickets waiting for admission per namespace",
                          **lbl))

    def namespace_load(self) -> Dict[str, int]:
        """Live tickets (queued + racing) per namespace — the Fleet's
        eviction guard: a namespace with in-flight work is never evicted
        out from under its tickets."""
        load: Dict[str, int] = {}
        for (_t, ns), q in self._queues.items():
            if ns is not None and q:
                load[ns] = load.get(ns, 0) + len(q)
        for g in self._groups:
            for m in g.members:
                ns = m.entry.namespace
                if ns is not None:
                    load[ns] = load.get(ns, 0) + 1
        return load

    # -- admission -----------------------------------------------------------

    def submit(self, queries, spec: Optional[QuerySpec] = None, *,
               tenant: str = "default", namespace: Optional[str] = None,
               rng=None, **overrides) -> Ticket:
        """Admit a query batch. Returns a ``Ticket`` immediately: poll or
        stream it, or let ``drain()`` run the plane to quiescence. Keyword
        overrides (``deadline=``, ``budget=``, ``k=``, …) refine the spec
        exactly like ``Index.query``. ``namespace`` routes the ticket to a
        fleet namespace (requires a router); admission fairness then keys
        on the ``(tenant, namespace)`` pair."""
        if spec is None:
            spec = QuerySpec(**overrides)
        elif overrides:
            spec = dataclasses.replace(spec, **overrides)
        index = self._resolve(namespace)
        is_sparse = isinstance(queries, tuple)
        # reject unraceable submissions HERE, not at group launch: a bad
        # spec admitted into a coalesced bucket would abort co-admitted
        # tickets' admission mid-step
        kind = index.kind
        if is_sparse != (kind == "sparse"):
            raise ValueError(
                f"a {kind!r} index takes "
                f"{'(q_idx, q_val, q_nnz) triplet' if kind == 'sparse' else 'dense (Q, d) array'} "
                "queries")
        if spec.mode == "fused" and kind == "sparse":
            raise ValueError("the fused epoch driver pulls corpus blocks — "
                             "sparse boxes race on the per-round driver")
        if spec.mode == "rounds" and kind != "sparse":
            raise ValueError(
                "anytime sessions drive dense/rotated boxes through the "
                "epoch-fused driver; mode='rounds' is blocking-query only")
        if spec.bind(index.cfg).k > index.n_live:
            raise ValueError(
                f"k={spec.bind(index.cfg).k} exceeds the index's "
                f"{index.n_live} live slots")
        if is_sparse:
            queries = tuple(np.asarray(a) for a in queries)
            Q = queries[0].shape[0]
        else:
            queries = np.asarray(queries, np.float32)
            Q = queries.shape[0]
        now = time.monotonic()
        ticket = Ticket(id=self._next_id, tenant=tenant, n_queries=Q,
                        spec=spec, submitted_at=now,
                        trace_id=f"{self.plane_id}.t{self._next_id}")
        self._next_id += 1
        self._submitted.inc()
        nsattr = {} if namespace is None else {"namespace": namespace}
        if namespace is not None:
            self._ns_metrics(namespace)[0].inc()
        tracer = self.obs.tracer
        tracer.instant("plane.submit", trace=ticket.trace_id,
                       tenant=tenant, n_queries=Q, **nsattr)
        entry = _Entry(ticket, queries, rng, spec, is_sparse, index,
                       namespace)
        self._entries[ticket.id] = entry

        q = self._queues.setdefault(self._qkey(entry), collections.deque())
        entry.epoch = index.epoch
        self._consult_cache(entry)
        if not entry.miss_rows:          # fully served from the query LRU —
            self._finish(entry, R_CERTIFIED)   # free, never needs a slot
            return ticket
        if len(q) >= self._max_queue(namespace):
            self._shed.inc()
            ticket.status = SHED
            ticket.reason = "queue_full"
            ticket.finished_at = now
            ticket.result = self._empty_result(entry, R_SHED)
            self._entries.pop(ticket.id, None)
            tracer.instant("plane.shed", trace=ticket.trace_id,
                           reason="queue_full", tenant=tenant, **nsattr)
            return ticket
        entry.queue_span = tracer.start("plane.queue",
                                        trace=ticket.trace_id, tenant=tenant,
                                        **nsattr)
        q.append(entry)
        return ticket

    def _consult_cache(self, entry: _Entry) -> None:
        """Serve exact-repeat rows from the handle's LRU at submit time
        (same contract as ``Index.query``; the shared cache keeps both
        surfaces coherent — and namespace-keyed, so two namespaces holding
        identical query bytes can never exchange rows). Near-repeat CI
        priors are seeded later, at group launch — a ticket shed by
        backpressure must not pay them."""
        index = entry.index
        cache = index._cache
        spec = entry.spec
        entry.cache_epoch = index.epoch
        if (cache is None or entry.is_sparse or not spec.cacheable
                or spec.cache == "bypass"):
            return
        hid = entry.queries
        for i in range(entry.ticket.n_queries):
            got = (None if spec.cache == "refresh"
                   else cache.get(QueryCache.key(hid[i], index._cache_ns)))
            if got is not None:
                entry.cached_rows[i] = (np.asarray(got[0]).copy(),
                                        np.asarray(got[1]).copy())

    # -- scheduling ----------------------------------------------------------

    def _race_key(self, entry: _Entry):
        # id(entry.index) pins coalescing to one backing handle: race
        # groups must never mix namespaces (or an index pre/post a fleet
        # reload) — every entry holds a live ref, so ids are stable here
        s = entry.spec
        return (s.k, s.mode, s.impl, s.delta, s.max_rounds, s.eliminate,
                s.warm_start, entry.is_sparse, entry.namespace,
                id(entry.index))

    def _admission_key(self, entry: _Entry):
        """Deadline-aware admission order: earliest absolute deadline
        first, unbounded traffic after, FIFO within a class."""
        dl = entry.spec.deadline
        expiry = (entry.ticket.submitted_at + dl.ms / 1e3 if dl is not None
                  else float("inf"))
        return (expiry, entry.ticket.submitted_at)

    def _pop_ready(self, entry: _Entry, now: float) -> bool:
        """Post-pop admission checks: expire a late ticket, re-consult
        stale cached rows (a mutation moved the epoch — a single result
        never mixes store epochs). True iff the entry still needs a race."""
        if self._expire_if_late(entry, now):
            return False
        if entry.cache_epoch != entry.index.epoch:
            entry.cached_rows.clear()
            self._consult_cache(entry)
            if not entry.miss_rows:
                entry.epoch = entry.index.epoch
                self._finish(entry, R_CERTIFIED)
                return False
        return True

    def _pick_deadline_overflow(self, now: float) -> List[_Entry]:
        """EDF scan of the WHOLE queues (not just heads — a deadline
        ticket may sit behind its own tenant's unbounded one) for the
        overflow slot's batch."""
        cands = sorted(
            ((self._admission_key(e), key, e)
             for key, q in self._queues.items() for e in q
             if e.spec.deadline is not None),
            key=lambda c: c[0])
        picked, rows = [], 0
        for _, qkey, entry in cands:
            if picked and (rows + len(entry.miss_rows)
                           > self.config.max_group_queries):
                continue
            self._queues[qkey].remove(entry)
            if not self._pop_ready(entry, now):
                continue
            picked.append(entry)
            rows += len(entry.miss_rows)
            if rows >= self.config.max_group_queries:
                break
        return picked

    def _admit_groups(self, now: float) -> None:
        """Join-at-epoch-boundary: pop pending tickets across
        (tenant, namespace) queues — at most one per queue per round
        (fairness against a heavy tenant OR a hot namespace),
        earliest-deadline-first within each round (deadline-aware
        micro-batching) — bucket them by race compatibility (which pins a
        bucket to one namespace's index), and launch each bucket as one
        pow2-coalesced race group."""
        budget = (self.config.max_active_groups - len(self._groups))
        if budget <= 0:
            # all group slots busy with long races: deadline-bounded
            # arrivals still get ONE overflow slot (never more — a huge
            # deadline is indistinguishable from run-to-certification, so
            # the overflow must stay bounded) — their groups usually retire
            # within a pass or two, while parking them behind long races
            # would burn their entire wall budget in the queue
            if (len(self._groups) <= self.config.max_active_groups
                    and any(e.spec.deadline is not None
                            for q in self._queues.values() for e in q)):
                picked = self._pick_deadline_overflow(now)
                budget = 1
            else:
                return
        else:
            picked = []
            rows = 0
            while rows < self.config.max_group_queries:
                progressed = False
                heads = sorted(
                    (key for key, q in self._queues.items() if q),
                    key=lambda key: self._admission_key(
                        self._queues[key][0]))
                for qkey in heads:
                    q = self._queues[qkey]
                    if not q:
                        continue
                    entry = q[0]
                    need = len(entry.miss_rows)
                    if picked and rows + need > self.config.max_group_queries:
                        continue
                    q.popleft()
                    progressed = True
                    if not self._pop_ready(entry, now):
                        continue
                    picked.append(entry)
                    rows += len(entry.miss_rows)
                    if rows >= self.config.max_group_queries:
                        break
                if not progressed:
                    break
        buckets: "collections.OrderedDict[tuple, List[_Entry]]" = \
            collections.OrderedDict()
        for entry in picked:
            buckets.setdefault(self._race_key(entry), []).append(entry)
        leftover: List[_Entry] = []
        for bucket in buckets.values():
            if budget <= 0:              # out of group slots this pass
                leftover.extend(bucket)
                continue
            self._launch_group(bucket, now)
            budget -= 1
        # requeue unlaunched entries in ORIGINAL pick order (front of their
        # tenant queues) so FIFO/EDF-within-class admission order survives
        for entry in reversed([e for e in picked if e in leftover]):
            self._queues.setdefault(
                self._qkey(entry), collections.deque()).appendleft(entry)

    def _launch_group(self, entries: List[_Entry], now: float) -> None:
        index = entries[0].index      # bucket key pins one index per group
        members: List[_Member] = []
        parts, hints, offset = [], [], 0
        for entry in entries:
            rows = entry.miss_rows
            members.append(_Member(entry, rows, offset))
            if entry.is_sparse:
                parts.append(tuple(a[rows] for a in entry.queries))
            else:
                parts.append(entry.queries[rows])
            # near-repeat warm starts: seeded per miss row from the LRU's
            # cosine neighbours (the Index.query contract), paid only for
            # tickets that actually race
            hint = None
            if (not entry.is_sparse and entry.spec.cacheable
                    and entry.spec.cache != "bypass"):
                hint = index._seeded_priors(entry.queries, rows)
            hints.append(hint)
            offset += len(rows)
        is_sparse = entries[0].is_sparse
        batch = (_concat_sparse(parts) if is_sparse
                 else np.concatenate(parts, axis=0))
        prior_hint = None
        if any(h is not None for h in hints):
            base = np.asarray(host_fetch(index.store.prior_var),
                              np.float32)
            priors = []
            for member, hint in zip(members, hints):
                priors.extend([base] * len(member.rows) if hint is None
                              else list(hint))
            prior_hint = np.stack(priors)
        pad = next_pow2(offset) - offset
        if pad:
            if is_sparse:
                batch = tuple(np.concatenate(
                    [a, np.repeat(a[:1], pad, 0)], 0) for a in batch)
            else:
                batch = np.concatenate(
                    [batch, np.repeat(batch[:1], pad, 0)], 0)
            if prior_hint is not None:
                prior_hint = np.concatenate(
                    [prior_hint, np.repeat(prior_hint[:1], pad, 0)], 0)
        spec = dataclasses.replace(entries[0].spec, prior_hint=prior_hint,
                                   deadline=None, budget=None)
        rng = next((e.rng for e in entries if e.rng is not None), None)
        # deadline-aware fused-round selection (DESIGN.md §9.7): hand the
        # session the group's tightest remaining wall budget — with a
        # tuned per-round cost on file it sizes each epoch's fused R to
        # rounds the budget can still pay instead of overshooting the
        # deadline inside one oversized launch.
        deadline_ms = None
        for entry in entries:
            dl = entry.spec.deadline
            if dl is None:
                continue
            left = (entry.ticket.submitted_at + dl.ms / 1e3 - now) * 1e3
            deadline_ms = left if deadline_ms is None \
                else min(deadline_ms, left)
        if deadline_ms is not None:
            deadline_ms = max(deadline_ms, 0.0)
        try:
            session = index.race(batch, rng, spec=spec,
                                 raced_queries=offset,
                                 chunk_rounds=self.config.chunk_rounds,
                                 obs=self.obs, deadline_ms=deadline_ms)
        except Exception as e:  # noqa: BLE001 — never orphan the bucket
            log.bind(plane=self.plane_id,
                     traces=",".join(e_.ticket.trace_id or ""
                                     for e_ in entries)).warning(
                "race launch rejected (%s): shedding %d ticket(s)",
                e, len(entries))
            for entry in entries:
                self._shed.inc()
                t = entry.ticket
                t.status = SHED
                t.reason = f"rejected: {e}"
                t.finished_at = time.monotonic()
                t.result = self._empty_result(entry, R_SHED)
                self._entries.pop(t.id, None)
                if entry.queue_span is not None:
                    entry.queue_span.end(outcome="shed")
                    entry.queue_span = None
                self.obs.tracer.instant("plane.shed", trace=t.trace_id,
                                        reason=t.reason)
            return
        if pad:
            # pow2 pad rows belong to no ticket: retire them immediately so
            # they neither race nor dilute the adaptive pull reallocation
            session.retire(np.arange(session.Q) >= offset)
        group = _Group(session, members, index.epoch, index)
        for member in members:
            entry = member.entry
            entry.group = group
            entry.member = member
            entry.epoch = group.store_epoch
            t = entry.ticket
            t.status = RACING
            if t.admitted_at is None:
                t.admitted_at = now
                self._admitted.inc()
            if entry.queue_span is not None:
                entry.queue_span.end(session=session.sid)
                entry.queue_span = None
            # the admit instant is the ticket ↔ session JOIN KEY: the
            # session's race.epoch spans record under session.sid
            nsattr = ({} if entry.namespace is None
                      else {"namespace": entry.namespace})
            self.obs.tracer.instant(
                "plane.admit", trace=t.trace_id, session=session.sid,
                rows=len(member.rows), store_epoch=group.store_epoch,
                **nsattr)
        self._groups.append(group)

    def _fence_groups(self) -> None:
        """Mutation fence: a group whose store epoch fell behind (per the
        group's OWN index — namespaces fence independently) either
        completes against its (immutable) old store or is re-admitted."""
        if self.config.on_mutation != "readmit":
            return
        for group in [g for g in self._groups
                      if g.store_epoch != g.index.epoch]:
            epoch = group.index.epoch
            self._groups.remove(group)
            # the epochs already paid against the old store are real load —
            # keep them in the cumulative per-shard telemetry
            group.index._record_session_telemetry(group.session)
            for member in group.members:
                entry = member.entry
                if entry.ticket.terminal:
                    continue
                # discard partial state computed against the dead epoch —
                # certified prefixes must never mix store epochs
                for i in member.rows:
                    entry.cert_ids[i] = []
                    entry.cert_vals[i] = []
                entry.cached_rows.clear()
                entry.group = entry.member = None
                entry.ticket.status = QUEUED
                self._readmitted.inc()
                self.obs.tracer.instant(
                    "plane.readmit", trace=entry.ticket.trace_id,
                    from_epoch=group.store_epoch, to_epoch=epoch)
                self._consult_cache(entry)
                if not entry.miss_rows:
                    entry.epoch = epoch
                    self._finish(entry, R_CERTIFIED)
                    continue
                entry.queue_span = self.obs.tracer.start(
                    "plane.queue", trace=entry.ticket.trace_id,
                    tenant=entry.ticket.tenant, readmit=True)
                self._queues.setdefault(
                    self._qkey(entry),
                    collections.deque()).appendleft(entry)

    def _harvest(self, group: _Group, *, count_epoch: bool) -> None:
        """Finish every member whose terminal condition holds against the
        group's current snapshot, retiring their rows so survivors inherit
        the pull budget. Called before AND after each group epoch — the
        pre-step pass lets a deadline expire at the boundary the ticket is
        already standing on instead of paying one more epoch."""
        now = time.monotonic()
        snap = group.session.snapshot
        retire_rows = []
        for member in list(group.members):
            entry = member.entry
            if count_epoch:
                entry.ticket.epochs += 1
                self._ingest(entry, member, snap, group.store_epoch)
                self._trace_ticket_epoch(entry, member, group, snap)
            reason = self._terminal_reason(entry, member, snap, now)
            if reason is not None:
                self._finish(entry, reason)
                group.members.remove(member)
                if reason != R_CERTIFIED:
                    retire_rows.extend(
                        range(member.offset,
                              member.offset + len(member.rows)))
        if retire_rows:
            mask = np.zeros((group.session.Q,), bool)
            mask[retire_rows] = True
            group.session.retire(mask)
        if not group.members:
            group.index._record_session_telemetry(group.session)
            self._groups.remove(group)

    def _trace_ticket_epoch(self, entry: _Entry, member: _Member,
                            group: _Group, snap) -> None:
        """Per-ticket race-epoch event: the ticket's own worst uncertified
        CI (its member rows only) plus the session's epoch telemetry —
        joinable with the ``race.epoch`` span via ``session``."""
        tracer = self.obs.tracer
        if not tracer.enabled:
            return
        rows = snap.ci[member.offset:member.offset + len(member.rows)]
        # host-sync: snap is the session's post-boundary numpy view
        worst = float(np.where(np.isfinite(rows), rows,
                               0.0).max(initial=0.0))
        cert = sum(len(ids) for ids in entry.cert_ids)
        info = group.session.last_epoch or {}
        attrs = {k: info[k] for k in
                 ("coord_ops", "rounds", "width", "n_surv", "R",
                  "shard_coord_ops", "shard_rounds") if k in info}
        tracer.instant("ticket.epoch", trace=entry.ticket.trace_id,
                       session=group.session.sid,
                       epoch=entry.ticket.epochs, worst_ci=worst,
                       certified=cert, store_epoch=group.store_epoch,
                       **attrs)

    def step(self) -> int:
        """One scheduler epoch: fence, admit, advance every active group by
        one epoch, harvest terminals. Returns tickets still in flight."""
        t0 = time.perf_counter()
        now = time.monotonic()
        self._fence_groups()
        self._admit_groups(now)
        # a TRUE idle pass: the epoch began with nothing racing and nothing
        # queued — only such passes may do shadow-audit work below, so the
        # step that *finishes* the last ticket (drain's final iteration)
        # never pays the oracle either
        idle_pass = not self._groups and not self._queues
        if self._groups:
            self._epochs.inc()
        for group in list(self._groups):
            self._harvest(group, count_epoch=False)   # pre-step expiries
            if group not in self._groups:
                continue
            group.session.step()
            self._harvest(group, count_epoch=True)
        # expire queued tickets whose deadline passed while waiting
        now = time.monotonic()
        for q in self._queues.values():
            for entry in [e for e in q if self._deadline_passed(e, now)]:
                q.remove(entry)
                entry.epoch = entry.index.epoch
                self._finish(entry, R_DEADLINE)
        # drop drained queues: distinct (tenant, namespace) pairs must not
        # grow the admission scan (or stats) without bound on a long plane
        for key in [key for key, q in self._queues.items() if not q]:
            del self._queues[key]
        if self._groups or self.active:
            self._h_epoch.observe((time.perf_counter() - t0) * 1e3)
        self._g_queue.set(sum(len(q) for q in self._queues.values()))
        self._g_active.set(sum(len(g.members) for g in self._groups))
        for ns, depth in self.ns_queue_depth().items():
            self._ns_metrics(ns)[2].set(depth)
        # shadow audits use IDLE steps only: with races active or tickets
        # queued the oracle never runs inside the serving epoch — audit
        # work is demonstrably off the critical path (DESIGN.md §10.2)
        if (self.auditor is not None and idle_pass
                and not self._groups and not self._queues):
            self.auditor.process(1)
        return self.active

    def drain(self, max_epochs: int = 100000) -> None:
        """Run the scheduler until every submitted ticket is terminal."""
        while self.active:
            self.step()
            max_epochs -= 1
            if max_epochs <= 0:
                raise RuntimeError("RequestPlane.drain did not quiesce")

    @property
    def active(self) -> int:
        queued = sum(len(q) for q in self._queues.values())
        racing = sum(len(g.members) for g in self._groups)
        return queued + racing

    # -- termination & result assembly --------------------------------------

    def _deadline_passed(self, entry: _Entry, now: float) -> bool:
        dl = entry.spec.deadline
        return (dl is not None
                and now >= entry.ticket.submitted_at + dl.ms / 1e3)

    def _expire_if_late(self, entry: _Entry, now: float) -> bool:
        if self._deadline_passed(entry, now):
            entry.epoch = entry.index.epoch
            self._finish(entry, R_DEADLINE)
            return True
        return False

    def _terminal_reason(self, entry: _Entry, member: _Member, snap,
                         now: float) -> Optional[str]:
        done = snap.done
        if all(done[member.offset + j] for j in range(len(member.rows))):
            return R_CERTIFIED
        if entry.group is not None and entry.group.session.exhausted:
            return R_BUDGET
        if self._deadline_passed(entry, now):
            return R_DEADLINE
        budget = entry.spec.budget
        if budget is not None:
            if (budget.epochs is not None
                    and entry.ticket.epochs >= budget.epochs):
                return R_BUDGET
            if (budget.coord_ops is not None  # host-sync: numpy ledger
                    and float(entry.coord_ops.max()) >= budget.coord_ops):
                return R_BUDGET
        return None

    def _ingest(self, entry: _Entry, member: _Member, snap,
                store_epoch: int) -> None:
        """Fold a group snapshot into the ticket: extend each row's frozen
        certified prefix (never revoked, never reordered) and refresh the
        cost counters."""
        entry.epoch = store_epoch
        for j, i in enumerate(member.rows):
            g = member.offset + j
            entry.coord_ops[i] = snap.coord_ops[g]
            entry.rounds[i] = snap.rounds[g]
            k = snap.ids.shape[1]
            acc = int(snap.acc_count[g])
            bar = float(snap.cand_lcb_min[g])  # host-sync: numpy snap
            frozen_ids = entry.cert_ids[i]
            frozen_vals = entry.cert_vals[i]
            for p in range(len(frozen_ids), acc):
                v = float(snap.values[g, p])  # host-sync: numpy snap
                if not (v < bar) or len(frozen_ids) >= k:
                    break
                gid = int(snap.ids[g, p])
                if gid in frozen_ids:      # δ-failure guard: never duplicate
                    continue
                frozen_ids.append(gid)
                frozen_vals.append(v)

    def _row_result(self, entry: _Entry, i: int, k: int, snap=None,
                    g: Optional[int] = None):
        """(ids, vals, ci, certified) for ticket row i: cached rows are a
        full certified prefix; raced rows are frozen-prefix + best-effort
        tail from the latest snapshot."""
        if i in entry.cached_rows:
            ids, vals = entry.cached_rows[i]
            # host-sync: cache holds host lists
            return (np.asarray(ids, np.int64),
                    np.asarray(vals, np.float32),
                    np.zeros((k,), np.float32), k)
        ids = list(entry.cert_ids[i])
        vals = list(entry.cert_vals[i])
        ci = [0.0] * len(ids)
        cc = len(ids)
        if snap is not None and g is not None:
            for p in range(snap.ids.shape[1]):
                if len(ids) >= k:
                    break
                gid = int(snap.ids[g, p])
                v = float(snap.values[g, p])  # host-sync: numpy snap
                if gid in entry.cert_ids[i] or not np.isfinite(v):
                    continue
                ids.append(gid)
                vals.append(v)
                ci.append(float(snap.ci[g, p]))  # host-sync: numpy snap
        while len(ids) < k:
            ids.append(-1)
            vals.append(np.inf)
            ci.append(np.inf)
        # host-sync: assembling host lists into the result arrays
        return (np.asarray(ids, np.int64), np.asarray(vals, np.float32),
                np.asarray(ci, np.float32), cc)

    def _build_result(self, entry: _Entry, terminal: bool,
                      reason: str) -> AnytimeResult:
        k = entry.spec.bind(entry.index.cfg).k
        Q = entry.ticket.n_queries
        ids = np.full((Q, k), -1, np.int64)
        vals = np.full((Q, k), np.inf, np.float32)
        ci = np.full((Q, k), np.inf, np.float32)
        cc = np.zeros((Q,), np.int32)
        member, snap = entry.member, None
        row_of_group = {}
        if member is not None and entry.group is not None:
            snap = entry.group.session.snapshot
            row_of_group = {i: member.offset + j
                            for j, i in enumerate(member.rows)}
        for i in range(Q):
            g = row_of_group.get(i)
            ids[i], vals[i], ci[i], cc[i] = self._row_result(
                entry, i, k, snap if g is not None else None, g)
        return AnytimeResult(
            indices=ids, values=vals, ci_radii=ci, certified_count=cc,
            epoch=entry.epoch, terminal=terminal, reason=reason,
            coord_ops=entry.coord_ops.copy(), rounds=entry.rounds.copy(),
            epochs=entry.ticket.epochs)

    def _empty_result(self, entry: _Entry, reason: str) -> AnytimeResult:
        return self._build_result(entry, True, reason)

    def _finish(self, entry: _Entry, reason: str) -> None:
        t = entry.ticket
        t.status = DONE if reason != R_SHED else SHED
        t.reason = reason
        t.finished_at = time.monotonic()
        t.result = self._build_result(entry, True, reason)
        self._completed.inc()
        if reason == R_DEADLINE:
            self._deadline_exits.inc()
        elif reason == R_BUDGET:
            self._budget_exits.inc()
        self._latencies.append(t.latency_ms)
        self._h_latency.observe(t.latency_ms)
        if entry.namespace is not None:
            self._ns_metrics(entry.namespace)[1].inc()
        self._fill_cache(entry, reason)
        self._offer_audit(entry, reason)
        entry.group = entry.member = None
        if entry.queue_span is not None:     # e.g. deadline expired queued
            entry.queue_span.end(outcome=reason)
            entry.queue_span = None
        nsattr = ({} if entry.namespace is None
                  else {"namespace": entry.namespace})
        self.obs.tracer.instant(
            "plane.shed" if reason == R_SHED else "plane.terminal",
            trace=t.trace_id, reason=reason, latency_ms=t.latency_ms,
            epochs=t.epochs, store_epoch=entry.epoch, **nsattr)
        self._entries.pop(t.id, None)

    def _offer_audit(self, entry: _Entry, reason: str) -> None:
        """Maybe sample this terminal ticket into the shadow-audit
        reservoir. Only FULLY-certified answers claim the complete 1-δ
        contract — partial deadline/budget/shed exits are counted as
        skipped, not audited against a promise they never made."""
        if self.auditor is None:
            return
        if entry.namespace is not None and self.auditor.router is None:
            # namespaced ticket but the auditor has no router to resolve
            # its ground truth through — counted as skipped, not missed
            self.auditor.note_skip("namespaced")
            return
        t = entry.ticket
        res = t.result
        if (reason != R_CERTIFIED
                or int(np.min(res.certified_count)) < res.indices.shape[1]):
            self.auditor.note_skip("uncertified")
            return
        cfg = entry.index._query_cfg(entry.spec)
        self.auditor.offer(
            trace_id=t.trace_id, tenant=t.tenant, store_epoch=entry.epoch,
            contract=("tuned" if entry.index._serving_tuned(entry.spec)
                      else "default"),
            k=res.indices.shape[1], delta=float(cfg.delta),
            queries=entry.queries, served_ids=res.indices,
            served_vals=res.values, spec=entry.spec,
            namespace=entry.namespace)

    def audit_step(self, max_items: int = 1) -> int:
        """Run the δ-audit oracle on up to ``max_items`` pending samples.
        Call between serving work — never inside it; ``step()`` only does
        this on an idle pass (no group racing, nothing queued)."""
        return (self.auditor.process(max_items)
                if self.auditor is not None else 0)

    def audit_flush(self) -> int:
        """Drain the whole audit reservoir through the oracle (benches,
        shutdown, tests). Returns the number of items processed."""
        return self.auditor.flush() if self.auditor is not None else 0

    def _fill_cache(self, entry: _Entry, reason: str) -> None:
        """Fully-certified default-contract answers populate the LRU —
        partial (deadline/budget) results never do, and neither does a
        result certified against a superseded store epoch (an
        ``on_mutation='complete'`` group finishing after a mutation must
        not poison the new epoch's cache with, e.g., a deleted id)."""
        index = entry.index
        cache = index._cache
        if (cache is None or reason != R_CERTIFIED or entry.is_sparse
                or not entry.spec.cacheable or entry.spec.cache == "bypass"
                or entry.epoch != index.epoch):
            return
        res = entry.ticket.result
        for i in entry.miss_rows:
            if int(res.certified_count[i]) < res.indices.shape[1]:
                continue
            row = entry.queries[i]
            cache.put(QueryCache.key(row, index._cache_ns),
                      (res.indices[i].copy(), res.values[i].copy()),
                      vec=row, namespace=index._cache_ns)

    # -- consumption ---------------------------------------------------------

    def poll(self, ticket: Ticket) -> AnytimeResult:
        """Non-advancing read of the ticket's current anytime answer."""
        if ticket.result is not None and ticket.terminal:
            return ticket.result
        entry = self._entries[ticket.id]
        reason = "queued" if ticket.status == QUEUED else "partial"
        return self._build_result(entry, False, reason)

    def stream(self, ticket: Ticket) -> Iterator[AnytimeResult]:
        """Drive the scheduler and yield the ticket's refined answer after
        every scheduler epoch, ending with the terminal result."""
        if ticket.terminal:
            yield ticket.result
            return
        while not ticket.terminal:
            self.step()
            yield self.poll(ticket)

    def query(self, queries, rng=None, spec: Optional[QuerySpec] = None,
              *, tenant: str = "default", namespace: Optional[str] = None,
              **overrides) -> AnytimeResult:
        """Blocking shim: submit + drain — what ``ServeEngine`` calls for
        its per-decode-step retrieval (under its own reserved tenant, so
        external load can never shed the decode loop). Same cache/counter
        semantics as the pre-plane ``Index.query`` hot path."""
        ticket = self.submit(queries, spec, tenant=tenant,
                             namespace=namespace, rng=rng, **overrides)
        while not ticket.terminal:
            self.step()
        if ticket.status == SHED:
            raise RuntimeError(
                f"blocking query shed by the request plane "
                f"({ticket.reason}) — the admission queue is full")
        return ticket.result

    # -- telemetry -----------------------------------------------------------

    def ns_queue_depth(self) -> Dict[str, int]:
        """Waiting tickets per namespace (queued only — the live pressure
        signal ``serve.scale`` fleet policies and eviction consume)."""
        depth: Dict[str, int] = {}
        for (_t, ns), q in self._queues.items():
            if ns is not None and q:
                depth[ns] = depth.get(ns, 0) + len(q)
        return depth

    @property
    def stats(self) -> ServeStats:
        """The handle's ``ServeStats`` extended with the plane's queue,
        latency and observability telemetry (schema v3) and — behind a
        router — the fleet's per-namespace rollup (schema v6). The counters
        come straight off the obs metrics registry — the same series the
        Prometheus/JSON exporters emit — so the two views never diverge.
        Percentiles are exact over the bounded ``latency_window`` and 0.0
        (never None/NaN) while the window is empty. A router-only plane
        starts from an empty ``ServeStats`` (there is no single handle
        whose cache/race counters could stand for the whole fleet)."""
        st = self.index.stats if self.index is not None else ServeStats()
        lat = list(self._latencies)
        queue_depth = sum(len(q) for q in self._queues.values())
        active = sum(len(g.members) for g in self._groups)
        self._g_queue.set(queue_depth)
        self._g_active.set(active)
        p50 = percentile(lat, 50)
        p95 = percentile(lat, 95)
        p99 = percentile(lat, 99)
        return dataclasses.replace(
            st,
            plane_submitted=int(self._submitted.value),
            plane_admitted=int(self._admitted.value),
            plane_completed=int(self._completed.value),
            plane_shed=int(self._shed.value),
            plane_deadline_exits=int(self._deadline_exits.value),
            plane_budget_exits=int(self._budget_exits.value),
            plane_readmitted=int(self._readmitted.value),
            plane_epochs=int(self._epochs.value),
            plane_queue_depth=queue_depth,
            plane_active=active,
            plane_latency_p50_ms=0.0 if p50 is None else float(p50),
            plane_latency_p95_ms=0.0 if p95 is None else float(p95),
            plane_latency_p99_ms=0.0 if p99 is None else float(p99),
            obs_events=self.obs.events.total,
            obs_event_drops=self.obs.events.drops,
            obs_epoch_ms=self._h_epoch.snapshot(),
            obs_latency_ms=self._h_latency.snapshot(),
            audit_sampled=(self.auditor.sampled_rows
                           if self.auditor is not None else 0),
            audit_mismatches=(self.auditor.mismatch_rows
                              if self.auditor is not None else 0),
            audit_err_upper=(self.auditor.err_upper()
                             if self.auditor is not None else 1.0),
            audit_pending=(self.auditor.pending
                           if self.auditor is not None else 0),
            slo_alerts=int(sum(
                m.value for m in self.obs.registry.collect()
                if m.name == "repro_slo_alerts_total")),
            serving_fallback=(self.index.serving_fallback
                              if self.index is not None else False),
            retune_requested=(self.index.retune_requested
                              if self.index is not None else False),
            fleet_namespaces_resident=(self.router.resident_count
                                       if self.router is not None else 0),
            fleet_namespaces_evicted=(self.router.evicted_count
                                      if self.router is not None else 0),
            fleet_reloads=(self.router.reload_count
                           if self.router is not None else 0),
            ns_queue_depth=(self.ns_queue_depth()
                            if self.router is not None else None),
        )


def _concat_sparse(parts: List[tuple]) -> tuple:
    """Concatenate (q_idx, q_val, q_nnz) padded-CSR triplets along the
    query axis, widening every part to the max pad width (fill: d-like
    sentinel column index 0-value, nnz untouched — pulls are nnz-bounded)."""
    m = max(p[0].shape[1] for p in parts)

    def widen(a, fill):
        pad = m - a.shape[1]
        if pad == 0:
            return a
        return np.concatenate(
            [a, np.full((a.shape[0], pad), fill, a.dtype)], axis=1)

    q_idx = np.concatenate([widen(p[0], 0) for p in parts], axis=0)
    q_val = np.concatenate([widen(p[1], 0) for p in parts], axis=0)
    q_nnz = np.concatenate([p[2] for p in parts], axis=0)
    return q_idx, q_val, q_nnz
