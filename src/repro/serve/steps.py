"""Serving steps: prefill + single-token decode with KV/SSM caches, wired
for the production mesh (cache sharded batch×heads)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelPlan
from repro.sharding.context import activation_sharding
from repro.sharding.spec import Rules, init_params, make_rules, param_pspecs


def init_cache(model, batch_size: int, max_seq: int, rng=None,
               dtype=jnp.bfloat16):
    specs = model.cache_specs(batch_size, max_seq, dtype)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    return init_params(specs, rng)


def cache_pspecs(model, batch_size: int, max_seq: int, rules: Rules,
                 dtype=jnp.bfloat16):
    specs = model.cache_specs(batch_size, max_seq, dtype)
    return param_pspecs(specs, rules)


def make_prefill_step(model, plan: ParallelPlan, mesh: Mesh, *,
                      rules: Optional[Rules] = None, multi_pod: bool = False):
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    rules = rules or make_rules(fsdp=plan.fsdp, tp=plan.tp, sp=plan.sp,
                                ep=plan.ep, multi_pod=multi_pod,
                                axis_sizes=axis_sizes,
                                kv_len_shard=plan.kv_len_shard)
    compute_dtype = jnp.bfloat16
    dp_spec = rules.mesh_axes("batch")

    def prefill_step(params, batch, cache):
        kw = {}
        if model.cfg.family == "moe":
            kw = dict(mesh=mesh, ep=plan.ep, dp_spec=dp_spec)
        with activation_sharding(rules, mesh):
            logits, new_cache = model.prefill(params, batch, cache,
                                              compute_dtype=compute_dtype, **kw)
        return logits[:, -1:], new_cache

    return prefill_step, rules


def make_decode_step(model, plan: ParallelPlan, mesh: Mesh, *,
                     rules: Optional[Rules] = None, multi_pod: bool = False,
                     sample: str = "greedy"):
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    rules = rules or make_rules(fsdp=plan.fsdp, tp=plan.tp, sp=plan.sp,
                                ep=plan.ep, multi_pod=multi_pod,
                                axis_sizes=axis_sizes,
                                kv_len_shard=plan.kv_len_shard)
    compute_dtype = jnp.bfloat16
    dp_spec = rules.mesh_axes("batch")

    def decode_step(params, cache, tokens):
        kw = {}
        if model.cfg.family == "moe":
            kw = dict(mesh=mesh, ep=plan.ep, dp_spec=dp_spec)
        with activation_sharding(rules, mesh):
            logits, new_cache = model.decode_step(params, cache, tokens,
                                                  compute_dtype=compute_dtype, **kw)
        if sample == "greedy":
            next_tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        else:
            next_tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return next_tok.astype(jnp.int32)[:, None], logits, new_cache

    return decode_step, rules
