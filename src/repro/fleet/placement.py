"""Namespace → mesh placement (DESIGN.md §11.2).

The mesh is one shared resource; every sharded namespace occupies a
contiguous device window ``[offset, offset + shards)`` (the
``ShardedIndexStore.device_offset`` contract from the PR-4 replica
fan-out). This module bin-packs namespaces onto that mesh by live-row
footprint — the same greedy least-loaded logic ``index/placement.py``
applies to rows-within-shards, lifted to namespaces-within-devices:
heaviest namespace first, each placed at the window whose max per-device
load stays lowest (ties → lowest offset, so placement is deterministic and
the manifest round-trips it).

``reshard`` (``Index.reshard`` / ``repro.api.admin.live_reshard``) is the
rebalance primitive when a window change alone cannot fix the imbalance —
the Fleet re-plans offsets cheaply on every eviction/reload and leaves the
expensive shard-count changes to an explicit ``Fleet.reshard`` call.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def plan_placement(footprints: Dict[str, Tuple[int, int]],
                   n_devices: int) -> Dict[str, int]:
    """Greedy contiguous-window bin-packing of namespaces onto devices.

    ``footprints``: namespace → ``(n_shards, live_rows)``. Returns
    namespace → device offset. Deterministic: namespaces sorted by
    (-live_rows, name), windows scanned low-to-high, ties toward the
    lowest offset — the same plan reproduces from the same manifest.

    A namespace whose shard count exceeds the mesh is pinned at offset 0
    (the store itself raises at launch if the devices truly aren't there —
    placement must not hide that error by refusing to plan).
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    load = np.zeros((n_devices,), np.float64)
    plan: Dict[str, int] = {}
    order = sorted(footprints, key=lambda ns: (-footprints[ns][1], ns))
    for ns in order:
        shards, rows = footprints[ns]
        shards = max(1, int(shards))
        if shards >= n_devices:
            off = 0
            span = n_devices
        else:
            # the window whose heaviest device stays lightest after adding
            # this namespace's per-device share
            share = rows / shards
            costs = [load[o:o + shards].max() + share
                     for o in range(n_devices - shards + 1)]
            off = int(np.argmin(costs))
            span = shards
        plan[ns] = off
        load[off:off + span] += rows / span
    return plan


def device_load(footprints: Dict[str, Tuple[int, int]],
                plan: Dict[str, int], n_devices: int) -> np.ndarray:
    """(n_devices,) live rows per device under ``plan`` — the balance
    telemetry benches and ``health_snapshot`` surface."""
    load = np.zeros((n_devices,), np.float64)
    for ns, off in plan.items():
        shards, rows = footprints[ns]
        span = min(max(1, int(shards)), n_devices)
        load[off:off + span] += rows / span
    return load
