"""``Fleet`` — many named namespaces, one mesh, one request plane
(DESIGN.md §11.1).

A namespace is one ``repro.api.Index`` (single-shard or mesh-spanning)
plus its durable state under ``<root>/ns/<name>/`` (checkpoint, payload,
tuned sidecar). The fleet owns the routing table, an LRU residency set
(at most ``max_resident`` namespaces materialized; the rest live as
checkpoints and reload transparently on next touch), the shared
namespace-keyed ``QueryCache``, and the placement plan that bin-packs
sharded namespaces onto the device mesh.

Serving goes through ONE shared ``RequestPlane``: construct it with
``fleet.serve()`` (or ``RequestPlane(router=fleet)``) and submit tickets
with a ``namespace=`` label — admission fairness, per-namespace
``max_queue`` quota and shed all ride the existing per-tenant machinery
at ``(tenant, namespace)`` granularity, and the plane's ``namespace_load``
guard keeps the fleet from evicting a namespace with in-flight tickets.

Durability contract: ``create`` checkpoints the namespace eagerly and
every eviction re-checkpoints iff the epoch moved since the last save
(both through the crash-safe staged-directory publish), the manifest
(``fleet.json``) is rewritten atomically after every membership/placement
change, and ``Fleet.open(root)`` recovers the whole fleet — namespaces,
placements, tuned sidecars, payloads — without materializing any index.
"""
from __future__ import annotations

import dataclasses
import os
import re
import shutil
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.api import Index
from repro.api.cache import QueryCache
from repro.fleet.manifest import load_manifest, save_manifest
from repro.fleet.placement import plan_placement
from repro.utils import get_logger

log = get_logger("repro.fleet")

#: filesystem- and metric-label-safe namespace names (no NUL — the cache
#: key prefix relies on that — no separators, no dot-prefixed traversal)
_NAME_RE = re.compile(r"^[A-Za-z0-9_][A-Za-z0-9_.-]{0,127}$")

NS_SUBDIR = "ns"


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Fleet-level knobs (per-namespace overrides ride ``create``)."""

    max_resident: int = 8          # namespaces materialized at once
    cache_capacity: int = 1024     # shared namespace-keyed query LRU
    default_max_queue: Optional[int] = None  # per-namespace admission bound
                                   # (None = the plane's own max_queue)

    def __post_init__(self):
        if self.max_resident < 1:
            raise ValueError(
                f"max_resident must be >= 1, got {self.max_resident}")
        if self.cache_capacity < 0:
            raise ValueError(
                f"cache_capacity must be >= 0, got {self.cache_capacity}")


class _NsState(object):
    """Routing-table row: the (maybe materialized) index + its metadata."""

    def __init__(self, name: str, meta: dict,
                 index: Optional[Index] = None):
        self.name = name
        self.meta = meta          # shards/device_offset/max_queue/n_live/kind
        self.index = index        # None while evicted (checkpoint on disk)
        self.last_used = 0        # fleet touch counter (LRU recency)
        self.saved_epoch = -1     # index epoch at the last checkpoint


class Fleet:
    """The namespace fleet handle. See the module docstring; construct
    with ``Fleet(root)`` (fresh or adopt an existing root) or
    ``Fleet.open(root)`` (strict: the manifest must exist)."""

    def __init__(self, root: str, config: Optional[FleetConfig] = None):
        self.root = root
        self.config = config if config is not None else FleetConfig()
        os.makedirs(os.path.join(root, NS_SUBDIR), exist_ok=True)
        self._ns: Dict[str, _NsState] = {}
        self._cache = (QueryCache(self.config.cache_capacity)
                       if self.config.cache_capacity > 0 else None)
        self._clock = 0           # monotone touch counter
        self._reloads = 0
        self._evictions = 0
        self.plane = None         # attached by RequestPlane(router=self)
        doc = load_manifest(root)
        if doc is not None:
            for name, rec in doc["namespaces"].items():
                self._ns[name] = _NsState(name, dict(rec))

    # -- constructors --------------------------------------------------------

    @classmethod
    def open(cls, root: str,
             config: Optional[FleetConfig] = None) -> "Fleet":
        """Recover a fleet from its root. Strict: a missing/invalid
        manifest raises instead of silently starting an empty fleet over
        data it cannot see. Namespaces materialize lazily on first touch."""
        if load_manifest(root) is None:
            raise FileNotFoundError(
                f"no fleet manifest at {root!r} — is this a fleet root?")
        return cls(root, config)

    # -- plumbing ------------------------------------------------------------

    def _dir(self, name: str) -> str:
        return os.path.join(self.root, NS_SUBDIR, name)

    def _check_name(self, name: str) -> None:
        if not _NAME_RE.match(name):
            raise ValueError(
                f"bad namespace name {name!r} (want {_NAME_RE.pattern})")

    def _state(self, name: str) -> _NsState:
        st = self._ns.get(name)
        if st is None:
            raise KeyError(f"unknown namespace {name!r} "
                           f"(have {sorted(self._ns)})")
        return st

    def _touch(self, st: _NsState) -> None:
        self._clock += 1
        st.last_used = self._clock

    def _adopt(self, st: _NsState, index: Index) -> None:
        """Wire a materialized index into the fleet: the SHARED namespace-
        keyed query cache replaces the handle's private one, so exact/near
        repeats stay warm across evict/reload while two namespaces can
        never exchange rows (the cache key carries the namespace)."""
        index._cache = self._cache
        index._cache_ns = st.name
        st.index = index
        self._touch(st)

    def _manifest_records(self) -> dict:
        recs = {}
        for name, st in self._ns.items():
            meta = dict(st.meta)
            if st.index is not None:
                meta["n_live"] = int(st.index.n_live)
                meta["shards"] = int(st.index.n_shards)
                meta["kind"] = st.index.kind
            recs[name] = meta
        return recs

    def _save_manifest(self) -> None:
        save_manifest(self.root, self._manifest_records())

    def _checkpoint(self, st: _NsState) -> bool:
        """Persist a resident namespace iff its epoch moved since the last
        save (a clean namespace's checkpoint is already on disk — eviction
        is then free). Crash-safe via the staged-directory publish."""
        if st.index is None:
            return False
        if st.saved_epoch == st.index.epoch:
            return False
        st.index.save(self._dir(st.name))
        st.saved_epoch = st.index.epoch
        st.meta["n_live"] = int(st.index.n_live)
        return True

    # -- lifecycle -----------------------------------------------------------

    def create(self, name: str, corpus, cfg, rng=None, *, shards: int = 1,
               payload=None, max_queue: Optional[int] = None,
               **build_kw) -> Index:
        """Build + register + eagerly checkpoint a namespace. Build kwargs
        (``placement=``, ``capacity=``, ``impl=``, …) pass through to
        ``Index.build``. ``max_queue`` bounds THIS namespace's admission
        queue on the shared plane (None = fleet/plane default)."""
        self._check_name(name)
        if name in self._ns:
            raise ValueError(f"namespace {name!r} already exists — "
                             "drop() it first")
        if self._cache is not None:
            # defensive: a crashed drop may have left stale cached rows
            self._cache.evict_namespace(name)
        index = Index.build(corpus, cfg, rng, shards=shards,
                            payload=payload, **build_kw)
        st = _NsState(name, {
            "shards": int(index.n_shards),
            "device_offset": 0,
            "max_queue": (max_queue if max_queue is not None
                          else self.config.default_max_queue),
            "n_live": int(index.n_live),
            "kind": index.kind,
        })
        self._adopt(st, index)
        self._ns[name] = st
        self._checkpoint(st)       # durable from birth: open() can see it
        self._save_manifest()
        self._maybe_evict(exclude=name)
        return index

    def get(self, name: str) -> Index:
        """The namespace's ``Index``, materializing it from its checkpoint
        if it was evicted (lazy open-on-access) and bumping LRU recency."""
        return self.resolve(name)

    def resolve(self, name: str) -> Index:
        """Router hook for ``RequestPlane``: same contract as ``get``."""
        st = self._state(name)
        if st.index is None:
            self._reload(st)
        else:
            self._touch(st)
        return st.index

    def peek(self, name: str) -> Optional[Index]:
        """The index IF resident, else None — never triggers a reload and
        never bumps recency (telemetry/tests)."""
        return self._state(name).index

    def drop(self, name: str) -> None:
        """Remove a namespace: routing entry, checkpoint directory, and its
        slice of the shared query cache (a later namespace reusing the name
        must start cold — the cache-poisoning regression in tests)."""
        st = self._state(name)
        if self.plane is not None and self.plane.namespace_load().get(name):
            raise RuntimeError(
                f"namespace {name!r} has in-flight tickets — drain before "
                "drop()")
        del self._ns[name]
        st.index = None
        if self._cache is not None:
            self._cache.evict_namespace(name)
        shutil.rmtree(self._dir(name), ignore_errors=True)
        self._save_manifest()

    # -- residency / eviction ------------------------------------------------

    @property
    def namespaces(self) -> List[str]:
        return sorted(self._ns)

    @property
    def resident(self) -> List[str]:
        return sorted(n for n, s in self._ns.items() if s.index is not None)

    @property
    def resident_count(self) -> int:
        return sum(1 for s in self._ns.values() if s.index is not None)

    @property
    def evicted_count(self) -> int:
        return len(self._ns) - self.resident_count

    @property
    def reload_count(self) -> int:
        return self._reloads

    @property
    def eviction_count(self) -> int:
        return self._evictions

    def namespace_max_queue(self, name: str) -> Optional[int]:
        """Per-namespace admission bound for the shared plane (router
        hook); None defers to the plane's own ``max_queue``."""
        st = self._ns.get(name)
        return None if st is None else st.meta.get("max_queue")

    def evict(self, name: str) -> bool:
        """Checkpoint + free one namespace. Refuses (returns False) when
        it is already cold or has in-flight tickets on the attached plane —
        eviction must be invisible to callers, so it only takes quiesced
        namespaces. The shared cache KEEPS the namespace's entries: the
        reload restores a bit-identical store, so they stay valid (drop()
        is the path that purges them)."""
        st = self._state(name)
        if st.index is None:
            return False
        if self.plane is not None and self.plane.namespace_load().get(name):
            return False
        self._checkpoint(st)
        st.index = None
        self._evictions += 1
        self._save_manifest()
        log.info("evicted namespace %r (resident=%d/%d)", name,
                 self.resident_count, self.config.max_resident)
        return True

    def _maybe_evict(self, exclude: Optional[str] = None) -> int:
        """LRU-evict until at most ``max_resident`` namespaces are
        materialized. Busy namespaces are skipped (never evicted out from
        under their tickets); ``exclude`` protects the namespace that
        triggered the scan (it is the most recently touched by
        definition)."""
        evicted = 0
        while self.resident_count > self.config.max_resident:
            cands = sorted(
                (s for s in self._ns.values()
                 if s.index is not None and s.name != exclude),
                key=lambda s: s.last_used)
            progressed = False
            for st in cands:
                if self.evict(st.name):
                    evicted += 1
                    progressed = True
                    break
            if not progressed:      # everything live is busy or excluded
                break
        return evicted

    def enforce_residency(self) -> int:
        """Re-run the LRU eviction scan and return how many namespaces it
        freed. The plane materializes a namespace at ``submit`` and the
        guard never takes one with in-flight tickets, so a burst of cold
        traffic can transiently push the resident set past ``max_resident``
        until those tickets drain — serve loops call this between steps to
        pull the set back to budget as soon as namespaces quiesce."""
        return self._maybe_evict()

    def _reload(self, st: _NsState) -> None:
        """Materialize an evicted namespace from its checkpoint (payload +
        tuned sidecar restore ride ``Index.load``), re-apply its planned
        device offset, and rejoin the residency set (possibly evicting the
        coldest other namespace to stay within ``max_resident``)."""
        index = Index.load(self._dir(st.name))
        off = int(st.meta.get("device_offset", 0))
        if off and index.sharded:
            # fresh handle — placement binds before any launch, no fence
            # repro-lint: allow[epoch-fence]
            index._store = dataclasses.replace(index._store,
                                               device_offset=off)
        self._adopt(st, index)
        st.saved_epoch = index.epoch
        self._reloads += 1
        log.info("reloaded namespace %r (n_live=%d)", st.name, index.n_live)
        self._maybe_evict(exclude=st.name)

    # -- placement -----------------------------------------------------------

    def footprints(self) -> Dict[str, tuple]:
        """namespace → (n_shards, live_rows), from the live index when
        resident, else the manifest record."""
        out = {}
        for name, st in self._ns.items():
            if st.index is not None:
                out[name] = (st.index.n_shards, int(st.index.n_live))
            else:
                out[name] = (int(st.meta.get("shards", 1)),
                             int(st.meta.get("n_live", 0)))
        return out

    def rebalance(self, n_devices: Optional[int] = None) -> Dict[str, int]:
        """Re-plan namespace placement by live-row footprint and apply it:
        resident sharded namespaces whose device window moved are swapped
        onto the new offset through the epoch fence; cold namespaces pick
        their new offset up at reload. Returns the plan. Shard-count
        changes are the caller's lever (``Fleet.reshard``) — this only
        moves windows."""
        n_devices = n_devices or jax.device_count()
        plan = plan_placement(self.footprints(), n_devices)
        for name, off in plan.items():
            st = self._ns[name]
            if st.meta.get("device_offset", 0) == off:
                continue
            st.meta["device_offset"] = off
            if st.index is not None and st.index.sharded:
                st.index._swap(dataclasses.replace(st.index.store,
                                                   device_offset=off))
        self._save_manifest()
        return plan

    def reshard(self, name: str, n_shards: int) -> np.ndarray:
        """Change one namespace's shard count (the expensive rebalance
        primitive — ``repro.api.admin.live_reshard`` under the hood)."""
        st = self._state(name)
        old_ids = self.resolve(name).reshard(n_shards)
        st.meta["shards"] = int(st.index.n_shards)
        self._save_manifest()
        return old_ids

    # -- serving / persistence ----------------------------------------------

    def serve(self, config=None, *, obs=None, default: Optional[str] = None):
        """One shared ``RequestPlane`` over every namespace (tickets carry
        ``namespace=``); also attached as the fleet's eviction guard.

        ``default=`` binds that namespace's live handle as the plane's
        default index: un-namespaced submits route to it, and the plane's
        δ-auditor (``PlaneConfig.audit_rate``) audits its traffic — other
        namespaces stay outside the auditor's contract (``note_skip``).
        The binding is by handle identity, so if the default namespace is
        ever evicted and reloaded the auditor stops sampling (gracefully —
        racing stays correct) until a new plane is built."""
        from repro.serve.plane import RequestPlane
        index = self.get(default) if default is not None else None
        return RequestPlane(index, config=config, obs=obs, router=self)

    def attach_plane(self, plane) -> None:
        """Called by ``RequestPlane(router=self)`` — wires the in-flight
        guard ``plane.namespace_load`` into eviction decisions."""
        self.plane = plane

    def flush(self) -> int:
        """Checkpoint every dirty resident namespace + the manifest
        (shutdown/suspend path). Returns namespaces written."""
        wrote = sum(1 for st in self._ns.values() if self._checkpoint(st))
        self._save_manifest()
        return wrote

    def stats(self) -> dict:
        """Fleet-level rollup (the ``health_snapshot`` fleet section)."""
        return {
            "namespaces": len(self._ns),
            "resident": self.resident_count,
            "evicted": self.evicted_count,
            "reloads": self._reloads,
            "evictions": self._evictions,
            "max_resident": self.config.max_resident,
            "cache_entries": (len(self._cache)
                              if self._cache is not None else 0),
            "ns_queue_depth": (self.plane.ns_queue_depth()
                               if self.plane is not None else {}),
        }

    def __contains__(self, name: str) -> bool:
        return name in self._ns

    def __len__(self) -> int:
        return len(self._ns)

    def __repr__(self) -> str:
        return (f"Fleet(root={self.root!r}, namespaces={len(self._ns)}, "
                f"resident={self.resident_count}/"
                f"{self.config.max_resident})")
