"""Fleet manifest: one versioned JSON sidecar at the fleet root
(DESIGN.md §11.3).

``fleet.json`` is the recovery record for a whole namespace fleet: every
namespace's name, shard count, placement (device offset), admission
override and live-row footprint — enough for ``Fleet.open(root)`` to
rebuild the routing table WITHOUT materializing a single index (lazy
open-on-access; the per-namespace checkpoints, tuned sidecars and payloads
live in the namespace directories and load on first touch).

Writes are atomic (tmp + ``os.replace``, the ``tune/sidecar.py`` idiom) so
a crash mid-update leaves the previous manifest readable. Fallback is
strict: a missing, unreadable, or version-bumped manifest means "no fleet
here" — ``Fleet.open`` fails loudly instead of serving half a fleet.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from repro.utils import get_logger

log = get_logger("repro.fleet")

FLEET_FILE = "fleet.json"
FLEET_VERSION = 1


def save_manifest(root: str, namespaces: dict) -> str:
    """Atomically publish the fleet manifest under ``root``.

    ``namespaces``: name → record dict (``shards``, ``device_offset``,
    ``max_queue``, ``n_live``, ``kind``). The record is advisory metadata
    for placement/routing — the namespace checkpoint stays the source of
    truth for the index itself.
    """
    doc = {"version": FLEET_VERSION, "namespaces": namespaces}
    fpath = os.path.join(root, FLEET_FILE)
    tmp = fpath + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    os.replace(tmp, fpath)
    return fpath


def load_manifest(root: str) -> Optional[dict]:
    """Read + validate ``root``'s manifest; None when there is no (valid)
    fleet at ``root``."""
    fpath = os.path.join(root, FLEET_FILE)
    if not os.path.exists(fpath):
        return None
    try:
        with open(fpath) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        log.warning("unreadable fleet manifest at %s", fpath)
        return None
    if doc.get("version") != FLEET_VERSION:
        log.warning("fleet manifest version %r != %d at %s",
                    doc.get("version"), FLEET_VERSION, fpath)
        return None
    if not isinstance(doc.get("namespaces"), dict):
        log.warning("malformed fleet manifest at %s", fpath)
        return None
    return doc
