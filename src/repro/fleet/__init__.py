"""repro.fleet — multi-tenant namespace fleet over one mesh and one
request plane (DESIGN.md §11).

Thousands of per-tenant/per-collection indexes, multiplexed: ``Fleet``
owns named namespaces (each a ``repro.api.Index``), an LRU residency set
with transparent evict-to-checkpoint / reload-on-touch, a shared
namespace-keyed query cache, mesh placement by live-row footprint, and a
versioned atomic manifest so ``Fleet.open(root)`` recovers everything
across restarts. Serving rides ONE shared ``RequestPlane`` via
``fleet.serve()`` with ``namespace=``-labeled tickets.
"""
from repro.fleet.core import Fleet, FleetConfig
from repro.fleet.manifest import (FLEET_FILE, FLEET_VERSION, load_manifest,
                                  save_manifest)
from repro.fleet.placement import device_load, plan_placement

__all__ = [
    "FLEET_FILE",
    "FLEET_VERSION",
    "Fleet",
    "FleetConfig",
    "device_load",
    "load_manifest",
    "plan_placement",
    "save_manifest",
]
