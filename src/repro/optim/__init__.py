from repro.optim.optimizers import adafactor, adamw, make_optimizer
from repro.optim.schedules import warmup_cosine

__all__ = ["adamw", "adafactor", "make_optimizer", "warmup_cosine"]
