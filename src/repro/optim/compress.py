"""int8 error-feedback gradient compression for data-parallel all-reduce.

Distributed-optimization trick for bandwidth-bound DP: each shard quantizes
(grad + error_carry) to int8 with a per-tensor scale, psums the int8 payload
in int32 (exact), dequantizes, and carries the quantization residual to the
next step (error feedback keeps the scheme unbiased over time; Karimireddy
et al. 2019). Wire format is 1 byte/grad element instead of 4/2 → ~4× less
DP all-reduce traffic.

Used through the shard_map training path (``train.steps.make_train_step``
with ``compress_grads=True``); convergence equivalence is covered by tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(g: jax.Array):
    amax = jnp.max(jnp.abs(g))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(grads, axis_name: str, error):
    """grads/error: pytrees of fp32. Returns (mean-reduced grads, new_error).

    Each leaf: q = int8(g + e); all-reduce q (int32 accum) and the fp32
    scales; dequantized mean = Σ_s q_s·scale_s / S; e' = (g + e) − q·scale.
    """
    S = jax.lax.psum(1, axis_name)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        # common scale across shards (one scalar pmax) so int payloads sum
        amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        # the big collective moves int16 (2B/elem vs 4B fp32); sum of ≤256
        # int8 shards fits int16 exactly
        total = jax.lax.psum(q.astype(jnp.int16), axis_name)
        mean = total.astype(jnp.float32) * scale / S
        new_e = g - q.astype(jnp.float32) * scale
        return mean, new_e

    out = jax.tree_util.tree_map(one, grads, error)
    mean = jax.tree_util.tree_map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return mean, new_e


def init_error(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * factor).astype(g.dtype), grads), norm
