"""Optimizers (pure JAX, no external deps): AdamW and Adafactor.

AdamW keeps fp32 m/v per parameter (3× param memory at fp32 params).
Adafactor (Shazeer & Stern 2018) keeps *factored* second moments — row + col
accumulators for matrices — so optimizer state is ~0 extra bytes/param; the
≥100B assigned archs use it (see per-arch plans in DESIGN.md). β1=0 (no first
moment) by default, update clipping by RMS.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable    # params -> opt_state
    update: Callable  # (grads, opt_state, params, step, lr) -> (new_params, new_state)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params)}

    def update(grads, state, params, step, lr):
        step = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** step
        c2 = 1.0 - b2 ** step

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh, vh = m / c1, v / c2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree_util.tree_map(lambda t: t[0], flat,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[2], flat,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def adafactor(eps: float = 1e-30, clip_rms: float = 1.0,
              decay_pow: float = 0.8, weight_decay: float = 0.0) -> Optimizer:
    """Factored second-moment optimizer; state per matrix = row + col vecs."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def one(p):
            if _factored(p):
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree_util.tree_map(one, params)

    def update(grads, state, params, step, lr):
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-decay_pow)

        def one(s, g, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                r = beta2 * s["r"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                c = beta2 * s["c"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                rc = jnp.mean(r, axis=-1, keepdims=True)
                v = (r / jnp.maximum(rc, eps))[..., None] * c[..., None, :]
                new_s = {"r": r, "c": c}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                new_s = {"v": v}
            u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_rms)
            pf = p.astype(jnp.float32)
            if weight_decay:
                u = u + weight_decay * pf
            return (pf - lr * u).astype(p.dtype), new_s

        # state goes first: its {"r","c"}/{"v"} dicts are the is_leaf boundary
        flat = jax.tree_util.tree_map(
            one, state, grads, params,
            is_leaf=lambda x: isinstance(x, dict) and set(x) <= {"r", "c", "v"})
        new_p = jax.tree_util.tree_map(lambda t: t[0], flat,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_s = jax.tree_util.tree_map(lambda t: t[1], flat,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_p, new_s

    return Optimizer(init, update)


def make_optimizer(name: str, train_cfg=None) -> Optimizer:
    wd = getattr(train_cfg, "weight_decay", 0.1) if train_cfg else 0.1
    b1 = getattr(train_cfg, "b1", 0.9) if train_cfg else 0.9
    b2 = getattr(train_cfg, "b2", 0.95) if train_cfg else 0.95
    if name == "adamw":
        return adamw(b1=b1, b2=b2, weight_decay=wd)
    if name == "adafactor":
        return adafactor(weight_decay=0.0)
    if name == "sgd":
        def init(params):
            return {}

        def update(grads, state, params, step, lr):
            new_p = jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_p, state

        return Optimizer(init, update)
    raise ValueError(name)
