"""First-class admin operations on a LIVE index handle (DESIGN.md §6.3).

The ROADMAP's top open item: the checkpoint path already re-shards (save at
S → load at S′, PR 3), but a *running* serving index had to pay a full
save/load cycle. ``live_reshard`` does it in memory:

  1. **quiesce** — the handle's admin fence rejects mutations for the
     duration of the swap (``Index._admin_op``),
  2. **remap** — the live rows are redistributed over S′ shards with the
     same deterministic uniform-stride remap the checkpoint path uses
     (``index/sharded.reshard`` — round-robin in ascending old-global-id
     order), so the result is BIT-IDENTICAL to save→load-at-S′, with no
     checkpoint written; the attached payload and build-row map ride the
     returned old→new global-id map,
  3. **swap under the epoch fence** — ``Index._swap`` installs the new
     store, bumps ``epoch``, clears the ``QueryCache`` (global ids moved)
     and drops materialized replicas (they re-derive lazily).

``add_replicas`` is the read-throughput twin: the same store is materialized
on r disjoint device slices (``ShardedIndexStore.device_offset``; a
single-shard store is ``device_put`` per replica device) and ``Index.query``
round-robins batches across them. Replicas are derived state — every
mutation/reshard invalidates and lazily rebuilds them from the primary.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.index.sharded import ShardedIndexStore, reshard as _reshard
from repro.utils import get_logger

log = get_logger("repro.api")


def live_reshard(handle, n_shards: int) -> np.ndarray:
    """Elastically re-shard a live handle to ``n_shards`` without a
    save/load cycle. Returns the old→new global-id map (compact contract)
    for any external side state; the attached payload is already remapped."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n_shards > len(jax.devices()):
        # fail the admin op BEFORE touching the handle: an S′-shard store
        # could not build its mesh, so swapping it in would turn every
        # subsequent query into an outage — the whole point of the live op
        # is that the old store keeps serving until the swap is viable
        raise RuntimeError(
            f"cannot live-reshard to {n_shards} shards: only "
            f"{len(jax.devices())} devices are visible — the handle keeps "
            "serving at the current shard count (on CPU, relaunch under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_shards})")
    with handle._admin_op("reshard"):
        store = handle._store
        if not hasattr(store, "shards"):
            # a single-shard IndexStore is the S=1 degenerate sharded store;
            # wrapping it reuses the one deterministic remap everywhere
            store = ShardedIndexStore([store])
        old_s = store.n_shards
        new_store, old_ids = _reshard(store, n_shards)
        handle._remap(old_ids)
        handle._swap(new_store)
        handle._reshards += 1
        log.info("live reshard: S=%d -> S=%d (epoch %d, %d live rows, "
                 "no checkpoint)", old_s, n_shards, handle.epoch,
                 new_store.n_live)
    return old_ids


def add_replicas(handle, n_replicas: int) -> int:
    """Set the handle's read fan-out. Replica placement is lazy (first query
    after the call or after any mutation); ``materialize_replicas`` below
    does the actual device work."""
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    handle._n_replicas = n_replicas
    handle._replica_stores = None
    log.info("read fan-out set to %d replica(s)", n_replicas)
    return n_replicas


def materialize_replicas(store, n_replicas: int):
    """Replica i of a sharded store lives on devices
    [i·S, (i+1)·S); a single-shard store is device_put whole. When the
    machine has too few devices the surplus replicas share the primary's
    placement — the fan-out still round-robins (correct, just not
    parallel), so smoke environments keep working."""
    devs = jax.devices()
    out = [store]
    for i in range(1, n_replicas):
        if hasattr(store, "shards"):
            S = store.n_shards
            off = i * S
            if off + S <= len(devs):
                out.append(dataclasses.replace(store, device_offset=off))
            else:
                log.warning(
                    "replica %d needs devices [%d, %d) but only %d are "
                    "visible — sharing the primary's mesh", i, off, off + S,
                    len(devs))
                out.append(store)
        else:
            dev = devs[i % len(devs)]
            put = lambda a: None if a is None else jax.device_put(a, dev)
            out.append(dataclasses.replace(
                store, alive=put(store.alive), x=put(store.x),
                signs=put(store.signs), indices=put(store.indices),
                values=put(store.values), nnz=put(store.nnz),
                prior_var=put(store.prior_var)))
    return out
