"""Typed anytime-streaming protocol of the request plane (DESIGN.md §7.2).

The bandit race certifies its top-k incrementally, so a request needs more
vocabulary than "the answer": these records carry *partial* answers with an
honest uncertainty report.

  * ``Deadline`` / ``EffortBudget`` — the two early-termination contracts a
    ``QuerySpec`` can carry: wall-clock and pull-budget. A request
    terminates on whichever of {deadline, budget, full certification} comes
    first.
  * ``AnytimeResult`` — the partial/terminal result: current top-k
    estimates with CI radii, the *certified prefix* length
    (``certified_count`` leading entries are exact and final w.h.p. 1 − δ;
    everything after is a best-effort estimate), the store ``epoch`` the
    race ran against (the mutation fence tag — one result never mixes
    epochs), and a ``terminal`` flag with the exit ``reason``.
  * ``Ticket`` — the handle ``RequestPlane.submit`` returns; poll or stream
    it. Lifecycle: queued → racing → done | shed.

LeJeune et al.'s adaptive-estimation kNN and Neufeld et al.'s bandit budget
allocation (PAPERS.md) motivate exactly this shape: per-instance effort is
the algorithm's output too, and a shared pull budget is spent across
concurrent queries, not just arms.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

#: ticket lifecycle states
QUEUED = "queued"
RACING = "racing"
DONE = "done"
SHED = "shed"

#: terminal reasons
R_CERTIFIED = "certified"
R_DEADLINE = "deadline"
R_BUDGET = "budget"
R_SHED = "shed"


@dataclasses.dataclass(frozen=True)
class Deadline:
    """Wall-clock budget, measured from ``submit`` time."""

    ms: float

    def __post_init__(self):
        if not self.ms > 0:
            raise ValueError(f"deadline must be > 0 ms, got {self.ms}")


@dataclasses.dataclass(frozen=True)
class EffortBudget:
    """Pull-budget cap: scheduler epochs and/or per-query coordinate ops.
    Exceeding either terminates the request with its certified prefix."""

    epochs: Optional[int] = None       # scheduler epochs (race launches)
    coord_ops: Optional[float] = None  # max per-query coordinate reads

    def __post_init__(self):
        if self.epochs is None and self.coord_ops is None:
            raise ValueError("an EffortBudget needs epochs or coord_ops")
        if self.epochs is not None and self.epochs < 1:
            raise ValueError(f"budget epochs must be >= 1, got {self.epochs}")
        if self.coord_ops is not None and not self.coord_ops > 0:
            raise ValueError(
                f"budget coord_ops must be > 0, got {self.coord_ops}")


@dataclasses.dataclass(frozen=True)
class AnytimeResult:
    """Partial (or terminal) answer for one ticket's query batch.

    The first ``certified_count[q]`` entries of row q are the *certified
    prefix*: exact θ values, CI 0, and w.h.p. 1 − δ exactly the prefix of
    the full-certification answer. Entries after the prefix are best-effort
    estimates ordered accepted-first (an uncertified arm is never ranked
    above a certified one) with honest CI radii. ``epoch`` is the store
    epoch the race ran against — a single result never mixes epochs.
    """

    indices: Any                  # (Q, k) int — global slot ids
    values: Any                   # (Q, k) float — θ (exact ≤ certified)
    ci_radii: Any                 # (Q, k) float — 0 on the certified prefix
    certified_count: Any          # (Q,) int — certified-prefix length
    epoch: int                    # store epoch (mutation-fence tag)
    terminal: bool                # no further refinement will arrive
    reason: str                   # certified | deadline | budget | shed | …
    coord_ops: Any = None         # (Q,) coordinate reads paid
    rounds: Any = None            # (Q,) racing rounds paid
    epochs: int = 0               # scheduler epochs this ticket consumed

    def as_dict(self) -> dict:
        from repro.api.spec import SCHEMA_VERSION
        out = dataclasses.asdict(self)
        out["schema_version"] = SCHEMA_VERSION
        return out


@dataclasses.dataclass
class Ticket:
    """Admission handle for one submitted query batch (one tenant)."""

    id: int
    tenant: str
    n_queries: int
    spec: Any                     # the bound QuerySpec
    status: str = QUEUED
    reason: str = ""              # shed/terminal detail
    submitted_at: float = 0.0     # time.monotonic() seconds
    admitted_at: Optional[float] = None
    finished_at: Optional[float] = None
    epochs: int = 0               # scheduler epochs consumed so far
    result: Optional[AnytimeResult] = None
    trace_id: Optional[str] = None  # obs trace id (p<plane>.t<ticket>)

    @property
    def terminal(self) -> bool:
        return self.status in (DONE, SHED)

    @property
    def latency_ms(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return 1e3 * (self.finished_at - self.submitted_at)


def percentile(samples: List[float], q: float) -> Optional[float]:
    """Nearest-rank percentile of a small host-side sample list."""
    if not samples:
        return None
    xs = sorted(samples)
    i = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[i]
