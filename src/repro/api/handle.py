"""``Index`` — the one handle in front of the index subsystem
(DESIGN.md §6.1).

PRs 1–3 grew three parallel surfaces for the same paper technique: the
``IndexStore`` free functions, their ``sharded_*`` twins, and the
cache/prior plumbing private to ``ServeEngine``. This handle collapses the
single-shard/sharded split: ``Index.build/load/open`` return one object
whose ``query/insert/delete/compact/save`` dispatch internally on the store
type, queries go through the typed ``QuerySpec`` protocol (spec.py), the
query LRU + near-repeat warm starts live behind ``CachePolicy``, and
tombstone debt behind ``CompactionPolicy``. Admin operations — **live**
elastic re-sharding and read-replica fan-out — are first-class methods
(admin.py) instead of a save/load cycle.

Side payloads (e.g. kNN-LM next-token ids) attach to the handle and ride
every slot-remapping event (growth, compaction, re-shard) automatically:
``payload[result.indices]`` is always aligned.

The handle is *mutable* (unlike the immutable stores underneath): every
mutation swaps in a fresh store and bumps ``epoch``, which fences the query
cache and the replica fan-out — the invalidation contract callers can rely
on instead of store identity.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Optional

import jax
import numpy as np

from repro.api.cache import QueryCache
from repro.api.spec import (CachePolicy, CompactionPolicy, KNNResult,
                            QuerySpec, ServeStats)
from repro.core.datasets import next_pow2
from repro.index import mutable
from repro.index.batched_race import index_knn as _index_knn
from repro.index.builder import build_index, load_index, save_index
from repro.index.sharded import (ShardedIndexStore, build_sharded_index,
                                 is_sharded_index_dir, load_sharded_index,
                                 save_sharded_index, sharded_delete,
                                 sharded_insert, sharded_maybe_compact)
from repro.utils import get_logger

log = get_logger("repro.api")

PAYLOAD_FILE = "payload.npy"


def _with_cfg(store, cfg):
    """Rebind the racing config (δ / budget overrides) on a store without
    touching its arrays. Off the fast path: a sharded store loses its cached
    device placement and re-places on the next launch."""
    if hasattr(store, "shards"):
        return dataclasses.replace(
            store, shards=[dataclasses.replace(s, cfg=cfg)
                           for s in store.shards])
    return dataclasses.replace(store, cfg=cfg)


class Index:
    """One handle over a single-shard or mesh-spanning racing index.

    Construct through ``Index.build`` (from a corpus), ``Index.load`` (from
    a saved directory, optionally re-sharded on the way in), or
    ``Index.open`` (around an existing store object). All query/mutation/
    admin traffic then goes through the handle; the underlying store is
    reachable read-only as ``handle.store``.
    """

    def __init__(self, store, *, payload: Optional[np.ndarray] = None,
                 build_gids: Optional[np.ndarray] = None,
                 cache: Optional[CachePolicy] = None,
                 compaction: Optional[CompactionPolicy] = None):
        self._store = store
        self._base_cfg = store.cfg    # pre-tuning config: the use_tuned=False
                                      # contract races exactly this
        self._tuned = None            # active repro.tune.TunedConfig (or None)
        self._force_untuned = False   # recall-guard fallback: serve every
                                      # query on build-time defaults
        self._retune_reason = None    # pending re-tune request (or None)
        self.cache_policy = cache if cache is not None else CachePolicy()
        self.compaction_policy = (compaction if compaction is not None
                                  else CompactionPolicy())
        self._cache = (QueryCache(self.cache_policy.capacity)
                       if self.cache_policy.capacity > 0 else None)
        self._cache_ns: Optional[str] = None  # fleet-set namespace label; a
                                              # shared cache keys/fences on it
        self._payload = payload
        self._build_gids = build_gids
        self._epoch = 0
        self._admin_active: Optional[str] = None
        self._n_replicas = 1
        self._replica_stores = None
        self._rr = 0
        self._races = 0
        self._raced_queries = 0
        self._near_hits = 0
        self._compactions = 0
        self._reshards = 0
        self._shard_coord_ops = None
        self._shard_rounds = None
        self._auto_rng = 0
        self._reset_shard_telemetry()

    # -- constructors -------------------------------------------------------

    @classmethod
    def build(cls, corpus, cfg, rng=None, *, shards: int = 1,
              placement: str = "round_robin", capacity: Optional[int] = None,
              impl: str = "auto", payload=None,
              cache: Optional[CachePolicy] = None,
              compaction: Optional[CompactionPolicy] = None) -> "Index":
        """Preprocess ``corpus`` (n, d) into a served index. ``shards > 1``
        spans it over that many mesh devices (DESIGN.md §5). ``payload``:
        optional (n,)-row-aligned side values (e.g. next-token ids) attached
        slot-aligned — the handle keeps them aligned through every remap."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        if shards > 1:
            store, gids = build_sharded_index(
                np.asarray(corpus), cfg, rng, shards=shards,
                placement=placement, capacity=capacity, impl=impl)
        else:
            store = build_index(corpus, cfg, rng, capacity=capacity,
                                impl=impl)
            gids = np.arange(store.n_live, dtype=np.int64)
        handle = cls(store, build_gids=gids, cache=cache,
                     compaction=compaction)
        if payload is not None:
            handle.attach_payload(payload, gids=gids)
        return handle

    @classmethod
    def open(cls, store, *, payload=None, payload_gids=None,
             cache: Optional[CachePolicy] = None,
             compaction: Optional[CompactionPolicy] = None) -> "Index":
        """Wrap an existing ``IndexStore`` / ``ShardedIndexStore``.

        ``payload`` without ``payload_gids`` is taken slot-aligned: it must
        cover every live slot, and a sharded store (whose live global ids
        are non-contiguous) needs the full capacity length."""
        handle = cls(store, cache=cache, compaction=compaction)
        if payload is not None:
            handle.attach_payload(payload, gids=payload_gids)
        return handle

    @classmethod
    def load(cls, path: str, *, shards: Optional[int] = None,
             cache: Optional[CachePolicy] = None,
             compaction: Optional[CompactionPolicy] = None) -> "Index":
        """Load a saved index directory (either layout); ``shards=S'``
        re-shards on the way in. A ``payload.npy`` sidecar (written by
        ``save`` when a payload is attached) is restored and remapped."""
        from repro.index.sharded import reshard as _reshard
        old_ids = None
        if is_sharded_index_dir(path):
            store, old_ids = load_sharded_index(path, shards=shards)
        else:
            store = load_index(path)
            if shards is not None and shards > 1:
                store, old_ids = _reshard(ShardedIndexStore([store]), shards)
        handle = cls(store, cache=cache, compaction=compaction)
        ppath = os.path.join(path, PAYLOAD_FILE)
        if os.path.exists(ppath):
            saved = np.load(ppath)
            buf = np.zeros((store.capacity,) + saved.shape[1:], saved.dtype)
            if old_ids is None:
                buf[: len(saved)] = saved
            else:
                live = old_ids >= 0
                buf[live] = saved[old_ids[live]]
            handle._payload = buf
        # tuned.json sidecar (repro.tune): apply only when its signature
        # still matches the store as reloaded — re-sharded / re-typed /
        # grown-past-bucket stores fall back to defaults bit-compatibly.
        from repro.tune import cache_put, load_tuned, signature_of
        tuned, _why = load_tuned(path, store)
        if tuned is not None:
            handle._apply_tuned(tuned, swap=False)
            cache_put(signature_of(store), tuned)
        return handle

    # -- store-shape properties --------------------------------------------

    @property
    def store(self):
        """The underlying (immutable) store — read-only access; mutate
        through the handle so the epoch fence stays truthful."""
        return self._store

    @property
    def sharded(self) -> bool:
        return hasattr(self._store, "shards")

    @property
    def n_shards(self) -> int:
        return self._store.n_shards if self.sharded else 1

    @property
    def capacity(self) -> int:
        return self._store.capacity

    @property
    def n_live(self) -> int:
        return self._store.n_live

    @property
    def kind(self) -> str:
        return self._store.kind

    @property
    def cfg(self):
        return self._store.cfg

    @property
    def k(self) -> int:
        return self._store.cfg.k

    @property
    def epoch(self) -> int:
        """Bumped on every mutation/admin swap — the cache/replica fence."""
        return self._epoch

    @property
    def tuned(self):
        """The active ``repro.tune.TunedConfig`` (None = build-time
        defaults). Set by ``tune()`` or a valid ``tuned.json`` sidecar at
        ``load``; cleared only by tuning again."""
        return self._tuned

    @property
    def serving_fallback(self) -> bool:
        """True while the recall guard has forced ``use_tuned=False`` for
        ALL queries (``force_untuned``) — the spec's own ``use_tuned`` is
        then ignored until the fallback is lifted."""
        return self._force_untuned

    @property
    def retune_requested(self) -> bool:
        """True while a re-tune has been flagged (``request_retune``) and
        not yet serviced by ``tune()``."""
        return self._retune_reason is not None

    @property
    def retune_reason(self) -> Optional[str]:
        return self._retune_reason

    def force_untuned(self, on: bool = True) -> None:
        """Recall-guard fallback (DESIGN.md §10.3): serve EVERY query on
        the pre-tuning build config until lifted. Cost-only, not an epoch
        event — the tuned config changes racing knobs, never which
        neighbors are correct, so certified cached results stay valid."""
        if on != self._force_untuned:
            log.warning("serving fallback %s: %s the tuned config",
                        "ENGAGED" if on else "lifted",
                        "bypassing" if on else "restoring")
        self._force_untuned = bool(on)

    def request_retune(self, reason: str = "") -> None:
        """Flag that the active tuning is suspect and should be re-raced
        (``tune(force=True)`` clears the flag). Advisory — the launcher or
        an operator decides when to pay the re-race."""
        self._retune_reason = reason or "requested"

    def _serving_tuned(self, spec: QuerySpec) -> bool:
        """Whether THIS query races the tuned config: needs an active
        tuning, the spec opting in, and no recall-guard fallback."""
        return (self._tuned is not None and spec.use_tuned
                and not self._force_untuned)

    @property
    def payload(self) -> Optional[np.ndarray]:
        """(capacity,)+ global-id-aligned side values; index with
        ``KNNResult.indices``."""
        return self._payload

    @property
    def build_gids(self) -> Optional[np.ndarray]:
        """Global slot of each original corpus row (−1 once deleted or
        displaced), maintained through every remap — the row-accuracy hook
        for benches and parity tests."""
        return self._build_gids

    @property
    def stats(self) -> ServeStats:
        cache = self._cache      # NB: an *empty* QueryCache is falsy (__len__)
        return ServeStats(
            races=self._races,
            raced_queries=self._raced_queries,
            cache_hits=cache.hits if cache is not None else 0,
            cache_misses=cache.misses if cache is not None else 0,
            cache_entries=len(cache) if cache is not None else 0,
            near_hits=self._near_hits,
            compactions=self._compactions,
            reshards=self._reshards,
            replicas=self._n_replicas,
            shard_coord_ops=(self._shard_coord_ops.tolist()
                             if self._shard_coord_ops is not None else None),
            shard_rounds=(self._shard_rounds.tolist()
                          if self._shard_rounds is not None else None),
            serving_fallback=self._force_untuned,
            retune_requested=self._retune_reason is not None,
        )

    # -- internal plumbing --------------------------------------------------

    def _reset_shard_telemetry(self) -> None:
        if self.sharded:
            self._shard_coord_ops = np.zeros(self.n_shards)
            self._shard_rounds = np.zeros(self.n_shards)
        else:
            self._shard_coord_ops = self._shard_rounds = None

    def _swap(self, store) -> None:
        """Epoch fence: install a new store, invalidate the query cache and
        the replica fan-out (both re-derive from the new store lazily)."""
        old_shards = self.n_shards if self.sharded else None
        self._store = store
        self._epoch += 1
        if self._cache is not None:
            # a standalone handle (_cache_ns=None) owns the whole cache; a
            # fleet-owned handle shares it and may only fence its own slice
            self._cache.clear(self._cache_ns)
        self._replica_stores = None
        new_shards = store.n_shards if hasattr(store, "shards") else None
        if new_shards != old_shards:
            self._reset_shard_telemetry()

    def _apply_tuned(self, tuned, *, swap: bool = True) -> None:
        """Install a ``TunedConfig``: rebind every shard onto the tuned
        racing knobs (k/δ/metric stay the store's own). ``swap=True`` goes
        through the epoch fence — live re-tunes must invalidate the query
        cache and replica fan-out; ``swap=False`` is the load-time path
        (fresh handle, nothing to fence)."""
        new = _with_cfg(self._store, tuned.bind(self._store.cfg))
        if swap:
            self._swap(new)
        else:
            # load-time: handle not yet published, nothing observes it
            self._store = new  # repro-lint: allow[epoch-fence]
        self._tuned = tuned

    def _remap(self, old_ids: np.ndarray) -> None:
        """Reindex payload + build-row map through an old→new global-id map
        (the ``mutable.compact`` contract). Call BEFORE ``_swap``."""
        old_ids = np.asarray(old_ids)
        live = old_ids >= 0
        if self._payload is not None:
            remapped = np.zeros((len(old_ids),) + self._payload.shape[1:],
                                self._payload.dtype)
            remapped[live] = self._payload[old_ids[live]]
            self._payload = remapped
        if self._build_gids is not None:
            lookup = np.full((self.capacity,), -1, np.int64)
            lookup[old_ids[live]] = np.nonzero(live)[0]
            bg = self._build_gids
            ok = bg >= 0
            self._build_gids = np.where(ok, lookup[np.where(ok, bg, 0)], -1)

    def _grow_payload(self, new_capacity: int) -> None:
        if self._payload is not None and new_capacity > len(self._payload):
            grown = np.zeros((new_capacity,) + self._payload.shape[1:],
                             self._payload.dtype)
            grown[: len(self._payload)] = self._payload
            self._payload = grown

    @contextlib.contextmanager
    def _admin_op(self, name: str):
        """Quiesce fence for admin swaps: mutations attempted while the op
        is in flight fail loudly instead of racing the swap."""
        if self._admin_active is not None:
            raise RuntimeError(
                f"admin op {name!r} while {self._admin_active!r} is in "
                "flight")
        self._admin_active = name
        try:
            yield
        finally:
            self._admin_active = None

    def _check_mutable(self, what: str) -> None:
        if self._admin_active is not None:
            raise RuntimeError(
                f"{what} rejected: index is quiesced for admin op "
                f"{self._admin_active!r}")

    def _route(self):
        """Round-robin the query over the replica fan-out (admin.py)."""
        if self._n_replicas <= 1:
            return self._store
        if self._replica_stores is None:
            from repro.api.admin import materialize_replicas
            self._replica_stores = materialize_replicas(
                self._store, self._n_replicas)
        store = self._replica_stores[self._rr % len(self._replica_stores)]
        self._rr += 1
        return store

    def _query_cfg(self, spec: QuerySpec):
        """The config a spec binds against: the served (tuned) config on
        the fast path, the pre-tuning build config under
        ``use_tuned=False`` or a recall-guard ``force_untuned`` fallback."""
        base = self.cfg if (self._tuned is None
                            or self._serving_tuned(spec)) \
            else self._base_cfg
        return spec.bind(base)

    def _race(self, store, queries, rng, cfg, spec: QuerySpec, prior_hint):
        want = dataclasses.replace(cfg, k=store.cfg.k)
        if want != store.cfg:     # δ / budget / tuning-opt-out overrides
            store = _with_cfg(store, want)
        mode = spec.mode
        if mode == "auto" and self._serving_tuned(spec):
            mode = self._tuned.mode       # tuned fused-vs-rounds dispatch
        return _index_knn(store, queries, rng, k=cfg.k, impl=spec.impl,
                          eliminate=spec.eliminate,
                          warm_start=spec.warm_start, mode=mode,
                          prior_hint=prior_hint)

    def _record_race(self, raw, n_queries: int) -> None:
        self._races += 1
        self._raced_queries += n_queries
        if self._shard_coord_ops is not None and \
                hasattr(raw, "shard_coord_ops"):
            self._shard_coord_ops += np.asarray(raw.shard_coord_ops)
            self._shard_rounds = np.maximum(self._shard_rounds,
                                            np.asarray(raw.shard_rounds))

    def _seeded_priors(self, hid: np.ndarray, miss):
        """Near-repeat warm starts: per-query CI variance priors for missed
        rows, tightened on the cached neighbour's top-k arms. Priors shape
        the variance estimate only — the race stays a fresh δ-PAC race."""
        pol = self.cache_policy
        if (self._cache is None or pol.near_threshold <= 0
                or len(self._cache) == 0):
            return None
        base = np.asarray(self._store.prior_var, np.float32)
        rows, found = [], False
        for i in miss:
            near = self._cache.get_near(hid[i], pol.near_threshold,
                                        self._cache_ns)
            if near is None:
                rows.append(base)
            else:
                seeded = base.copy()
                seeded[near[0]] *= pol.near_prior_scale
                rows.append(seeded)
                found = True
                self._near_hits += 1
        return np.stack(rows) if found else None

    # -- query --------------------------------------------------------------

    def query(self, queries, rng=None, *, spec: Optional[QuerySpec] = None,
              **overrides) -> KNNResult:
        """Batched k-NN with the typed query protocol (spec.py): pass a
        ``QuerySpec``, keyword overrides (``k=``, ``delta=``, ``mode=``, …),
        or both (kwargs refine the spec). Dense queries are a (Q, d) array;
        the sparse box takes the (q_idx, q_val, q_nnz) padded triplet.

        Returns the stable ``KNNResult`` schema with GLOBAL slot ids.
        Exact-repeat rows are served from the query LRU at zero
        coordinate-ops (unless the spec bypasses it); near-repeats race with
        seeded CI priors."""
        if spec is None:
            spec = QuerySpec(**overrides)
        elif overrides:
            spec = dataclasses.replace(spec, **overrides)
        cfg = self._query_cfg(spec)
        if rng is None:
            rng = jax.random.PRNGKey(self._auto_rng)
            self._auto_rng += 1
        is_sparse_q = isinstance(queries, tuple)
        use_cache = (self._cache is not None and spec.cacheable
                     and spec.cache != "bypass" and not is_sparse_q)
        if not use_cache:
            raw = self._race(self._route(), queries, rng, cfg, spec,
                             spec.prior_hint)
            Q = int(np.asarray(raw.indices).shape[0])
            self._record_race(raw, Q)
            return self._result(raw)

        hid = np.asarray(queries, np.float32)
        Q, k = hid.shape[0], cfg.k
        idx = np.zeros((Q, k), np.int64)
        vals = np.zeros((Q, k), np.float32)
        coord_ops = np.zeros((Q,), np.float32)
        rounds = np.zeros((Q,), np.int32)
        n_exact = np.zeros((Q,), np.int32)
        keys = [QueryCache.key(row, self._cache_ns) for row in hid]
        miss = []
        for i in range(Q):
            got = None if spec.cache == "refresh" else self._cache.get(keys[i])
            if got is None:
                miss.append(i)
            else:
                idx[i], vals[i] = got
        raw = None
        if miss:
            sub = hid[miss]
            prior_hint = self._seeded_priors(hid, miss)
            # pad to a power-of-two sub-batch so the jitted executables
            # stay warm across varying miss counts
            pad = next_pow2(len(miss)) - len(miss)
            if pad:
                sub = np.concatenate([sub, np.repeat(sub[:1], pad, 0)], 0)
                if prior_hint is not None:
                    prior_hint = np.concatenate(
                        [prior_hint, np.repeat(prior_hint[:1], pad, 0)], 0)
            raw = self._race(self._route(), sub, rng, cfg, spec, prior_hint)
            r_idx = np.asarray(raw.indices)
            r_vals = np.asarray(raw.values)
            r_ops = np.asarray(raw.coord_ops)
            r_rounds = np.asarray(raw.rounds)
            r_exact = np.asarray(raw.n_exact)
            for j, i in enumerate(miss):
                idx[i], vals[i] = r_idx[j], r_vals[j]
                coord_ops[i] = r_ops[j]
                rounds[i] = r_rounds[j]
                n_exact[i] = r_exact[j]
                self._cache.put(keys[i], (idx[i].copy(), vals[i].copy()),
                                vec=hid[i], namespace=self._cache_ns)
            self._record_race(raw, len(miss))
        return self._result(raw, indices=idx, values=vals,
                            coord_ops=coord_ops, rounds=rounds,
                            n_exact=n_exact, cache_hits=Q - len(miss))

    def race(self, queries, rng=None, *, spec: Optional[QuerySpec] = None,
             raced_queries: Optional[int] = None, chunk_rounds: int = 0,
             obs=None, sid=None, deadline_ms: Optional[float] = None,
             **overrides):
        """Epoch-granular resumable race — the anytime twin of ``query``
        (DESIGN.md §7.1). Returns a ``repro.index.anytime.RaceSession``:
        ``step()`` advances one epoch, ``snapshot`` is the partial top-k
        with CI radii and the certified-prefix length. The request plane
        (``repro.serve.plane``) drives this to implement deadlines, effort
        budgets and anytime streaming; it never touches the query LRU
        (partial results must not poison the cache).

        ``raced_queries`` overrides the row count recorded in ``stats``
        (the plane pads coalesced batches to powers of two).
        ``obs``/``sid`` select the observability context / trace id the
        session's per-epoch spans record under (DESIGN.md §8.3).

        ``deadline_ms``: remaining wall budget for this race — with a
        tuned per-round cost estimate on file (``repro.tune``), the
        session caps each epoch's fused round count R to what the budget
        can still pay (DESIGN.md §9.7). Defaults to ``spec.deadline``'s
        full allowance; the request plane passes the group's tightest
        remaining budget explicitly."""
        from repro.index.anytime import make_session
        if spec is None:
            spec = QuerySpec(**overrides)
        elif overrides:
            spec = dataclasses.replace(spec, **overrides)
        cfg = self._query_cfg(spec)
        if rng is None:
            rng = jax.random.PRNGKey(self._auto_rng)
            self._auto_rng += 1
        if spec.mode == "fused" and self.kind == "sparse":
            raise ValueError("the fused epoch driver pulls corpus blocks — "
                             "sparse boxes race on the per-round driver")
        if spec.mode == "rounds" and self.kind != "sparse":
            raise ValueError(
                "anytime sessions drive dense/rotated boxes through the "
                "epoch-fused driver; mode='rounds' is blocking-query only")
        if deadline_ms is None and spec.deadline is not None:
            deadline_ms = spec.deadline.ms
        round_ms = (self._tuned.round_ms if self._serving_tuned(spec)
                    else 0.0)
        session = make_session(
            self._route(), queries, rng, cfg=cfg, impl=spec.impl,
            eliminate=spec.eliminate, warm_start=spec.warm_start,
            prior_hint=spec.prior_hint, chunk_rounds=chunk_rounds,
            obs=obs, sid=sid, deadline_ms=deadline_ms, round_ms=round_ms)
        self._races += 1
        self._raced_queries += int(raced_queries if raced_queries is not None
                                   else session.Q)
        return session

    def _record_session_telemetry(self, session) -> None:
        """Fold a finished RaceSession's per-shard counters into stats
        (the plane calls this when it drops a race group)."""
        if (self._shard_coord_ops is not None
                and session.shard_coord_ops is not None
                and len(session.shard_coord_ops) == len(self._shard_coord_ops)):
            self._shard_coord_ops += np.asarray(session.shard_coord_ops)
            self._shard_rounds = np.maximum(
                self._shard_rounds, np.asarray(session.shard_rounds))

    def _result(self, raw, **overrides) -> KNNResult:
        kw = dict(
            shard_coord_ops=(np.asarray(raw.shard_coord_ops).tolist()
                             if raw is not None
                             and hasattr(raw, "shard_coord_ops") else None),
            shard_rounds=(np.asarray(raw.shard_rounds).tolist()
                          if raw is not None
                          and hasattr(raw, "shard_rounds") else None),
        )
        if "indices" not in overrides:
            kw.update(indices=np.asarray(raw.indices),
                      values=np.asarray(raw.values),
                      coord_ops=np.asarray(raw.coord_ops),
                      rounds=np.asarray(raw.rounds),
                      n_exact=np.asarray(raw.n_exact))
        kw.update(overrides)
        return KNNResult(**kw)

    # -- mutation ------------------------------------------------------------

    def attach_payload(self, values, *, gids=None) -> None:
        """Attach (or replace) the slot-aligned side payload. ``gids``
        places row i of ``values`` at global slot ``gids[i]``; without it
        the values are taken slot-aligned from 0 (and must cover every live
        slot — a sharded store needs the full capacity length, since its
        live global ids are non-contiguous)."""
        values = np.asarray(values)
        if gids is None:
            if len(values) > self.capacity:
                raise ValueError(
                    f"payload ({len(values)}) exceeds index capacity "
                    f"({self.capacity}) — wrong index for this datastore?")
            if len(values) < self.n_live:
                raise ValueError(
                    f"payload ({len(values)}) does not cover the index's "
                    f"{self.n_live} live slots — uncovered slots would "
                    "silently serve zeros")
            if self.sharded and len(values) != self.capacity:
                raise ValueError(
                    f"a sharded index needs a capacity-length "
                    f"({self.capacity}) global-id-aligned payload, got "
                    f"{len(values)} (or pass gids=)")
        buf = np.zeros((self.capacity,) + values.shape[1:], values.dtype)
        if gids is None:
            buf[: len(values)] = values
        else:
            buf[np.asarray(gids)] = values
        self._payload = buf

    def insert(self, rows, *, payload=None) -> np.ndarray:
        """Insert (B, d) dense rows; returns their GLOBAL slot ids.
        ``payload``: per-row side values written into the attached payload
        at those slots."""
        self._check_mutable("insert")
        if self.sharded:
            store, gids, grow_ids = sharded_insert(self._store, rows)
            if grow_ids is not None:      # stride grew → global ids shifted
                self._remap(grow_ids)
        else:
            store, gids = mutable.insert(self._store, rows)
        self._grow_payload(store.capacity)
        if payload is not None:
            if self._payload is None:
                payload = np.asarray(payload)
                self._payload = np.zeros(
                    (store.capacity,) + payload.shape[1:], payload.dtype)
            self._payload[np.asarray(gids)] = payload
        self._swap(store)
        return np.asarray(gids, np.int64)

    def delete(self, global_ids) -> None:
        """Tombstone global slots (O(1)); data stays until compaction."""
        self._check_mutable("delete")
        if self.sharded:
            store = sharded_delete(self._store, global_ids)
        else:
            store = mutable.delete(self._store, global_ids)
        if self._build_gids is not None:
            # honour the build_gids contract (−1 once deleted): a later
            # insert may reuse the freed slot, which would otherwise be
            # silently attributed to the original corpus row
            dead = np.atleast_1d(np.asarray(global_ids, np.int64))
            self._build_gids = np.where(
                np.isin(self._build_gids, dead), -1, self._build_gids)
        self._swap(store)

    def compact(self) -> np.ndarray:
        """Rebuild the slot layout dropping tombstones; payload and build
        map are remapped in place. Returns the old→new global-id map for
        any *external* side state."""
        self._check_mutable("compact")
        if self.sharded:
            from repro.index.sharded import sharded_compact
            store, old_ids = sharded_compact(self._store)
        else:
            store, old_ids = mutable.compact(self._store)
        self._remap(old_ids)
        self._swap(store)
        self._compactions += 1
        return old_ids

    def maybe_compact(self, *, threshold: Optional[float] = None
                      ) -> Optional[np.ndarray]:
        """Apply the handle's ``CompactionPolicy`` (or an explicit
        threshold): compact only when tombstone debt crosses it AND capacity
        would shrink. Returns the remap when a compaction ran, else None."""
        self._check_mutable("compact")
        thr = threshold if threshold is not None \
            else self.compaction_policy.threshold
        if self.sharded:
            store, old_ids = sharded_maybe_compact(self._store, threshold=thr)
        else:
            store, old_ids = mutable.maybe_compact(self._store, threshold=thr)
        if old_ids is None:
            return None
        self._remap(old_ids)
        self._swap(store)
        self._compactions += 1
        return old_ids

    # -- persistence ---------------------------------------------------------

    def save(self, path: str) -> None:
        """Persist through the checkpoint layer (per-shard checkpoints +
        manifest when sharded); an attached payload is written as a
        ``payload.npy`` sidecar that ``Index.load`` restores and remaps.

        Crash-safe: the sidecars are staged INSIDE the checkpoint layer's
        all-or-nothing directory publish, so ``path`` only ever holds a
        complete index (arrays + manifest + payload + tuned config) — a
        kill at any byte leaves the previous version untouched."""
        def _sidecars(tmp: str) -> None:
            if self._payload is not None:
                np.save(os.path.join(tmp, PAYLOAD_FILE), self._payload)
            if self._tuned is not None:
                from repro.tune import save_tuned, signature_of
                save_tuned(tmp, signature_of(self._store), self._tuned,
                           measured={"epoch_ms": self._tuned.epoch_ms,
                                     "round_ms": self._tuned.round_ms})

        if self.sharded:
            save_sharded_index(self._store, path, extra=_sidecars)
        else:
            save_index(self._store, path, extra=_sidecars)

    # -- admin ops (admin.py) ------------------------------------------------

    def reshard(self, n_shards: int) -> np.ndarray:
        """LIVE elastic re-shard to ``n_shards`` — no checkpoint round-trip;
        see ``repro.api.admin.live_reshard`` for the fence protocol."""
        from repro.api.admin import live_reshard
        return live_reshard(self, n_shards)

    def tune(self, queries=None, rng=None, *, levels: int = 2,
             reps: int = 1, force: bool = False, apply: bool = True,
             **kw) -> dict:
        """Autotune the serving config for THIS store (repro.tune,
        DESIGN.md §9): enumerate the (R, P, B, floor, buffers, mode)
        candidate grid, prune it with the roofline cost model, and race
        the survivors with successive halving on measured wall time.

        Runs as an admin op — serving traffic is quiesced for the race
        and the winner is installed through the epoch fence, never under
        live queries. An equal-signature tuning from earlier in the
        process is reused without re-racing unless ``force``. ``queries``
        defaults to a synthetic batch drawn from the corpus (sparse boxes
        must pass real queries). ``apply=False`` measures without
        installing. ``save()`` persists the active tuning as a
        ``tuned.json`` sidecar; ``load()`` re-applies it while the store
        signature still matches. Returns the tuning report dict."""
        from repro.tune import tune_store
        with self._admin_op("tune"):
            tuned, report = tune_store(self._store, queries, rng,
                                       levels=levels, reps=reps,
                                       force=force, **kw)
            report = dict(report, applied=bool(apply))
            if apply:
                self._apply_tuned(tuned)
                # a fresh tuning services any pending recall-guard state:
                # the suspect config is gone, so the fallback lifts and
                # the re-tune request is satisfied
                if self._force_untuned:
                    self.force_untuned(False)
                self._retune_reason = None
        return report

    def add_replicas(self, n_replicas: int) -> int:
        """Set the read fan-out to ``n_replicas`` (1 = primary only);
        queries round-robin across replica meshes. Returns the fan-out."""
        from repro.api.admin import add_replicas
        return add_replicas(self, n_replicas)

    def __repr__(self) -> str:
        return (f"Index(kind={self.kind!r}, shards={self.n_shards}, "
                f"live={self.n_live}/{self.capacity}, k={self.k}, "
                f"epoch={self._epoch}, replicas={self._n_replicas})")
