"""Typed query protocol for the unified index surface (DESIGN.md §6.2).

Three frozen/typed records replace the ad-hoc kwargs and stringly-keyed
dicts that accumulated across PRs 1–3:

  * ``QuerySpec`` — everything a caller may vary per query batch (k, racing
    mode/impl, a δ override, a pull-budget cap, per-query CI variance
    priors, cache policy), validated ONCE at construction instead of
    per-call inside every driver. A default-constructed spec is the serving
    fast path and is the only spec the query cache serves.
  * ``KNNResult`` — the stable result schema of ``Index.query``: host-side
    arrays with GLOBAL slot ids, per-query cost counters, and (behind a
    sharded store) per-shard load telemetry.
  * ``ServeStats`` — the typed replacement for ``engine.stats``'s dict
    (LeJeune et al. 2019 / Mason et al. 2021 treat per-query budgets and
    priors as part of the query contract; so does this surface).

Plus the two pluggable policy objects lifted out of ``ServeEngine``:
``CachePolicy`` (query LRU + near-repeat warm starts) and
``CompactionPolicy`` (tombstone-debt threshold).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

MODES = ("auto", "fused", "rounds")
IMPLS = ("auto", "pallas", "ref", "xla")
CACHE_POLICIES = ("use", "bypass", "refresh")

#: schema version of KNNResult / ServeStats.as_dict() — bump on any field
#: change so downstream JSON consumers (benchmarks, dashboards) can gate.
#: v2 (PR 5): QuerySpec gained deadline/budget; ServeStats gained the
#: request-plane queue/latency fields (DESIGN.md §7.4).
#: v3 (PR 6): ServeStats gained the obs_* observability fields; the plane
#: latency percentiles became plain floats (0.0 on an empty window, never
#: None/NaN) so autoscaling policies can compare them unconditionally
#: (DESIGN.md §8.6).
#: v4 (PR 7): QuerySpec gained ``use_tuned`` — per-query opt-out of the
#: autotuned serving config (DESIGN.md §9.6).
#: v5 (PR 8): ServeStats gained the audit_*/slo_alerts/serving_fallback/
#: retune_requested fields — the online δ-audit and SLO burn-rate state
#: (DESIGN.md §10).
#: v6 (PR 9): ServeStats gained the fleet rollup fields — per-namespace
#: residency/eviction/reload counters and live per-namespace queue depths
#: (``fleet_namespaces_resident/evicted``, ``fleet_reloads``,
#: ``ns_queue_depth``) so autoscaling can see namespace pressure
#: (DESIGN.md §11).
SCHEMA_VERSION = 6


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """Per-query-batch contract, validated at the boundary.

    ``None`` means "use the index's build-time default" for the overridable
    fields; a default-constructed ``QuerySpec()`` is the cacheable serving
    fast path.
    """

    k: Optional[int] = None            # top-k override (None = store cfg.k)
    mode: str = "auto"                 # auto | fused | rounds driver
    impl: str = "auto"                 # kernel impl (auto/pallas/ref/xla)
    delta: Optional[float] = None      # failure-probability override
    max_rounds: Optional[int] = None   # pull-budget cap (racing rounds)
    eliminate: bool = True             # Alg. 1 elimination on/off
    warm_start: bool = True            # build-time CI variance priors
    prior_hint: Optional[Any] = None   # (Q, capacity) per-query variance
                                       # priors (near-repeat warm starts)
    cache: str = "use"                 # use | bypass | refresh the query LRU
    deadline: Optional[Any] = None     # stream.Deadline — wall-clock cap;
                                       # the request plane returns the
                                       # certified prefix at expiry
    budget: Optional[Any] = None       # stream.EffortBudget — pull-budget
                                       # cap (epochs / coord_ops)
    use_tuned: bool = True             # serve on the autotuned config
                                       # (repro.tune) when one is active;
                                       # False races on build-time defaults

    def __post_init__(self):
        from repro.api.stream import Deadline, EffortBudget
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r} (want one of {MODES})")
        if self.impl not in IMPLS:
            raise ValueError(f"unknown impl {self.impl!r} (want one of {IMPLS})")
        if self.cache not in CACHE_POLICIES:
            raise ValueError(f"unknown cache policy {self.cache!r} "
                             f"(want one of {CACHE_POLICIES})")
        if self.k is not None and self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.delta is not None and not (0.0 < self.delta < 1.0):
            raise ValueError(f"delta must be in (0, 1), got {self.delta}")
        if self.max_rounds is not None and self.max_rounds < 1:
            raise ValueError(f"max_rounds must be >= 1, got {self.max_rounds}")
        if self.deadline is not None and not isinstance(self.deadline,
                                                        Deadline):
            raise ValueError(
                f"deadline must be a repro.api.Deadline, got "
                f"{type(self.deadline).__name__}")
        if self.budget is not None and not isinstance(self.budget,
                                                      EffortBudget):
            raise ValueError(
                f"budget must be a repro.api.EffortBudget, got "
                f"{type(self.budget).__name__}")

    def bind(self, cfg):
        """Apply the spec's overrides to the store's build-time BMOConfig."""
        kw = {}
        if self.k is not None:
            kw["k"] = self.k
        if self.delta is not None:
            kw["delta"] = self.delta
        if self.max_rounds is not None:
            kw["max_rounds"] = self.max_rounds
        return dataclasses.replace(cfg, **kw) if kw else cfg

    @property
    def cacheable(self) -> bool:
        """Only default-contract races may hit or fill the query LRU: a k /
        δ / budget override, a seeded prior, or an anytime early-exit
        contract (deadline / effort budget — the result may be partial)
        changes what the cached result would certify."""
        return (self.k is None and self.delta is None
                and self.max_rounds is None and self.prior_hint is None
                and self.eliminate and self.warm_start
                and self.deadline is None and self.budget is None
                and self.use_tuned)


@dataclasses.dataclass(frozen=True)
class KNNResult:
    """Stable result schema of ``Index.query`` (host-side numpy).

    ``indices`` are GLOBAL slot ids (shard·stride + local behind a sharded
    store) — feed them to ``Index.payload`` lookups or ``Index.delete``.
    Cache-served rows report zero ``coord_ops``/``rounds``.
    """

    indices: Any                       # (Q, k) int   — global slot ids
    values: Any                        # (Q, k) float — ascending θ
    coord_ops: Any                     # (Q,) coordinate reads paid
    rounds: Any                        # (Q,) racing rounds paid
    n_exact: Any                       # (Q,) lazy exact evaluations
    cache_hits: int = 0                # rows served from the query LRU
    shard_coord_ops: Optional[List[float]] = None   # (S,) per-shard reads
    shard_rounds: Optional[List[float]] = None      # (S,) per-shard rounds

    def as_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out["schema_version"] = SCHEMA_VERSION
        return out


@dataclasses.dataclass
class ServeStats:
    """Typed serving counters (the ``engine.stats`` contract since PR 4).

    ``as_dict()`` is the stable JSON schema benchmarks emit; ``__getitem__``
    additionally accepts the pre-PR-4 stringly keys (``knn_cache_hits``, …)
    so downstream dict-style consumers keep working.
    """

    races: int = 0             # batched races launched
    raced_queries: int = 0     # cache misses that actually paid a race
    cache_hits: int = 0
    cache_misses: int = 0
    cache_entries: int = 0
    near_hits: int = 0         # near-repeat CI warm starts
    compactions: int = 0
    reshards: int = 0          # live re-shard admin ops
    replicas: int = 1          # read replicas serving the fan-out
    shard_coord_ops: Optional[List[float]] = None  # cumulative per shard
    shard_rounds: Optional[List[float]] = None     # max per shard
    # -- request-plane telemetry (schema v2, DESIGN.md §7.4) ---------------
    plane_submitted: int = 0   # tickets submitted
    plane_admitted: int = 0    # tickets admitted into a race group
    plane_completed: int = 0   # tickets finished (any terminal reason)
    plane_shed: int = 0        # tickets shed at admission (backpressure)
    plane_deadline_exits: int = 0   # terminated at the wall-clock deadline
    plane_budget_exits: int = 0     # terminated at the effort budget
    plane_readmitted: int = 0  # tickets re-raced after a mutation fence
    plane_epochs: int = 0      # scheduler epochs run
    plane_queue_depth: int = 0      # tickets waiting for admission (now)
    plane_active: int = 0      # tickets racing (now)
    # 0.0 (never None/NaN) when no terminal latency landed in the window yet
    plane_latency_p50_ms: float = 0.0   # terminal latency percentiles
    plane_latency_p95_ms: float = 0.0
    plane_latency_p99_ms: float = 0.0
    # -- observability (schema v3, DESIGN.md §8) ---------------------------
    obs_events: int = 0        # trace events recorded (ring-buffer total)
    obs_event_drops: int = 0   # events overwritten before export
    obs_epoch_ms: Optional[dict] = None    # race-epoch histogram snapshot
    obs_latency_ms: Optional[dict] = None  # ticket-latency histogram snap
    # -- δ-audit / SLO (schema v5, DESIGN.md §10) --------------------------
    audit_sampled: int = 0     # query rows shadow-audited so far
    audit_mismatches: int = 0  # audited rows violating the 1-δ contract
    # 1.0 = "no claim yet": the Wilson bound carries no evidence until
    # rows have actually been audited (and is 1.0 with auditing off)
    audit_err_upper: float = 1.0
    audit_pending: int = 0     # sampled tickets awaiting the oracle
    slo_alerts: int = 0        # burn-rate alerts fired (lifetime)
    serving_fallback: bool = False  # tuned config forced off (recall guard)
    retune_requested: bool = False  # an Index.tune() re-race is flagged
    # -- fleet rollup (schema v6, DESIGN.md §11) ---------------------------
    fleet_namespaces_resident: int = 0  # namespaces open in memory (now)
    fleet_namespaces_evicted: int = 0   # namespaces checkpointed cold (now)
    fleet_reloads: int = 0              # cold reloads paid (lifetime)
    ns_queue_depth: Optional[dict] = None  # namespace -> waiting tickets

    _LEGACY = {
        "knn_races": "races",
        "knn_raced_queries": "raced_queries",
        "knn_cache_hits": "cache_hits",
        "knn_cache_misses": "cache_misses",
        "knn_cache_entries": "cache_entries",
        "knn_near_hits": "near_hits",
        "index_compactions": "compactions",
        "knn_shard_coord_ops": "shard_coord_ops",
        "knn_shard_rounds": "shard_rounds",
    }

    def as_dict(self) -> dict:
        out = {f.name: getattr(self, f.name)
               for f in dataclasses.fields(self)}
        out["schema_version"] = SCHEMA_VERSION
        return out

    def __getitem__(self, key: str):
        name = self._LEGACY.get(key, key)
        if name.startswith("_") or not hasattr(self, name):
            raise KeyError(key)
        return getattr(self, name)

    def __contains__(self, key) -> bool:
        try:
            self[key]
        except (KeyError, TypeError):
            return False
        return True


@dataclasses.dataclass(frozen=True)
class CachePolicy:
    """Query-LRU policy (lifted out of ServeEngine): exact-byte repeats are
    served from memory; a *near* repeat (cosine ≥ ``near_threshold``) still
    races but has its CI variance priors seeded from the cached neighbour.
    ``capacity=0`` disables caching entirely."""

    capacity: int = 256
    near_threshold: float = 0.95     # 0 disables near-repeat warm starts
    near_prior_scale: float = 0.25   # variance tightening on seeded arms

    def __post_init__(self):
        if self.capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {self.capacity}")
        if self.near_threshold > 1.0:
            raise ValueError("near_threshold is a cosine similarity; "
                             f"got {self.near_threshold}")


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """Tombstone-debt policy (lifted out of ServeEngine): rebuild the slot
    layout when the dead fraction crosses ``threshold`` AND capacity would
    actually shrink. ``threshold >= 1`` disables auto-compaction."""

    threshold: float = 0.5

    def __post_init__(self):
        if self.threshold <= 0:
            raise ValueError(f"threshold must be > 0, got {self.threshold}")
