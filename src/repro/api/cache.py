"""Query LRU for the index handle (DESIGN.md §6.2; lifted out of
``serve/engine.py`` in PR 4 so every surface — engine, CLIs, benches —
shares one cache implementation behind ``Index.query``).

Keys are the raw query bytes — only *exact* repeats hit and short-circuit
the race, which is the safe contract for a δ-PAC result. A *near* repeat
(cosine similarity to a cached query above a threshold) still races, but
``get_near`` hands the caller the cached neighbour's result so the race's
CI variance priors can be seeded from it (priors tighten early rounds
without faking evidence; see ``confidence.empirical_sigma_sq_prior``).

Namespacing (DESIGN.md §11.4): a fleet shares one cache across many
namespaces, so keys carry a namespace prefix (``ns + "\\x00" + bytes``) and
near-repeat lookups only scan vectors admitted under the *same* namespace —
two namespaces holding identical query vectors must never exchange rows or
priors. ``evict_namespace`` drops every entry of a dropped/evicted
namespace so a recreated namespace of the same name starts cold.

Zero-norm guard: cosine similarity divides by vector norms, so zero (or
non-finite) query vectors must MISS the near lookup rather than NaN-match,
and zero-norm vectors are never admitted to the near-match matrix.
"""
from __future__ import annotations

import collections
from typing import Optional

import numpy as np


class QueryCache:
    def __init__(self, capacity: int):
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self._od: collections.OrderedDict = collections.OrderedDict()
        self._vecs: collections.OrderedDict = collections.OrderedDict()
        self._vec_ns: dict = {}  # key -> namespace ("" for the default)
        self._mats: dict = {}  # namespace -> (keys, stacked unit vectors);
                               # rebuilt lazily after any mutation

    @staticmethod
    def key(row: np.ndarray, namespace: Optional[str] = None) -> bytes:
        """Cache key = namespace prefix + raw query bytes. Namespace names
        never contain NUL (validated at ``Fleet.create``), so the prefix
        cannot collide across namespaces or with the un-namespaced form."""
        prefix = (namespace or "").encode() + b"\x00"
        return prefix + np.ascontiguousarray(row, np.float32).tobytes()

    def get(self, key: bytes):
        hit = self._od.get(key)
        if hit is not None:
            self._od.move_to_end(key)
            self.hits += 1
            return hit
        self.misses += 1
        return None

    def get_near(self, row: np.ndarray, threshold: float,
                 namespace: Optional[str] = None):
        """Best cached entry *of this namespace* with cosine(row, cached
        query) ≥ threshold, or None. Called only on exact misses, so a match
        is a genuinely *near* (never identical-bytes) neighbour. O(entries·d)
        numpy scan — the cache is small by construction."""
        ns = namespace or ""
        if not self._vecs or threshold <= 0:
            return None
        norm = float(np.linalg.norm(row))
        if norm == 0.0 or not np.isfinite(norm):
            # a zero (or NaN/inf) query has no direction: dividing by its
            # norm would NaN-match — it must miss instead
            return None
        if ns not in self._mats:
            keys = [k for k in self._vecs if self._vec_ns.get(k, "") == ns]
            if not keys:
                return None
            self._mats[ns] = (keys, np.stack([self._vecs[k] for k in keys]))
        keys, mat = self._mats[ns]
        sims = mat @ (np.asarray(row, np.float32) / norm)
        j = int(np.argmax(sims))
        if not (sims[j] >= threshold):     # NaN compares False → miss
            return None
        return self._od[keys[j]]

    def put(self, key: bytes, value, vec: Optional[np.ndarray] = None,
            namespace: Optional[str] = None) -> None:
        ns = namespace or ""
        self._od[key] = value
        self._od.move_to_end(key)
        if vec is not None:
            norm = float(np.linalg.norm(vec))
            if norm > 0 and np.isfinite(norm):
                self._vecs[key] = np.asarray(vec, np.float32) / norm
                self._vecs.move_to_end(key)
                self._vec_ns[key] = ns
                self._mats.pop(ns, None)
        while len(self._od) > self.capacity:
            old, _ = self._od.popitem(last=False)
            if self._vecs.pop(old, None) is not None:
                self._mats.pop(self._vec_ns.pop(old, ""), None)

    def __len__(self) -> int:
        return len(self._od)

    def evict_namespace(self, namespace: Optional[str]) -> int:
        """Drop every entry belonging to ``namespace`` (the eviction hook a
        Fleet calls on drop/evict and an Index calls on its epoch fence).
        Returns the number of result entries removed."""
        prefix = (namespace or "").encode() + b"\x00"
        doomed = [k for k in self._od if k.startswith(prefix)]
        for k in doomed:
            del self._od[k]
            self._vecs.pop(k, None)
            self._vec_ns.pop(k, None)
        self._mats.pop(namespace or "", None)
        return len(doomed)

    def clear(self, namespace: Optional[str] = None) -> None:
        """Clear the whole cache, or — when the owner serves exactly one
        namespace — just that namespace's slice of a shared cache."""
        if namespace is not None:
            self.evict_namespace(namespace)
            return
        self._od.clear()
        self._vecs.clear()
        self._vec_ns.clear()
        self._mats.clear()
