"""Query LRU for the index handle (DESIGN.md §6.2; lifted out of
``serve/engine.py`` in PR 4 so every surface — engine, CLIs, benches —
shares one cache implementation behind ``Index.query``).

Keys are the raw query bytes — only *exact* repeats hit and short-circuit
the race, which is the safe contract for a δ-PAC result. A *near* repeat
(cosine similarity to a cached query above a threshold) still races, but
``get_near`` hands the caller the cached neighbour's result so the race's
CI variance priors can be seeded from it (priors tighten early rounds
without faking evidence; see ``confidence.empirical_sigma_sq_prior``).

Zero-norm guard: cosine similarity divides by vector norms, so zero (or
non-finite) query vectors must MISS the near lookup rather than NaN-match,
and zero-norm vectors are never admitted to the near-match matrix.
"""
from __future__ import annotations

import collections
from typing import Optional

import numpy as np


class QueryCache:
    def __init__(self, capacity: int):
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self._od: collections.OrderedDict = collections.OrderedDict()
        self._vecs: collections.OrderedDict = collections.OrderedDict()
        self._mat = None       # cached (keys, stacked unit vectors) for
                               # get_near; rebuilt lazily after any mutation

    @staticmethod
    def key(row: np.ndarray) -> bytes:
        return np.ascontiguousarray(row, np.float32).tobytes()

    def get(self, key: bytes):
        hit = self._od.get(key)
        if hit is not None:
            self._od.move_to_end(key)
            self.hits += 1
            return hit
        self.misses += 1
        return None

    def get_near(self, row: np.ndarray, threshold: float):
        """Best cached entry with cosine(row, cached query) ≥ threshold, or
        None. Called only on exact misses, so a match is a genuinely *near*
        (never identical-bytes) neighbour. O(entries·d) numpy scan — the
        cache is small by construction."""
        if not self._vecs or threshold <= 0:
            return None
        norm = float(np.linalg.norm(row))
        if norm == 0.0 or not np.isfinite(norm):
            # a zero (or NaN/inf) query has no direction: dividing by its
            # norm would NaN-match — it must miss instead
            return None
        if self._mat is None:
            self._mat = (list(self._vecs.keys()),
                         np.stack(list(self._vecs.values())))
        keys, mat = self._mat
        sims = mat @ (np.asarray(row, np.float32) / norm)
        j = int(np.argmax(sims))
        if not (sims[j] >= threshold):     # NaN compares False → miss
            return None
        return self._od[keys[j]]

    def put(self, key: bytes, value, vec: Optional[np.ndarray] = None) -> None:
        self._od[key] = value
        self._od.move_to_end(key)
        if vec is not None:
            norm = float(np.linalg.norm(vec))
            if norm > 0 and np.isfinite(norm):
                self._vecs[key] = np.asarray(vec, np.float32) / norm
                self._vecs.move_to_end(key)
                self._mat = None
        while len(self._od) > self.capacity:
            old, _ = self._od.popitem(last=False)
            if self._vecs.pop(old, None) is not None:
                self._mat = None

    def __len__(self) -> int:
        return len(self._od)

    def clear(self) -> None:
        self._od.clear()
        self._vecs.clear()
        self._mat = None
