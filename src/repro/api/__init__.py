"""repro.api — the unified client surface of the index subsystem
(DESIGN.md §6).

One handle (``Index``) in front of everything PRs 1–3 built: build/load/
open, typed queries (``QuerySpec`` → ``KNNResult``), online mutation with
automatic payload remapping, pluggable ``CachePolicy``/``CompactionPolicy``,
typed serving counters (``ServeStats``), and first-class admin ops — LIVE
elastic re-sharding (``Index.reshard``) and read-replica fan-out
(``Index.add_replicas``) with no checkpoint round-trip.

Since PR 5 the surface also speaks the *anytime* protocol (``api/stream.py``,
DESIGN.md §7): ``QuerySpec`` can carry a ``Deadline`` / ``EffortBudget``,
``Index.race`` opens an epoch-granular resumable race, and the request plane
(``repro.serve.plane.RequestPlane``) turns those into tickets with streamed
``AnytimeResult`` partials.

The pre-PR-4 ``repro.index`` free functions remain as deprecation shims.

    from repro.api import Index, QuerySpec
    idx = Index.build(corpus, cfg, rng, shards=4, payload=next_ids)
    res = idx.query(queries, rng)                      # KNNResult
    res = idx.query(queries, rng, k=10, delta=0.001)   # spec overrides
    idx.insert(rows, payload=toks); idx.maybe_compact()
    idx.reshard(8)          # live, bit-identical to save->load-at-8
    idx.add_replicas(2)     # read fan-out over replica meshes

    from repro.serve.plane import RequestPlane
    from repro.api import Deadline
    plane = RequestPlane(idx)
    t = plane.submit(queries, deadline=Deadline(ms=5.0))
    for partial in plane.stream(t):                    # AnytimeResult
        ...                                            # anytime consumption
"""
from repro.api.cache import QueryCache
from repro.api.handle import Index
from repro.api.spec import (CachePolicy, CompactionPolicy, KNNResult,
                            QuerySpec, ServeStats)
from repro.api.stream import AnytimeResult, Deadline, EffortBudget, Ticket

__all__ = [
    "AnytimeResult",
    "CachePolicy",
    "CompactionPolicy",
    "Deadline",
    "EffortBudget",
    "Index",
    "KNNResult",
    "QueryCache",
    "QuerySpec",
    "ServeStats",
    "Ticket",
]
