"""Checkpointing: atomic, mesh-independent, async-capable, keep-last-N.

Layout:  <dir>/step_<n>/arrays.npz + meta.msgpack ;  <dir>/step_<n>.tmp
during write, atomically renamed on publish. Arrays are saved as host numpy
keyed by flattened pytree path, so a checkpoint written on one mesh restores
onto any other mesh/device count (resharding happens in device_put against
the target sharding) — the basis of elastic scaling (runtime/elastic.py).
"""
from __future__ import annotations

import contextlib
import os
import shutil
import threading
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.utils import get_logger

log = get_logger("repro.checkpoint")


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}

    def fmt(path):
        return "/".join(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
                        for p in path)

    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        arr = jax.device_get(leaf)
        if hasattr(arr, "dtype") and arr.dtype == jnp.bfloat16:
            # numpy can't serialize bf16; upcast to f32 (lossless), restore
            # casts back to the target dtype
            arr = np.asarray(arr, np.float32)
        flat[fmt(path)] = np.asarray(arr)
    return flat


@contextlib.contextmanager
def staged_dir(path: str):
    """All-or-nothing directory publish: yields a fresh sibling tmp dir to
    write the COMPLETE new content into; on clean exit the tmp dir replaces
    ``path`` in one rename, on exception it is torn down and ``path`` is
    left exactly as it was. A crash mid-write (even ``os._exit``) leaves at
    worst a stale ``<path>.tmp-*`` sibling that readers never look at —
    never a half-written ``path``. This is the directory-granularity twin of
    the tmp+``os.replace`` idiom used by ``tune/sidecar.py`` file writes."""
    tmp = f"{path}.tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    try:
        yield tmp
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def save(path: str, state, *, meta: Optional[Dict[str, Any]] = None,
         extra: Optional[Callable[[str], None]] = None) -> None:
    """Atomic checkpoint write. ``extra(tmpdir)`` lets callers stage
    sidecars (payloads, tuned configs) into the same publish, so the
    checkpoint and its sidecars appear — or don't — together."""
    with staged_dir(path) as tmp:
        flat = _flatten(state)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
            f.write(msgpack.packb(meta or {}))
        if extra is not None:
            extra(tmp)


def restore(path: str, like, *, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: matching pytree of NamedSharding for
    resharded placement (elastic restore)."""
    with np.load(os.path.join(path, "arrays.npz")) as data:
        arrays = {k: data[k] for k in data.files}

    def fmt(path):
        return "/".join(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
                        for p in path)

    leaves_with_path = jax.tree_util.tree_leaves_with_path(like)
    out_leaves = []
    for path_, leaf in leaves_with_path:
        key = fmt(path_)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = arrays[key]
        want_dtype = leaf.dtype
        out_leaves.append(np.asarray(arr).astype(want_dtype))
    treedef = jax.tree_util.tree_structure(like)
    restored = jax.tree_util.tree_unflatten(treedef, out_leaves)
    if shardings is not None:
        restored = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    else:
        restored = jax.tree_util.tree_map(jnp.asarray, restored)
    return restored


def load_arrays(path: str) -> Dict[str, np.ndarray]:
    """Load a checkpoint's flat array dict as-is (no ``like`` template) —
    for states whose shapes are only known from the checkpoint itself, e.g.
    index/builder.load_index restoring an IndexStore."""
    with np.load(os.path.join(path, "arrays.npz")) as data:
        return {k: data[k] for k in data.files}


def read_meta(path: str) -> Dict[str, Any]:
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read())


class CheckpointManager:
    """keep-last-N manager with optional async (background-thread) saves."""

    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def all_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, state, meta: Optional[Dict[str, Any]] = None):
        self.wait()
        # snapshot to host synchronously (cheap vs I/O), write async
        flat_state = jax.tree_util.tree_map(lambda l: np.asarray(jax.device_get(l)),
                                            state)
        meta = dict(meta or {}, step=step)

        def _do():
            save(self._step_dir(step), flat_state, meta=meta)
            self._gc()
            log.info("saved checkpoint step=%d", step)

        if self.async_save:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def restore_latest(self, like, *, shardings=None):
        self.wait()
        step = self.latest_step()
        if step is None:
            return None, None
        state = restore(self._step_dir(step), like, shardings=shardings)
        meta = read_meta(self._step_dir(step))
        return state, meta

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
