from repro.checkpoint import manager
from repro.checkpoint.manager import CheckpointManager, load_arrays, restore, save

__all__ = ["CheckpointManager", "load_arrays", "manager", "restore", "save"]
