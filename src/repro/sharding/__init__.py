from repro.sharding.spec import (
    ParamSpec,
    Rules,
    abstract_params,
    init_params,
    logical_to_pspec,
    param_shardings,
    spec_tree_axes,
)

__all__ = [
    "ParamSpec",
    "Rules",
    "abstract_params",
    "init_params",
    "logical_to_pspec",
    "param_shardings",
    "spec_tree_axes",
]
