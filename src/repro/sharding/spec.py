"""Logical-axis parameter specification & sharding-rule system.

Models declare their parameters once, as a pytree of :class:`ParamSpec`
(shape + dtype + *logical* axis names + initializer).  Everything else is
derived mechanically from that single declaration:

  * ``init_params``      — materialize real arrays (per-path PRNG folding),
  * ``abstract_params``  — ``ShapeDtypeStruct`` stand-ins (dry-run: no alloc),
  * ``param_shardings``  — ``NamedSharding`` per leaf via :class:`Rules`.

A :class:`Rules` object maps logical axis names (``"embed"``, ``"mlp"``,
``"vocab"``, ``"experts"``, ``"batch"`` …) to mesh axis names (or tuples of
them, or ``None`` for replication).  Parallelism plans (DP / FSDP / TP / SP /
EP) are just different rule tables over the same logical names, so changing
the plan never touches model code.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declaration of a single parameter tensor."""

    shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    axes: Tuple[Optional[str], ...] = ()
    init: str = "normal"  # normal | zeros | ones | fanin | embed | scalar
    scale: Optional[float] = None

    def __post_init__(self):
        if len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank != shape {self.shape} rank"
            )

    @property
    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "scalar":
        return jnp.full(spec.shape, spec.scale if spec.scale is not None else 0.0, spec.dtype)
    if spec.init == "fanin":
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = (spec.scale if spec.scale is not None else 1.0) / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)
    # "normal" / "embed": N(0, scale), default scale .02 (GPT-style)
    std = spec.scale if spec.scale is not None else 0.02
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)


def _path_key(base: jax.Array, path_str: str) -> jax.Array:
    h = int.from_bytes(hashlib.md5(path_str.encode()).digest()[:4], "little")
    return jax.random.fold_in(base, h)


def init_params(spec_tree, rng: jax.Array):
    """Materialize a ParamSpec tree into real arrays (path-deterministic)."""

    def _fmt(path) -> str:
        return "/".join(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p)))) for p in path)

    return jax.tree_util.tree_map_with_path(
        lambda p, s: _init_leaf(s, _path_key(rng, _fmt(p))), spec_tree,
        is_leaf=_is_spec,
    )


def abstract_params(spec_tree):
    """ShapeDtypeStruct tree — for .lower() without allocating anything."""
    return jax.tree_util.tree_map(lambda s: s.sds, spec_tree, is_leaf=_is_spec)


def spec_tree_axes(spec_tree):
    """Tree of logical-axes tuples (mirrors the param tree)."""
    return jax.tree_util.tree_map(lambda s: s.axes, spec_tree, is_leaf=_is_spec)


@dataclasses.dataclass(frozen=True)
class Rules:
    """Logical-axis name → mesh axes mapping.

    With ``axis_sizes`` (mesh axis name → size) set, ``pspec`` drops any
    mapping whose mesh extent does not divide the tensor dim — the uniform
    fallback for e.g. 40 heads on a 16-wide model axis, MQA kv=1, or
    global_batch=1 long-context decode (the dim stays replicated)."""

    table: Mapping[str, MeshAxes]
    axis_sizes: Optional[Mapping[str, int]] = None

    def mesh_axes(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        return self.table.get(logical, None)

    def _extent(self, ms: Tuple[str, ...]) -> int:
        if not self.axis_sizes:
            return 1
        e = 1
        for a in ms:
            e *= int(self.axis_sizes.get(a, 1))
        return e

    def pspec(self, axes: Sequence[Optional[str]],
              shape: Optional[Sequence[int]] = None) -> P:
        used: set = set()
        out = []
        for i, ax in enumerate(axes):
            m = self.mesh_axes(ax)
            if m is None:
                out.append(None)
                continue
            ms = (m,) if isinstance(m, str) else tuple(m)
            ms = tuple(a for a in ms if a not in used)
            if shape is not None and self.axis_sizes and ms:
                # greedily drop trailing axes until the extent divides
                while ms and shape[i] % self._extent(ms) != 0:
                    ms = ms[:-1]
            used.update(ms)
            if not ms:
                out.append(None)
            elif len(ms) == 1:
                out.append(ms[0])
            else:
                out.append(ms)
        while out and out[-1] is None:
            out.pop()
        return P(*out)


def logical_to_pspec(axes_tree, rules: Rules):
    """Tree of logical-axes tuples → tree of PartitionSpec."""
    return jax.tree_util.tree_map(
        lambda axes: rules.pspec(axes),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x),
    )


def param_shardings(spec_tree, rules: Rules, mesh: Mesh):
    """Tree of NamedSharding for a ParamSpec tree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, rules.pspec(s.axes, s.shape)),
        spec_tree, is_leaf=_is_spec
    )


def param_pspecs(spec_tree, rules: Rules):
    """Tree of PartitionSpec for a ParamSpec tree."""
    return jax.tree_util.tree_map(
        lambda s: rules.pspec(s.axes, s.shape), spec_tree, is_leaf=_is_spec
    )


# ---------------------------------------------------------------------------
# Canonical rule tables.  Mesh axes: ("pod",) "data", "model".
# Logical activation axes: batch, seq (sequence-parallel residual), act_embed.
# Logical parameter axes:  layers, embed, mlp, vocab, heads, kv_heads, head_dim,
#                          experts, ssm_state, conv, qk_rank, kv_rank, stage.
# ---------------------------------------------------------------------------


def make_rules(
    *,
    fsdp: bool = False,
    tp: bool = True,
    sp: bool = False,
    ep: bool = False,
    multi_pod: bool = False,
    axis_sizes: Optional[Mapping[str, int]] = None,
    kv_len_shard: bool = False,
) -> Rules:
    """Build a rule table for a parallelism plan.

    DP/FSDP use ("pod","data") when a pod axis exists (data-parallel spans
    pods); TP/SP/EP stay within a pod (ICI-local) on the "model" axis.
    ``head_dim`` also maps to the TP axis: per-tensor axis dedup + the
    divisibility fallback make it the natural backup when heads/kv_heads
    don't divide the mesh (GQA kv=8 on model=16, MQA kv=1, 40-head qwen).
    """
    dp: MeshAxes = ("pod", "data") if multi_pod else "data"
    t: MeshAxes = "model" if tp else None
    table = {
        # activations
        "batch": dp,
        "seq": "model" if sp else None,
        "act_embed": None,
        "kv_len": "model" if kv_len_shard else None,
        # params
        "layers": None,
        "embed": dp if fsdp else None,          # FSDP shards the contraction dim
        "mlp": t,
        "vocab": t,
        "heads": t,
        "kv_heads": t,
        "head_dim": t,
        "qk_rank": t,
        "kv_rank": None,
        "experts": "model" if ep else None,
        "expert_mlp": None if ep else t,
        "ssm_state": None,
        "ssm_heads": t,
        "conv": None,
        "frame": None,
    }
    return Rules(table=table, axis_sizes=axis_sizes)
