"""Ambient activation-sharding context.

Model code is written against *logical* activation axes; the training/serving
step builders install (rules, mesh) here, and models call ``shard_act`` at
layer boundaries (embed output) to pin the residual-stream layout (batch over
DP, seq over model when sequence parallelism is on). Outside any context the
call is a no-op, so smoke tests and single-device runs are unaffected.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding

_TLS = threading.local()


@contextlib.contextmanager
def activation_sharding(rules, mesh):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = (rules, mesh)
    try:
        yield
    finally:
        _TLS.ctx = prev


def shard_act(x, axes=("batch", "seq", "act_embed")):
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return x
    rules, mesh = ctx
    axes = tuple(axes[: x.ndim]) + (None,) * max(0, x.ndim - len(axes))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, rules.pspec(axes, x.shape)))
