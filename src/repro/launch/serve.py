"""Serving CLI: batched generation on a host mesh, with the optional BMO-NN
kNN-LM retrieval hook (the paper's technique in the serving path).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --smoke \
      --batch 4 --prompt-len 16 --new-tokens 32 --knn-lm

Retrieval is served from a persistent ``repro.api.Index`` handle.
``--index-dir`` reuses a saved index across launches (build-once/serve-many:
loaded when present, built+saved when not — the next-token payload rides the
handle's sidecar); ``--index-append`` grows the datastore during decode;
``--index-shards`` spans the index over a mesh, and a saved index re-shards
on the way in when the flag differs from the saved shard count;
``--tune`` self-races kernel/frontier configs after build/load
(``repro.tune``, DESIGN.md §9) and persists the winner with the index.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serve.engine import KNNLMConfig, ServeEngine
from repro.sharding.spec import init_params
from repro.utils import get_logger

log = get_logger("repro.serve")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=None)
    ap.add_argument("--knn-lm", action="store_true")
    ap.add_argument("--index-dir", default=None,
                    help="load the retrieval IndexStore from this directory "
                         "if it exists, else build it there once")
    ap.add_argument("--index-append", action="store_true",
                    help="insert each decode step's (hidden, token) pairs "
                         "back into the index")
    ap.add_argument("--index-shards", type=int, default=0,
                    help=">1: span the retrieval index over that many mesh "
                         "devices (one ShardedIndexStore, DESIGN.md §5); "
                         "needs that many visible devices — on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--tune", action="store_true",
                    help="autotune the retrieval index after build/load: "
                         "race kernel/frontier candidate configs on measured "
                         "wall time (repro.tune, DESIGN.md §9) and serve the "
                         "winner; with --index-dir the tuned.json sidecar is "
                         "persisted next to the checkpoint so later launches "
                         "serve tuned without re-racing")
    ap.add_argument("--fleet-root", default=None, metavar="DIR",
                    help="serve retrieval from a namespace fleet rooted "
                         "here (repro.fleet, DESIGN.md §11): the index "
                         "becomes the fleet's 'default' namespace "
                         "(created on first launch, recovered from the "
                         "manifest afterwards) and the engine shares the "
                         "fleet's request plane; overrides --index-dir")
    ap.add_argument("--max-resident", type=int, default=8,
                    help="with --fleet-root: LRU residency budget — "
                         "namespaces beyond this many are checkpointed "
                         "and evicted, reloading transparently on access")
    ap.add_argument("--datastore-size", type=int, default=2048)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--autoscale", action="store_true",
                    help="consult a ScalePolicy on the request-plane "
                         "telemetry after serving and LOG its "
                         "add_replicas/reshard recommendation "
                         "(recommendation-only unless --autoscale-apply)")
    ap.add_argument("--autoscale-apply", action="store_true",
                    help="actually apply an add_replicas recommendation "
                         "to the live handle (reshard stays advisory)")
    ap.add_argument("--audit-rate", type=float, default=0.0,
                    help="shadow δ-audit: re-answer this fraction of "
                         "certified tickets exactly, off the critical path, "
                         "and compare against the served ids "
                         "(repro.obs.audit, DESIGN.md §10)")
    ap.add_argument("--audit-dir", default=None, metavar="DIR",
                    help="write a replayable flight-recorder bundle here "
                         "for every audited mismatch "
                         "(replay with tools/replay_audit.py)")
    ap.add_argument("--slo", action="store_true",
                    help="evaluate burn-rate SLOs (recall vs δ, shed rate) "
                         "over the plane's telemetry after serving; a "
                         "burning recall SLO engages the recall guard "
                         "(fallback to untuned, flag a re-tune) when "
                         "--autoscale-apply is set, else it is logged")
    ap.add_argument("--health-dump", default=None, metavar="PATH",
                    help="write the combined health snapshot (stats + "
                         "audit + SLO state) here on exit as JSON")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="write the obs metrics registry here on exit "
                         "(.json = JSON snapshot, else Prometheus text)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the raw trace-event dump here on exit "
                         "(render/convert with tools/trace_view.py)")
    args = ap.parse_args(argv)

    entry = get_arch(args.arch)
    cfg = entry.smoke if args.smoke else entry.config
    assert cfg.family in ("dense",) or not args.knn_lm, \
        "kNN-LM hook needs a hidden-state-exposing DenseLM"
    plan = dataclasses.replace(entry.plan, fsdp=False, sp=False, ep=False,
                               tp=args.model > 1)
    model = build_model(cfg)
    mesh = make_host_mesh(args.data, args.model)
    rng = jax.random.PRNGKey(0)
    params = init_params(model.param_specs(), rng)
    max_seq = args.max_seq or (args.prompt_len + args.new_tokens + 8)

    knn_cfg = index = fleet = fleet_plane = None
    if args.knn_lm:
        import os

        from repro.api import Index
        from repro.configs.base import BMOConfig
        ds_rng = np.random.default_rng(0)
        keys = ds_rng.normal(size=(args.datastore_size, cfg.d_model)).astype(np.float32)
        next_ids = ds_rng.integers(0, cfg.vocab_size, args.datastore_size).astype(np.int32)
        from repro.serve.plane import PlaneConfig
        knn_cfg = KNNLMConfig(lam=0.2, index_shards=args.index_shards,
                              bmo=BMOConfig(
            k=8, delta=0.05, block=min(64, cfg.d_model), batch_arms=16),
                              plane=PlaneConfig(audit_rate=args.audit_rate,
                                                audit_dir=args.audit_dir))
        policies = dict(cache=knn_cfg.cache_policy(),
                        compaction=knn_cfg.compaction_policy())
        shards = max(args.index_shards, 1)
        if args.fleet_root:
            from repro.fleet import Fleet, FleetConfig
            fleet = Fleet(args.fleet_root,
                          FleetConfig(max_resident=args.max_resident))
            if "default" in fleet:
                index = fleet.get("default")
                log.info("fleet %s: recovered namespace 'default' "
                         "(%d live slots, %d shard(s); %d namespace(s) "
                         "total, %d resident)", args.fleet_root,
                         index.n_live, index.n_shards, len(fleet),
                         fleet.resident_count)
            else:
                index = fleet.create("default", keys, knn_cfg.bmo,
                                     jax.random.PRNGKey(7), shards=shards,
                                     payload=next_ids)
                log.info("fleet %s: created namespace 'default' "
                         "(%d shard(s))", args.fleet_root, index.n_shards)
            # default= binds the 'default' namespace as the plane's default
            # index so the δ-auditor (--audit-rate) covers its traffic
            fleet_plane = fleet.serve(knn_cfg.plane, default="default")
        elif args.index_dir and os.path.exists(args.index_dir):
            # one call covers both layouts; --index-shards != saved shard
            # count re-shards on the way in, the payload sidecar rides the
            # remap inside the handle
            index = Index.load(args.index_dir,
                               shards=shards if shards > 1 else None,
                               **policies)
            if index.payload is None:
                if index.sharded:
                    # a sharded store's live global ids are non-contiguous,
                    # so this CLI's row-ordered next_ids CANNOT be attached
                    # slot-aligned — even when the lengths happen to match,
                    # every neighbour would vote the wrong token
                    raise FileNotFoundError(
                        f"{args.index_dir} holds a sharded index but no "
                        "payload.npy sidecar (the slot-aligned next-token "
                        "ids Index.save writes when a payload is attached) "
                        "— rebuild with this CLI or add the sidecar")
                index.attach_payload(next_ids)
            log.info("loaded index from %s (%d live slots, %d shard(s))",
                     args.index_dir, index.n_live, index.n_shards)
        else:
            index = Index.build(keys, knn_cfg.bmo, jax.random.PRNGKey(7),
                                shards=shards, payload=next_ids, **policies)
            if args.index_dir:
                index.save(args.index_dir)
                log.info("built + saved index to %s (%d shard(s))",
                         args.index_dir, index.n_shards)
        if args.tune and index.tuned is None:
            t0 = time.time()
            report = index.tune(rng=jax.random.PRNGKey(13))
            log.info("autotuned in %.1fs: %s (winner %.2f ms vs default "
                     "%.2f ms over %d raced candidates)",
                     time.time() - t0, report["config"],
                     report.get("winner_median_ms", float("nan")),
                     report.get("default_median_ms", float("nan")),
                     report.get("raced", 0))
            if args.index_dir:
                from repro.tune import save_tuned, signature_of
                save_tuned(args.index_dir, signature_of(index.store),
                           index.tuned,
                           measured={"epoch_ms": index.tuned.epoch_ms,
                                     "round_ms": index.tuned.round_ms})
                log.info("tuned.json sidecar -> %s", args.index_dir)
        elif args.tune:
            log.info("index loaded with a tuned sidecar — serving it "
                     "without re-racing (%s)", index.tuned.to_dict())

    engine = ServeEngine(model, params, plan, mesh, batch_size=args.batch,
                         max_seq=max_seq, knn_lm=knn_cfg,
                         index=index, index_append=args.index_append,
                         plane=fleet_plane,
                         plane_namespace="default" if fleet_plane else None)
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out, retrieval_ops = engine.generate(prompts, args.new_tokens)
    dt = time.time() - t0
    log.info("generated %s tokens in %.2fs (%.1f tok/s)%s",
             out.shape, dt, out.size / dt,
             f"; retrieval coord-ops={retrieval_ops:.0f}" if args.knn_lm else "")
    if args.knn_lm:
        if (args.audit_rate > 0.0 and engine.plane is not None
                and engine.plane.auditor is not None):
            done = engine.plane.audit_flush()   # oracle runs post-serve
            a = engine.plane.auditor.summary()
            log.info("δ-audit: %d ticket(s) flushed — %d/%d audited rows "
                     "mismatched, err_upper=%.4g (%s), %d bundle(s)",
                     done, a["mismatch_rows"], a["sampled_rows"],
                     a["err_upper"], a["method"], len(a["bundles"]))
            for b in a["bundles"]:
                log.warning("flight-recorder bundle: %s", b)
        st = engine.stats            # typed repro.api.ServeStats (schema v2)
        log.info("engine stats: %s", st.as_dict())
        if st.shard_coord_ops is not None:
            log.info("per-shard coord-ops %s, max rounds %s",
                     [f"{v:.3g}" for v in st.shard_coord_ops],
                     st.shard_rounds)
        if fleet is not None:
            fleet.flush()       # manifest + dirty checkpoints to disk
            log.info("fleet stats: %s", fleet.stats())
        if args.autoscale:
            from repro.serve.scale import QueueDepthPolicy
            policy = QueueDepthPolicy(sustain=1)
            decision = policy.recommend(st)
            log.info("autoscale recommendation: %s value=%d (%s)",
                     decision.action, decision.value,
                     decision.reason or "no signal")
            if (args.autoscale_apply and decision.action == "add_replicas"
                    and engine.index is not None):
                engine.index.add_replicas(decision.value)
                log.info("applied: read fan-out now %d replicas",
                         engine.stats.replicas)
        if args.slo and engine.plane is not None:
            from repro.obs import (AlertSink, SLOEngine, default_slos,
                                   plane_sources)
            from repro.serve.scale import RecallGuardPolicy, apply_guard
            plane = engine.plane
            delta = float(engine.index.cfg.delta)
            sink = AlertSink()
            slo = SLOEngine(default_slos(delta), sink=sink, obs=plane.obs)
            slo.observe(plane_sources(plane, plane.auditor))
            state = slo.state()
            for s in state["slos"]:
                burning = any(r["active"] for r in s["rules"])
                log.info("SLO %s: bad_frac=%.4g budget=%g %s", s["name"],
                         s["bad_frac"], s["budget"],
                         "BURNING" if burning else "ok")
            guard = RecallGuardPolicy(sink)
            decision = guard.recommend(engine.stats)
            log.info("recall guard: %s (%s)", decision.action,
                     decision.reason or "no signal")
            if args.autoscale_apply and apply_guard(engine.index, decision):
                log.info("applied: serving_fallback=%s retune_requested=%s",
                         engine.index.serving_fallback,
                         engine.index.retune_requested)
    if args.health_dump:
        from repro.obs import dump_health
        dump_health(args.health_dump, plane=engine.plane,
                    index=engine.index)
        log.info("health snapshot -> %s", args.health_dump)
    if args.metrics_dump or args.trace:
        from repro.obs import dump_events, dump_metrics, get_obs
        obs = get_obs()
        if args.metrics_dump:
            dump_metrics(args.metrics_dump, obs)
            log.info("metrics dumped to %s", args.metrics_dump)
        if args.trace:
            dump_events(args.trace, obs)
            log.info("trace dumped to %s (%d events, %d dropped)",
                     args.trace, obs.events.total, obs.events.drops)
    print(out[:, :16])


if __name__ == "__main__":
    main()
