import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import: jax locks the device count on first init.
# (This also means: no `from __future__ import annotations` in this module.)

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape × mesh) cell:
  * build the model + parallelism plan,
  * construct abstract (ShapeDtypeStruct) state / batch / cache — no
    allocation,
  * jit the train/prefill/decode step with explicit in_shardings,
  * ``.lower().compile()`` — success proves the distribution config is
    coherent; failures are bugs,
  * print ``memory_analysis()`` and ``cost_analysis()`` and derive the
    roofline terms (§Roofline), appended to a JSONL results file.

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.jsonl
  python -m repro.launch.dryrun --arch bmo-nn --shape knn_100k_12k --mesh single
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, TrainConfig, get_arch, list_archs
from repro.configs.base import ShapeConfig
from repro.configs.registry import shape_skip_reason
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.roofline.analysis import analyze_compiled, model_flops_estimate
from repro.sharding.spec import abstract_params, make_rules, param_pspecs
from repro.train.steps import (abstract_train_state, batch_pspecs,
                               make_train_step, state_pspecs, to_named)
from repro.utils import get_logger

log = get_logger("repro.dryrun")

HBM_BYTES = 16 * 1024 ** 3  # v5e-class chip


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------


def input_specs(arch_id: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    entry = get_arch(arch_id)
    model = build_model(entry.config)
    return model.input_specs(SHAPES[shape_name])


# ---------------------------------------------------------------------------
# cell runners
# ---------------------------------------------------------------------------


def _named(mesh, tree_pspecs):
    return jax.tree_util.tree_map(
        lambda ps: NamedSharding(mesh, ps), tree_pspecs,
        is_leaf=lambda x: isinstance(x, P))


def _active_params(model, plan) -> float:
    """Active params for MODEL_FLOPS: MoE expert tensors scaled by
    (active + shared)/total experts."""
    from repro.utils.tree import tree_map_with_path_str
    specs = model.param_specs()
    total = 0.0
    cfg = model.cfg

    def add(path, s):
        nonlocal total
        n = float(np.prod(s.shape))
        if cfg.family == "moe" and "/moe/w" in path:
            n *= cfg.n_experts_active / max(cfg.n_experts, 1)
        total += n

    tree_map_with_path_str(add, specs)
    return total


def run_cell(arch_id: str, shape_name: str, mesh_kind: str, *,
             overrides: Optional[Dict[str, Any]] = None,
             variant: str = "baseline") -> Dict[str, Any]:
    t_start = time.time()
    shape = SHAPES[shape_name]
    entry = get_arch(arch_id)
    cfg, plan = entry.config, entry.plan
    if overrides:
        plan_kw = {k.split(".", 1)[1]: v for k, v in overrides.items()
                   if k.startswith("plan.")}
        cfg_kw = {k.split(".", 1)[1]: v for k, v in overrides.items()
                  if k.startswith("cfg.")}
        if plan_kw:
            plan = dataclasses.replace(plan, **plan_kw)
        if cfg_kw:
            cfg = dataclasses.replace(cfg, **cfg_kw)
    skip = shape_skip_reason(arch_id, shape_name)
    if skip:
        return {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
                "variant": variant, "status": "skipped", "reason": skip}

    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    rules = make_rules(fsdp=plan.fsdp, tp=plan.tp, sp=plan.sp, ep=plan.ep,
                       multi_pod=multi_pod, axis_sizes=axis_sizes,
                       kv_len_shard=plan.kv_len_shard)
    model = build_model(cfg)
    tcfg = TrainConfig()
    in_specs = model.input_specs(shape)
    b_pspecs = batch_pspecs(in_specs, rules)

    if shape.kind == "train":
        # microbatch must still cover the data-parallel extent
        dp_axes = rules.mesh_axes("batch")
        dp_extent = int(np.prod([axis_sizes[a] for a in
                                 ((dp_axes,) if isinstance(dp_axes, str) else dp_axes)]))
        ga = max(min(plan.grad_accum, shape.global_batch // dp_extent), 1)
        if ga != plan.grad_accum:
            plan = dataclasses.replace(plan, grad_accum=ga)
        step, _ = make_train_step(model, plan, tcfg, mesh, rules=rules,
                                  multi_pod=multi_pod)
        state = abstract_train_state(model, plan, tcfg)
        s_pspecs = state_pspecs(model, plan, rules)
        jitted = jax.jit(step,
                         in_shardings=(_named(mesh, s_pspecs),
                                       _named(mesh, b_pspecs)),
                         donate_argnums=0)
        lowered = jitted.lower(state, in_specs)
    elif shape.kind == "prefill":
        from repro.serve.steps import cache_pspecs, make_prefill_step
        prefill, _ = make_prefill_step(model, plan, mesh, rules=rules,
                                       multi_pod=multi_pod)
        p_specs = model.param_specs(dtype=jnp.bfloat16)
        params_abs = abstract_params(p_specs)
        p_pspecs = param_pspecs(p_specs, rules)
        c_specs = model.cache_specs(shape.global_batch, shape.seq_len)
        cache_abs = abstract_params(c_specs)
        c_pspecs = param_pspecs(c_specs, rules)
        jitted = jax.jit(prefill,
                         in_shardings=(_named(mesh, p_pspecs),
                                       _named(mesh, b_pspecs),
                                       _named(mesh, c_pspecs)),
                         donate_argnums=2)
        lowered = jitted.lower(params_abs, in_specs, cache_abs)
    else:  # decode
        from repro.serve.steps import cache_pspecs, make_decode_step
        decode, _ = make_decode_step(model, plan, mesh, rules=rules,
                                     multi_pod=multi_pod)
        p_specs = model.param_specs(dtype=jnp.bfloat16)
        params_abs = abstract_params(p_specs)
        p_pspecs = param_pspecs(p_specs, rules)
        c_specs = model.cache_specs(shape.global_batch, shape.seq_len)
        cache_abs = abstract_params(c_specs)
        c_pspecs = param_pspecs(c_specs, rules)
        tok_specs = in_specs if cfg.family == "vlm" else in_specs["tokens"]
        tok_pspecs = b_pspecs if cfg.family == "vlm" else b_pspecs["tokens"]
        jitted = jax.jit(decode,
                         in_shardings=(_named(mesh, p_pspecs),
                                       _named(mesh, c_pspecs),
                                       _named(mesh, tok_pspecs)),
                         donate_argnums=1)
        lowered = jitted.lower(params_abs, cache_abs, tok_specs)

    t_lower = time.time()
    compiled = lowered.compile()
    t_compile = time.time()

    mem = compiled.memory_analysis()
    print(f"--- {arch_id} × {shape_name} × {mesh_kind} [{variant}] ---")
    print("memory_analysis:", mem)
    cost = compiled.cost_analysis()
    cost0 = cost[0] if isinstance(cost, list) else cost
    print("cost_analysis: flops=%.3e bytes=%.3e" % (
        float(cost0.get("flops", 0)), float(cost0.get("bytes accessed", 0))))

    n_active = _active_params(model, plan)
    mf = model_flops_estimate(cfg, shape, n_active)
    terms = analyze_compiled(compiled, arch=arch_id, shape=shape_name,
                             mesh_name=mesh_kind, chips=chips, model_flops=mf)
    rec = terms.to_dict()
    rec.update({
        "variant": variant, "status": "ok",
        "lower_s": round(t_lower - t_start, 1),
        "compile_s": round(t_compile - t_lower, 1),
        "n_params_active": n_active,
        "overrides": overrides or {},
        "fits_hbm": bool(terms.peak_memory_per_chip <= HBM_BYTES
                         if terms.peak_memory_per_chip else True),
    })
    print(json.dumps({k: rec[k] for k in
                      ("t_compute", "t_memory", "t_collective", "bottleneck",
                       "useful_flops_ratio", "roofline_fraction",
                       "peak_memory_per_chip", "fits_hbm")}, indent=None))
    return rec


# ---------------------------------------------------------------------------
# BMO-NN (the paper's own workload) cells
# ---------------------------------------------------------------------------

KNN_SHAPES = {
    # (n points, d, Q queries per step)
    "knn_100k_12k": (100_000 * 8, 12_288, 256),   # pod-scale corpus (800k)
    "knn_1m_12k": (1_048_576, 12_288, 256),
    "knn_100k_28k": (131_072, 28_672, 256),
}


def run_bmo_cell(shape_name: str, mesh_kind: str, *,
                 variant: str = "baseline",
                 overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    from repro.configs.base import BMOConfig
    from repro.core.distributed import distributed_knn
    t_start = time.time()
    n, d, Q = KNN_SHAPES[shape_name]
    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    dp = ("pod", "data") if multi_pod else "data"
    bmo_kw = {k.split(".", 1)[1]: v for k, v in (overrides or {}).items()
              if k.startswith("bmo.")}
    base_kw = dict(k=5, delta=0.01, block=128, batch_arms=32,
                   pulls_per_round=2, metric="l2", max_rounds=64)
    base_kw.update(bmo_kw)
    cfg = BMOConfig(**base_kw)

    x_s = jax.ShapeDtypeStruct((n, d), jnp.float32)
    q_s = jax.ShapeDtypeStruct((Q, d), jnp.float32)
    rng_s = jax.ShapeDtypeStruct((2,), jnp.uint32)

    fn = lambda x, q, r: distributed_knn(x, q, cfg, mesh, r, impl="ref",
                                         multi_pod=multi_pod)
    jitted = jax.jit(fn, in_shardings=(
        NamedSharding(mesh, P(dp, "model")),
        NamedSharding(mesh, P(None, "model")),
        NamedSharding(mesh, P()),
    ))
    lowered = jitted.lower(x_s, q_s, rng_s)
    t_lower = time.time()
    compiled = lowered.compile()
    t_compile = time.time()
    print(f"--- bmo-nn × {shape_name} × {mesh_kind} [{variant}] ---")
    print("memory_analysis:", compiled.memory_analysis())
    cost = compiled.cost_analysis()
    cost0 = cost[0] if isinstance(cost, list) else cost
    print("cost_analysis: flops=%.3e bytes=%.3e" % (
        float(cost0.get("flops", 0)), float(cost0.get("bytes accessed", 0))))
    # MODEL_FLOPS for kNN = the paper's metric at the roofline: per query,
    # adaptive coordinate reads ≈ n·init·block ops (1 flop each, l2: 3)
    mf = 3.0 * Q * n * cfg.init_pulls * cfg.block
    terms = analyze_compiled(compiled, arch="bmo-nn", shape=shape_name,
                             mesh_name=mesh_kind, chips=chips, model_flops=mf)
    rec = terms.to_dict()
    rec.update({"variant": variant, "status": "ok",
                "lower_s": round(t_lower - t_start, 1),
                "compile_s": round(t_compile - t_lower, 1),
                "overrides": overrides or {},
                "fits_hbm": bool(terms.peak_memory_per_chip <= HBM_BYTES
                                 if terms.peak_memory_per_chip else True)})
    print(json.dumps({k: rec[k] for k in
                      ("t_compute", "t_memory", "t_collective", "bottleneck",
                       "peak_memory_per_chip", "fits_hbm")}))
    return rec


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            continue
    if v in ("true", "false", "True", "False"):
        return k, v.lower() == "true"
    return k, v


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch id, or 'bmo-nn' for the paper workload")
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    help="plan.X=V / cfg.X=V / bmo.X=V override")
    args = ap.parse_args(argv)

    overrides = dict(_parse_override(kv) for kv in args.overrides) or None
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    cells = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                cells += [(a, s, m) for m in meshes]
        for s in KNN_SHAPES:
            cells += [("bmo-nn", s, m) for m in meshes]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, m) for m in meshes]

    failures = 0
    for arch, shape, m in cells:
        try:
            if arch == "bmo-nn":
                rec = run_bmo_cell(shape, m, variant=args.variant,
                                   overrides=overrides)
            else:
                rec = run_cell(arch, shape, m, variant=args.variant,
                               overrides=overrides)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "mesh": m,
                   "variant": args.variant, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
            failures += 1
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    if failures:
        log.error("%d cells failed", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
