"""Production mesh construction. A FUNCTION (not a module-level constant) so
importing this module never touches jax device state."""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """single pod: 16×16 = 256 chips (data, model);
    multi pod:  2×16×16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over host devices (tests / examples)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
