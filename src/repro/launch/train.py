"""Training CLI (host-scale; the production mesh path is exercised by the
dry-run). Wires together: arch config → model → sharded train step →
deterministic loader → checkpoint manager → fault-tolerant supervisor.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/run1 [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import TrainConfig, get_arch
from repro.data.loader import ShardedLoader
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.runtime.supervisor import FailureInjector, Supervisor
from repro.train.steps import (batch_pspecs, init_train_state, make_train_step,
                               state_pspecs, to_named)
from repro.utils import get_logger

log = get_logger("repro.train")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--data", type=int, default=1, help="mesh data axis")
    ap.add_argument("--model", type=int, default=1, help="mesh model axis")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (fault-tolerance demo)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    entry = get_arch(args.arch)
    cfg = entry.smoke if args.smoke else entry.config
    plan = dataclasses.replace(entry.plan, grad_accum=1,
                               fsdp=False, sp=False,
                               tp=args.model > 1, ep=False)
    model = build_model(cfg)
    mesh = make_host_mesh(args.data, args.model)
    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 20, 1))
    step_fn, rules = make_train_step(model, plan, tcfg, mesh)
    s_shardings = to_named(state_pspecs(model, plan, rules), mesh)
    jstep = jax.jit(step_fn, donate_argnums=0)

    loader_ = ShardedLoader(cfg.vocab_size, args.batch, args.seq, mesh=mesh,
                            batch_pspec=batch_pspecs(
                                model.input_specs(
                                    dataclasses.replace(
                                        __import__("repro.configs.base",
                                                   fromlist=["ShapeConfig"]).ShapeConfig(
                                            "cli", args.seq, args.batch, "train"))),
                                rules)["tokens"])

    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    injector = FailureInjector([args.fail_at]) if args.fail_at else None

    t0 = time.time()

    def on_metrics(step, metrics):
        if step % args.log_every == 0:
            log.info("step=%d loss=%.4f lr=%.2e %.2fs/step", step,
                     float(metrics["loss"]), float(metrics["lr"]),
                     (time.time() - t0) / max(step, 1))

    sup = Supervisor(
        ckpt=ckpt,
        train_step=jstep,
        loader=loader_.get,
        init_state=lambda: init_train_state(model, plan, tcfg,
                                            jax.random.PRNGKey(tcfg.seed)),
        state_shardings=s_shardings,
        ckpt_every=args.ckpt_every,
        injector=injector,
    )
    sup.run(args.steps, on_metrics=on_metrics)
    log.info("done in %.1fs", time.time() - t0)


if __name__ == "__main__":
    main()
