"""epoch-fence: store swaps happen only under the fence
(DESIGN.md §12.3).

``Index.epoch`` is the system-wide invalidation fence: the query LRU,
replica fan-out, in-flight plane race groups, δ-audit staleness checks
and tuned-sidecar validity ALL key on it. The contract (DESIGN.md §6.3)
is that the immutable store referenced by ``Index._store`` is replaced
only by ``Index._swap`` — which bumps the epoch in the same breath — so
nothing can observe a new store under an old epoch (or vice versa).

This rule flags:
  * any assignment to a ``._store`` attribute outside ``__init__`` /
    ``_swap``-named fenced helpers (pre-publication construction in
    ``__init__`` is safe by definition: no one else holds the handle);
  * a ``_swap``-style helper that assigns ``_store`` but never bumps
    ``_epoch`` — a fence that doesn't fence.

Deliberate exceptions (e.g. re-deriving device placement on a
just-loaded, not-yet-published handle) carry an inline
``# repro-lint: allow[epoch-fence]`` with the justification in the
comment — making every un-fenced site a reviewed, greppable decision.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import FileContext, Finding, Rule

#: function names allowed to assign ``._store`` without the fence
FENCED_FUNCTIONS = ("__init__", "_swap")


def _targets(node: ast.AST):
    if isinstance(node, ast.Assign):
        return node.targets
    if isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        return [node.target]
    return []


def _assigns_attr(node: ast.AST, attr: str) -> bool:
    return any(isinstance(t, ast.Attribute) and t.attr == attr
               for t in _targets(node))


class EpochFenceRule(Rule):
    name = "epoch-fence"
    doc = ("Index._store is swapped only by __init__/_swap-style fenced "
           "helpers, and every fenced helper bumps the epoch")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if _assigns_attr(node, "_store"):
                fn = ctx.enclosing_function(node)
                fname = fn.name if fn is not None else "<module>"
                if not (fname in FENCED_FUNCTIONS
                        or fname.startswith("_swap")):
                    yield ctx.finding(
                        self.name, node,
                        f"store swap outside the epoch fence (in "
                        f"{fname!r}) — go through Index._swap so the "
                        f"epoch bump invalidates caches/replicas/groups "
                        f"atomically")
            if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and node.name.startswith("_swap")):
                assigns = bumps = False
                for sub in ast.walk(node):
                    if _assigns_attr(sub, "_store"):
                        assigns = True
                    if _assigns_attr(sub, "_epoch"):
                        bumps = True
                if assigns and not bumps:
                    yield ctx.finding(
                        self.name, node,
                        f"fenced helper {node.name!r} swaps _store but "
                        f"never bumps _epoch — stale caches and replicas "
                        f"will serve the old store's answers")
