"""delta-ledger: the δ union-bound accounting must be enumerable
(DESIGN.md §12.2).

The paper's exactness guarantee (top-k exact with prob ≥ 1−δ) survives
composition only because every *split* of the configured δ flows through
the accounting helpers in ``core/confidence.py`` — ``delta_prime``
(Lemma 1: δ′ = δ/(n·MP) per CI) and ``shard_delta`` (δ/S per shard, so
the S shard-local contracts union-bound back to the global δ). A raw
``cfg.delta / something`` anywhere else, or a numeric-literal failure
probability handed straight to a CI radius, is an unauditable leak in
the proof: LeJeune et al. (arXiv:1902.09465) is the cautionary tale of
an approximate contract that silently degrades when the accounting
slips.

This rule flags:
  * arithmetic (``/`` or ``*``) on a ``.delta`` attribute outside the
    ledger home module — route it through a helper instead;
  * numeric-literal ``delta=`` arguments at accounting/CI call sites
    (``delta_prime``, ``shard_delta``, ``hoeffding_*``) — the δ must
    come from config, never be re-derived inline;
  * ``log(2/<literal>)``-style inlined confidence terms.

and COLLECTS every helper call site into ``self.ledger`` — the
machine-generated δ-split table DESIGN.md §12.2 renders, and the thing
``tests/test_analysis.py`` pins so a new split site must register here.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.engine import FileContext, Finding, Rule, call_name

#: the accounting helpers — the ONLY sanctioned δ-split sites
ACCOUNTING_HELPERS = ("delta_prime", "shard_delta")

#: module that owns the helpers; raw δ arithmetic is legal only here
LEDGER_HOME = "src/repro/core/confidence.py"


def _is_delta_attr(node: ast.AST) -> bool:
    return isinstance(node, ast.Attribute) and node.attr == "delta"


def _is_number(node: ast.AST) -> bool:
    return (isinstance(node, ast.Constant)
            and isinstance(node.value, (int, float))
            and not isinstance(node.value, bool))


class DeltaLedgerRule(Rule):
    name = "delta-ledger"
    doc = ("every split of the config δ flows through core.confidence "
           "accounting helpers; no literal failure probabilities at CI "
           "call sites")

    def reset(self) -> None:
        self.ledger: List[dict] = []

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        in_home = ctx.rel.endswith("core/confidence.py")
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.BinOp) and isinstance(
                    node.op, (ast.Div, ast.Mult)):
                if _is_delta_attr(node.left) or _is_delta_attr(node.right):
                    if in_home:
                        continue  # the helper bodies themselves
                    yield ctx.finding(
                        self.name, node,
                        "raw arithmetic on a .delta attribute — split the "
                        "failure budget through core.confidence.delta_prime/"
                        "shard_delta so the ledger can enumerate it")
            elif isinstance(node, ast.Call):
                cname = call_name(node)
                leaf = cname.rsplit(".", 1)[-1]
                if leaf in ACCOUNTING_HELPERS:
                    chain = ctx.function_chain(node)
                    self.ledger.append({
                        "helper": leaf, "path": ctx.rel,
                        "line": node.lineno,
                        "function": chain[0] if chain else "<module>",
                    })
                if leaf in ACCOUNTING_HELPERS or leaf.startswith("hoeffding"):
                    literal = None
                    if node.args and _is_number(node.args[0]):
                        literal = node.args[0]
                    for kw in node.keywords:
                        if kw.arg == "delta" and _is_number(kw.value):
                            literal = kw.value
                    if literal is not None:
                        yield ctx.finding(
                            self.name, literal,
                            f"numeric-literal failure probability "
                            f"({literal.value!r}) at CI call site "
                            f"{leaf}() — take δ from the config so the "
                            f"union bound stays auditable")
                elif leaf == "log":
                    # log(2/0.05)-style inlined confidence term
                    for arg in node.args:
                        if (isinstance(arg, ast.BinOp)
                                and isinstance(arg.op, ast.Div)
                                and _is_number(arg.left)
                                and _is_number(arg.right)):
                            yield ctx.finding(
                                self.name, arg,
                                "inlined log(c/δ) confidence term with a "
                                "literal δ — derive the log term from "
                                "delta_prime(cfg.delta, ...)")
