"""The rule engine: per-file AST visitor pipeline, inline suppressions,
and the ratchet baseline (DESIGN.md §12.1).

Life of a lint run:

  1. every target file is parsed ONCE into a ``FileContext`` (source,
     lines, AST with parent links, suppression table);
  2. each registered rule's ``check(ctx)`` yields ``Finding``s for that
     file; after all files, ``finalize()`` yields cross-file findings
     (e.g. metric name/type conflicts);
  3. findings carrying an inline ``# repro-lint: allow[rule]`` on their
     line (or on a standalone comment line directly above) are dropped
     as *suppressed* — the annotation is the reviewed, greppable record
     of a deliberate exception;
  4. the remainder is matched against the committed ratchet baseline:
     per-fingerprint counts frozen at adoption time. Findings beyond the
     baseline count are NEW (CI fails); findings within it are
     *baselined* (pre-existing debt, visible but not fatal); baseline
     entries no longer observed are *stale* (a warning nudging a
     ``--baseline-update`` shrink — the ratchet only tightens).

Fingerprints deliberately exclude line numbers (``rule|path|snippet``)
so unrelated edits that shift a frozen finding down the file do not
resurrect it as new.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

BASELINE_VERSION = 1
REPORT_VERSION = 1

_ALLOW_RE = re.compile(r"#\s*repro-lint:\s*allow\[([A-Za-z0-9_,\-\s*]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one site."""

    rule: str
    path: str          # repo-relative posix path
    line: int          # 1-indexed
    col: int
    message: str
    snippet: str = ""  # stripped source line (fingerprint component)

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}|{self.path}|{self.snippet}"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "snippet": self.snippet}

    def render(self, status: str = "") -> str:
        tag = f" [{status}]" if status else ""
        return (f"{self.path}:{self.line}:{self.col}: {self.rule}: "
                f"{self.message}{tag}")


class FileContext:
    """One parsed file: source, line table, AST with ``.parent`` links,
    and the per-line suppression table."""

    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child.parent = node  # type: ignore[attr-defined]
        self.allow: Dict[int, set] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _ALLOW_RE.search(text)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                self.allow[i] = rules
                # a standalone comment line suppresses the next line too
                if text.lstrip().startswith("#"):
                    self.allow.setdefault(i + 1, set()).update(rules)

    # -- shared AST helpers (every rule needs these) -------------------------

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def suppressed(self, finding: Finding) -> bool:
        rules = self.allow.get(finding.line)
        return bool(rules and (finding.rule in rules or "*" in rules))

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = getattr(node, "parent", None)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = getattr(cur, "parent", None)
        return None

    def function_chain(self, node: ast.AST) -> List[str]:
        """Names of every enclosing def, innermost first."""
        out = []
        cur = self.enclosing_function(node)
        while cur is not None:
            out.append(cur.name)
            cur = self.enclosing_function(cur)
        return out

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        return Finding(rule=rule, path=self.rel, line=line,
                       col=getattr(node, "col_offset", 0), message=message,
                       snippet=self.line_text(line))


def dotted_name(node: ast.AST) -> str:
    """``a.b.c`` for nested Attribute/Name chains, '' when not a plain
    dotted reference (calls/subscripts in the chain collapse to '')."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def call_name(call: ast.Call) -> str:
    return dotted_name(call.func)


def has_decorator(fn: ast.AST, *names: str) -> bool:
    for dec in getattr(fn, "decorator_list", ()):
        target = dec.func if isinstance(dec, ast.Call) else dec
        dname = dotted_name(target)
        if any(dname == n or dname.endswith("." + n) for n in names):
            return True
        # functools.partial(jax.jit, ...) style decorators: look inside
        if isinstance(dec, ast.Call):
            for arg in dec.args:
                aname = dotted_name(arg)
                if any(aname == n or aname.endswith("." + n) for n in names):
                    return True
    return False


class Rule:
    """Base rule: per-file ``check`` plus an optional cross-file
    ``finalize`` pass that runs after every file has been checked."""

    name = ""
    doc = ""           # one-line: the invariant this rule guards

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        return ()

    def finalize(self) -> Iterable[Finding]:
        return ()

    def reset(self) -> None:
        """Called once per engine run before any file is checked."""


@dataclasses.dataclass
class LintReport:
    findings: List[Finding]                  # post-suppression, all
    new: List[Finding]
    baselined: List[Finding]
    suppressed: int
    stale: List[str]                         # baseline fps no longer seen
    ledger: List[dict]                       # δ-split sites (rules_delta)
    errors: List[str]                        # unparseable files

    @property
    def ok(self) -> bool:
        return not self.new and not self.errors

    def statuses(self) -> List[str]:
        """Per-finding status, parallel to ``findings`` — replays the
        baseline budget exactly as ``apply_baseline`` consumed it (first
        occurrences of a fingerprint are the baselined ones)."""
        budget: Dict[str, int] = {}
        for f in self.baselined:
            budget[f.fingerprint] = budget.get(f.fingerprint, 0) + 1
        out = []
        for f in self.findings:
            if budget.get(f.fingerprint, 0) > 0:
                budget[f.fingerprint] -= 1
                out.append("baselined")
            else:
                out.append("new")
        return out

    def to_dict(self) -> dict:
        out = [dict(f.to_dict(), status=s)
               for f, s in zip(self.findings, self.statuses())]
        return {
            "version": REPORT_VERSION,
            "ok": self.ok,
            "counts": {"total": len(self.findings), "new": len(self.new),
                       "baselined": len(self.baselined),
                       "suppressed": self.suppressed,
                       "stale": len(self.stale)},
            "findings": out,
            "stale": list(self.stale),
            "ledger": list(self.ledger),
            "errors": list(self.errors),
        }


class LintEngine:
    """Run a rule catalog over a file set and ratchet against a baseline."""

    def __init__(self, rules: Sequence[Rule], root: str = "."):
        names = [r.name for r in rules]
        dup = {n for n in names if names.count(n) > 1}
        if dup:
            raise ValueError(f"duplicate rule names: {sorted(dup)}")
        self.rules = list(rules)
        self.root = root

    def run(self, files: Iterable[Tuple[str, str]],
            baseline: Optional[Dict[str, int]] = None) -> LintReport:
        """``files`` yields (abs_path, repo_relative_path) pairs."""
        for rule in self.rules:
            rule.reset()
        findings: List[Finding] = []
        suppressed = 0
        errors: List[str] = []
        for path, rel in files:
            try:
                with open(path, "r", encoding="utf-8") as fh:
                    source = fh.read()
                ctx = FileContext(path, rel, source)
            except (OSError, SyntaxError, ValueError) as e:
                errors.append(f"{rel}: {e}")
                continue
            for rule in self.rules:
                for f in rule.check(ctx):
                    if ctx.suppressed(f):
                        suppressed += 1
                    else:
                        findings.append(f)
        for rule in self.rules:
            findings.extend(rule.finalize())
        ledger: List[dict] = []
        for rule in self.rules:
            ledger.extend(getattr(rule, "ledger", ()))
        new, baselined, stale = apply_baseline(findings, baseline or {})
        return LintReport(findings=findings, new=new, baselined=baselined,
                          suppressed=suppressed, stale=stale, ledger=ledger,
                          errors=errors)


def apply_baseline(findings: List[Finding], baseline: Dict[str, int],
                   ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Split findings into (new, baselined) against per-fingerprint
    budget counts; return stale baseline fingerprints as the third
    element. Within one fingerprint the earliest occurrences (file
    order) consume the budget — which ones are 'old' is unknowable
    without line numbers, and any assignment keeps the invariant that
    #new = max(0, observed - budget)."""
    budget = dict(baseline)
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        if budget.get(f.fingerprint, 0) > 0:
            budget[f.fingerprint] -= 1
            old.append(f)
        else:
            new.append(f)
    seen = {f.fingerprint for f in findings}
    stale = sorted(fp for fp, n in baseline.items()
                   if n > 0 and fp not in seen)
    return new, old, stale


def baseline_from(findings: List[Finding]) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for f in findings:
        counts[f.fingerprint] = counts.get(f.fingerprint, 0) + 1
    return counts


def load_baseline(path: str) -> Dict[str, int]:
    with open(path, "r", encoding="utf-8") as fh:
        doc = json.load(fh)
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"baseline {path}: version {doc.get('version')!r} != "
            f"{BASELINE_VERSION} — regenerate with --baseline-update")
    counts = doc.get("findings", {})
    if not isinstance(counts, dict) or not all(
            isinstance(v, int) and v > 0 for v in counts.values()):
        raise ValueError(f"baseline {path}: malformed findings table")
    return dict(counts)


def save_baseline(path: str, counts: Dict[str, int]) -> None:
    doc = {"version": BASELINE_VERSION,
           "findings": {k: counts[k] for k in sorted(counts)}}
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=1, sort_keys=False)
        fh.write("\n")
