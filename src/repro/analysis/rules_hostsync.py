"""host-sync: no silent device→host transfers on the per-epoch hot
paths (DESIGN.md §12.4).

A ``float()`` / ``.item()`` / ``np.asarray()`` / ``block_until_ready()``
on a device value blocks the Python thread on the device stream. On the
serving hot paths — one call per *epoch*, potentially thousands per
second — a hidden sync serializes the launch pipeline and caps qps at
the launch latency. The discipline (DESIGN.md §8): arrays cross to the
host at ONE deliberate boundary per epoch (``RaceSession`` snapshot
capture via ``repro.utils.hostsync.host_fetch``), and everything
downstream works on host-resident numpy.

Statically, "is this value on device?" is undecidable — so the rule
inverts the burden: inside the configured hot functions, every sync-
shaped call must carry an explicit boundary annotation
(``# host-sync: <why>`` on the call's line) or go through the sanctioned
``host_fetch`` helper (which is itself an allow-scoped
``jax.device_get``). The runtime companion is the CI sanitize tier:
tier-1 under ``jax.transfer_guard("disallow")``, which fails on real
hardware exactly where an annotation is missing or lying.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, Set

from repro.analysis.engine import FileContext, Finding, Rule, dotted_name

#: per-file hot functions — one entry per per-epoch serving loop
HOT_FUNCTIONS: Dict[str, Set[str]] = {
    "src/repro/index/anytime.py": {
        "step", "_step_impl", "_refresh", "_ingest", "_record_epoch",
        "_epoch_extra", "snapshot", "retire", "done", "exhausted",
        "_to_host", "_merge_shard_partials",
    },
    "src/repro/serve/plane.py": {
        "step", "_harvest", "_ingest", "_trace_ticket_epoch",
        "_terminal_reason", "_row_result", "_build_result",
        "_launch_group",
    },
    "src/repro/index/batched_race.py": {
        "fused_race_topk",
    },
}

#: sanctioned explicit-boundary helpers — calls through these pass
SANCTIONED = ("host_fetch", "device_get")

_ANNOTATION = "# host-sync:"


def _sync_shape(node: ast.Call) -> str:
    """'' when the call is not sync-shaped, else a short label."""
    fn = node.func
    if isinstance(fn, ast.Name) and fn.id == "float":
        if node.args and not isinstance(node.args[0], ast.Constant):
            return "float()"
        return ""
    name = dotted_name(fn)
    if name in ("np.asarray", "numpy.asarray", "np.array", "numpy.array"):
        return name + "()"
    if isinstance(fn, ast.Attribute):
        if fn.attr == "item" and not node.args:
            return ".item()"
        if fn.attr == "block_until_ready":
            return ".block_until_ready()"
    return ""


class HostSyncRule(Rule):
    name = "host-sync"
    doc = ("device->host syncs on per-epoch hot paths go through "
           "host_fetch or carry an explicit '# host-sync:' boundary "
           "annotation")

    def __init__(self, hot: Dict[str, Set[str]] = HOT_FUNCTIONS):
        self.hot = hot

    def _hot_set(self, rel: str):
        for path, fns in self.hot.items():
            # match on the repo path or any suffix of it (the engine may
            # be handed paths relative to src/ or to the repo root)
            if rel == path or path.endswith("/" + rel) \
                    or rel.endswith("/" + path.split("src/", 1)[-1]):
                return fns
        return None

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        fns = self._hot_set(ctx.rel)
        if fns is None:
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            label = _sync_shape(node)
            if not label:
                continue
            chain = ctx.function_chain(node)
            if not chain or not any(f in fns for f in chain):
                continue
            if any(f in SANCTIONED for f in chain):
                continue  # inside the sanctioned boundary helper itself
            # float(np.sum(host_fetch(x)))-style wrappers: the value
            # already crossed at the sanctioned boundary
            if any(isinstance(sub, ast.Call)
                   and dotted_name(sub.func).rsplit(".", 1)[-1]
                   in SANCTIONED
                   for a in node.args for sub in ast.walk(a)):
                continue
            line = ctx.lines[node.lineno - 1] if \
                node.lineno <= len(ctx.lines) else ""
            if _ANNOTATION in line:
                continue
            # multi-line calls: annotation may sit on the statement head
            # line or on a comment line directly above it
            stmt = node
            while hasattr(stmt, "parent") and not isinstance(
                    stmt, ast.stmt):
                stmt = stmt.parent  # type: ignore[attr-defined]
            if isinstance(stmt, ast.stmt) and stmt.lineno <= len(ctx.lines):
                head = ctx.lines[stmt.lineno - 1]
                above = ctx.lines[stmt.lineno - 2] \
                    if stmt.lineno >= 2 else ""
                if _ANNOTATION in head or (
                        above.lstrip().startswith("#")
                        and _ANNOTATION in above):
                    continue
            yield ctx.finding(
                self.name, node,
                f"{label} inside hot function {chain[0]!r} — a silent "
                f"device sync here serializes the epoch pipeline; route "
                f"through repro.utils.hostsync.host_fetch or annotate "
                f"the line with '# host-sync: <why this is host-side>'")
