"""metrics-conformance: one coherent metrics surface (DESIGN.md §12.6).

Every series the stack exports flows through ``obs.MetricsRegistry``,
and the exporters (Prometheus text format, OTLP mapping) assume the
conventions this rule pins:

  * names match ``repro_[a-z0-9_]+`` — one prefix so dashboards can
    glob the whole stack, lowercase+underscore so the Prometheus
    exposition is valid without mangling;
  * counters end in ``_total`` (and nothing else does) — the suffix is
    how PromQL users tell a monotone rate()-able series from a gauge;
  * label keys come from the fixed vocabulary below — a typo'd label
    key (``namepsace``) silently forks a series and every dashboard
    aggregation quietly loses rows;
  * a name is registered with ONE kind across the whole tree — the
    registry raises at runtime on a (name, kind) conflict, but only on
    the code path that hits both call sites; ``finalize()`` catches it
    cross-file at lint time.

Dynamic names (``reg.counter(f"repro_{x}")``) defeat static checking —
they are flagged as findings so each one is either rewritten to a
literal or explicitly allow-listed.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Tuple

from repro.analysis.engine import FileContext, Finding, Rule

NAME_RE = re.compile(r"^repro_[a-z0-9_]+$")

#: the closed label-key vocabulary (keep sorted; extending it is a
#: reviewed DESIGN.md §12.6 change, not a drive-by kwarg)
VOCAB = frozenset({
    "backend", "contract", "kernel", "kind", "namespace", "plane",
    "ring", "severity", "shard", "slo", "store_epoch", "tenant",
})

#: registry-method kwargs that are NOT labels
_NON_LABEL_KWARGS = ("help", "buckets")

_KINDS = ("counter", "gauge", "histogram")


class MetricsConformanceRule(Rule):
    name = "metrics-conformance"
    doc = ("metric names match repro_[a-z0-9_]+, counters end _total, "
           "label keys come from the fixed vocabulary, and each name "
           "has one kind tree-wide")

    def reset(self) -> None:
        # name -> [(kind, path, line)] for the cross-file conflict pass
        self.registrations: Dict[str, List[Tuple[str, str, int]]] = {}

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if not (isinstance(fn, ast.Attribute) and fn.attr in _KINDS):
                continue
            # only registry-shaped receivers: reg/registry/...registry
            recv = fn.value
            recv_name = recv.attr if isinstance(recv, ast.Attribute) \
                else recv.id if isinstance(recv, ast.Name) else ""
            if recv_name not in ("reg", "registry", "metrics"):
                continue
            kind = fn.attr
            name_node = node.args[0] if node.args else None
            for kw in node.keywords:
                if kw.arg == "name":
                    name_node = kw.value
            if name_node is None:
                continue
            if not (isinstance(name_node, ast.Constant)
                    and isinstance(name_node.value, str)):
                yield ctx.finding(
                    self.name, name_node,
                    f"dynamic metric name at a {kind}() registration — "
                    f"static conformance checking needs a string literal; "
                    f"enumerate the variants or allow-list this site")
                continue
            mname = name_node.value
            self.registrations.setdefault(mname, []).append(
                (kind, ctx.rel, node.lineno))
            if not NAME_RE.match(mname):
                yield ctx.finding(
                    self.name, name_node,
                    f"metric name {mname!r} does not match "
                    f"'repro_[a-z0-9_]+' — the exporters and dashboard "
                    f"globs assume the repro_ prefix and snake_case")
            if kind == "counter" and not mname.endswith("_total"):
                yield ctx.finding(
                    self.name, name_node,
                    f"counter {mname!r} must end in '_total' — the "
                    f"suffix marks rate()-able monotone series")
            if kind != "counter" and mname.endswith("_total"):
                yield ctx.finding(
                    self.name, name_node,
                    f"{kind} {mname!r} ends in '_total', which is "
                    f"reserved for counters")
            for kw in node.keywords:
                if kw.arg is None or kw.arg in _NON_LABEL_KWARGS \
                        or kw.arg == "name":
                    continue
                if kw.arg not in VOCAB:
                    yield ctx.finding(
                        self.name, kw.value,
                        f"label key {kw.arg!r} on {mname!r} is outside "
                        f"the fixed vocabulary "
                        f"({', '.join(sorted(VOCAB))}) — a typo'd key "
                        f"forks the series; extend VOCAB deliberately "
                        f"if this is a new dimension")

    def finalize(self) -> Iterable[Finding]:
        for mname, regs in sorted(self.registrations.items()):
            kinds = {k for k, _, _ in regs}
            if len(kinds) > 1:
                sites = ", ".join(f"{p}:{ln} ({k})" for k, p, ln in regs)
                first = regs[0]
                yield Finding(
                    rule=self.name, path=first[1], line=first[2], col=0,
                    message=(f"metric {mname!r} registered with "
                             f"conflicting kinds at {sites} — the "
                             f"registry raises at runtime on whichever "
                             f"path hits both"),
                    snippet=f"kinds:{'+'.join(sorted(kinds))}")
