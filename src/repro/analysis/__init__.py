"""repro.analysis — the invariant lint engine (DESIGN.md §12).

Nine PRs of review enforced this repo's proof obligations by eye: the
δ union-bound accounting behind every CI radius, the epoch-fence
discipline behind every store swap, the no-silent-host-sync rule on the
per-epoch hot paths, the no-mid-traffic-recompile contract, the metrics
naming scheme, and the VMEM budgets of the Pallas kernels. This package
makes them machine-checked: an AST rule engine (``engine.py``) with a
repo-specific rule catalog (``rules_*.py``), inline
``# repro-lint: allow[rule]`` suppressions, and a committed ratchet
baseline (``tools/lint_baseline.json``) so pre-existing findings are
frozen while any NEW violation fails CI.

Pure stdlib on purpose — the linter must run (and fail fast) in a CI
job that never imports jax.
"""
from repro.analysis.catalog import default_rules
from repro.analysis.engine import (BASELINE_VERSION, REPORT_VERSION, Finding,
                                   LintEngine, LintReport, Rule,
                                   apply_baseline, baseline_from,
                                   load_baseline, save_baseline)

__all__ = [
    "BASELINE_VERSION", "REPORT_VERSION", "Finding", "LintEngine",
    "LintReport", "Rule", "apply_baseline", "baseline_from",
    "default_rules", "load_baseline", "save_baseline",
]
