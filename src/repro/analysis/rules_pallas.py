"""pallas-budget: kernels fit VMEM and keep their index math divisible
(DESIGN.md §12.7).

A Pallas kernel that oversubscribes VMEM fails at *compile* time on
hardware — which in this repo means in the TPU CI tier or, worse, at
first tune-time on a customer box, not in the CPU-interpret tier-1 run
that merged the PR. This rule bounds the damage statically: for every
``pl.pallas_call`` it prices the per-grid-step footprint from the
``BlockSpec`` block shapes and ``scratch_shapes``, assuming worst-case
4-byte elements and the guide's double-buffered pipeline (×2 on in/out
blocks; scratch is already explicitly multi-buffered via ``n_buf``),
and compares against the per-backend budget below (16 MiB/core on TPU,
per the Pallas guide).

Symbolic dims (``block``, ``d_pad``…) are priced at the documented
upper bounds in ``DIM_BOUNDS``; a symbolic dim with no bound is itself
a finding — an unpriceable kernel is an unreviewable kernel.

Two shape-discipline checks ride along:
  * a constant trailing block dim not divisible by 128 wastes lanes on
    every TPU generation (the VPU/MXU lane width);
  * ``pl.ds(i * name, name)`` strided indexing requires a visible
    ``assert ... % name == 0``-style divisibility guard somewhere in
    the module — otherwise the last partial block reads out of bounds
    (Pallas pads silently in interpret mode and corrupts on hardware).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.engine import FileContext, Finding, Rule, call_name

#: per-backend VMEM/shared-memory budget in bytes per grid step
BACKEND_BUDGETS = {"tpu": 16 * 1024 * 1024}

#: documented upper bounds for symbolic block-shape dims (DESIGN.md §12.7)
DIM_BOUNDS: Dict[str, int] = {
    "block": 4096,      # feature-block width, lane-aligned
    "d_pad": 65536,     # padded feature dim ceiling
    "n_buf": 8,         # streaming slot depth
}

_WORST_CASE_ITEMSIZE = 4   # f32/i32; bf16 kernels only ever cost less
_PIPELINE_FACTOR = 2       # double-buffered in/out blocks
_LANE = 128


def _dim_value(node: ast.AST) -> Optional[int]:
    """Concrete or bounded value of one block-shape dim, None when
    unpriceable."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.Name):
        return DIM_BOUNDS.get(node.id)
    return None


def _block_shape(spec: ast.Call) -> Optional[ast.Tuple]:
    """The block-shape tuple of a pl.BlockSpec(...) call, if present."""
    if spec.args and isinstance(spec.args[0], ast.Tuple):
        return spec.args[0]
    for kw in spec.keywords:
        if kw.arg in ("block_shape", None):
            if isinstance(kw.value, ast.Tuple):
                return kw.value
    return None


def _is_any_space(spec: ast.Call) -> bool:
    return any(kw.arg == "memory_space" for kw in spec.keywords)


class PallasBudgetRule(Rule):
    name = "pallas-budget"
    doc = ("every pallas_call's priced VMEM footprint fits the backend "
           "budget; strided pl.ds indexing carries a divisibility guard")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        calls = [n for n in ast.walk(ctx.tree)
                 if isinstance(n, ast.Call)
                 and call_name(n).endswith("pallas_call")]
        if not calls:
            return
        guards = self._divisibility_guards(ctx)
        for call in calls:
            yield from self._check_budget(ctx, call)
        yield from self._check_strides(ctx, guards)

    # -- VMEM pricing --------------------------------------------------------

    def _check_budget(self, ctx: FileContext,
                      call: ast.Call) -> Iterable[Finding]:
        total = 0
        priceable = True
        for kw in call.keywords:
            if kw.arg in ("in_specs", "out_specs", "scratch_shapes"):
                specs = kw.value.elts if isinstance(
                    kw.value, (ast.List, ast.Tuple)) else [kw.value]
                factor = 1 if kw.arg == "scratch_shapes" \
                    else _PIPELINE_FACTOR
                for spec in specs:
                    if not isinstance(spec, ast.Call):
                        continue
                    if _is_any_space(spec):
                        continue  # stays in HBM — not a VMEM block
                    shape = _block_shape(spec)
                    if shape is None:
                        continue
                    cost = _WORST_CASE_ITEMSIZE
                    for dim in shape.elts:
                        v = _dim_value(dim)
                        if v is None:
                            priceable = False
                            yield ctx.finding(
                                self.name, dim,
                                f"unpriceable block-shape dim "
                                f"{ctx.line_text(dim.lineno)!r} — give "
                                f"the symbol an upper bound in "
                                f"analysis.rules_pallas.DIM_BOUNDS so "
                                f"the VMEM footprint stays reviewable")
                        else:
                            cost *= v
                    total += cost * factor
                    # lane-alignment on the trailing dim
                    last = shape.elts[-1] if shape.elts else None
                    lv = _dim_value(last) if last is not None else None
                    if (isinstance(last, ast.Constant) and lv
                            and lv >= _LANE and lv % _LANE):
                        yield ctx.finding(
                            self.name, last,
                            f"trailing block dim {lv} is not a multiple "
                            f"of the {_LANE}-wide lane — pad to the "
                            f"lane width or throughput drops on every "
                            f"TPU generation")
        budget = BACKEND_BUDGETS["tpu"]
        if priceable and total > budget:
            yield ctx.finding(
                self.name, call,
                f"worst-case VMEM footprint {total // 1024} KiB exceeds "
                f"the {budget // (1024 * 1024)} MiB/core TPU budget "
                f"(priced at {_WORST_CASE_ITEMSIZE}-byte elements, "
                f"x{_PIPELINE_FACTOR} pipeline buffers) — shrink the "
                f"block shapes or tighten DIM_BOUNDS")

    # -- strided-index divisibility ------------------------------------------

    def _divisibility_guards(self, ctx: FileContext) -> Set[str]:
        """Names appearing as '% name' inside any assert in the module."""
        out: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Assert):
                continue
            for sub in ast.walk(node.test):
                if (isinstance(sub, ast.BinOp)
                        and isinstance(sub.op, ast.Mod)
                        and isinstance(sub.right, ast.Name)):
                    out.add(sub.right.id)
        return out

    def _check_strides(self, ctx: FileContext,
                       guards: Set[str]) -> Iterable[Finding]:
        flagged: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and call_name(node).endswith("pl.ds")
                    and len(node.args) == 2):
                continue
            start, size = node.args
            if not isinstance(size, ast.Name):
                continue
            strided = (isinstance(start, ast.BinOp)
                       and isinstance(start.op, ast.Mult)
                       and any(isinstance(s, ast.Name)
                               and s.id == size.id
                               for s in (start.left, start.right)))
            if strided and size.id not in guards \
                    and size.id not in flagged:
                flagged.add(size.id)
                yield ctx.finding(
                    self.name, node,
                    f"strided pl.ds(i * {size.id}, {size.id}) with no "
                    f"'% {size.id}' divisibility assert in the module — "
                    f"a ragged last block reads out of bounds on "
                    f"hardware (interpret mode pads silently)")
