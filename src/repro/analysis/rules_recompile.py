"""recompile-hazard: no mid-traffic XLA recompiles (DESIGN.md §12.5).

The serving stack's latency contract assumes one warm race pre-compiles
every (Q, W, T) specialization a request can reach (DESIGN.md §7.1, §9):
frontier widths shrink down a pow2 chain, race batches are pow2-padded,
and adaptive R is pow2-quantized — so the set of shapes is log-sized and
warmable. The ``repro_xla_compiles_total`` regression test enforces this
at runtime; this rule catches the two static ways PRs have almost broken
it:

  * a ``jax.jit`` call *inside* a per-call function — every invocation
    builds a fresh jitted callable with an empty cache, i.e. a
    guaranteed recompile. Module level, ``__init__`` (once per object)
    and ``lru_cache``-memoized factories are the sanctioned homes;
  * unhashable values in ``static_argnums``/``static_argnames``
    positions (a list/dict/set default on a static parameter) — a
    TypeError at best, a per-call retrace forever at worst;
  * pow2 discipline in batch construction: a ``len(...)`` fed straight
    into a ``jnp.zeros``-style shape inside the frontier/plane files
    creates one XLA specialization per distinct length — bucket it
    through ``next_pow2``/``bucket_width`` first.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import (FileContext, Finding, Rule, call_name,
                                   dotted_name, has_decorator)

#: files whose batch/shape construction must stay on the pow2 chain
POW2_FILES = ("index/frontier.py", "serve/plane.py", "index/anytime.py",
              "index/batched_race.py")

#: shape-taking constructors checked by the pow2 discipline
_SHAPE_CTORS = ("zeros", "ones", "full", "empty")

#: helpers that launder a length onto the pow2 chain
_POW2_HELPERS = ("next_pow2", "pow2_floor", "bucket_width", "floor_width")


def _jit_target(node: ast.Call):
    """The function object being jitted, for jax.jit(f, ...) calls."""
    return node.args[0] if node.args else None


def _contains_len(node: ast.AST) -> bool:
    names = [call_name(sub) for sub in ast.walk(node)
             if isinstance(sub, ast.Call)]
    if any(n.rsplit(".", 1)[-1] in _POW2_HELPERS for n in names):
        return False  # laundered through the pow2 chain
    return any(n == "len" for n in names)


class RecompileHazardRule(Rule):
    name = "recompile-hazard"
    doc = ("no per-call jax.jit, no unhashable static args, and batch "
           "shapes in frontier/plane files stay on the pow2 chain")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        pow2_scope = any(ctx.rel.endswith(p) for p in POW2_FILES)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                cname = call_name(node)
                if cname in ("jax.jit", "jit"):
                    yield from self._check_jit_site(ctx, node)
                    yield from self._check_static_args(ctx, node)
                if pow2_scope and isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _SHAPE_CTORS \
                        and dotted_name(node.func).startswith(("jnp.",
                                                               "jax.numpy")):
                    yield from self._check_pow2_shape(ctx, node)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_static_defaults(ctx, node)

    def _check_jit_site(self, ctx: FileContext,
                        node: ast.Call) -> Iterable[Finding]:
        fn = ctx.enclosing_function(node)
        if fn is None or fn.name == "__init__":
            return
        cur = fn
        while cur is not None:
            if has_decorator(cur, "lru_cache", "cache"):
                return
            cur = ctx.enclosing_function(cur)
        yield ctx.finding(
            self.name, node,
            f"jax.jit called inside per-call function {fn.name!r} — each "
            f"call builds a fresh jitted callable (guaranteed recompile); "
            f"hoist to module level or memoize the factory with "
            f"functools.lru_cache")

    def _check_static_args(self, ctx: FileContext,
                           node: ast.Call) -> Iterable[Finding]:
        static_names = []
        for kw in node.keywords:
            if kw.arg == "static_argnames" and isinstance(
                    kw.value, (ast.Tuple, ast.List)):
                static_names = [e.value for e in kw.value.elts
                                if isinstance(e, ast.Constant)]
        target = _jit_target(node)
        if not static_names or not isinstance(target, ast.Name):
            return
        # resolve the jitted function when defined in the same module
        for sub in ast.walk(ctx.tree):
            if isinstance(sub, ast.FunctionDef) and sub.name == target.id:
                yield from self._unhashable_defaults(ctx, sub, static_names)
                return

    def _check_static_defaults(self, ctx: FileContext,
                               fn: ast.AST) -> Iterable[Finding]:
        """Decorator form: @partial(jax.jit, static_argnames=(...))."""
        for dec in fn.decorator_list:
            if not (isinstance(dec, ast.Call)
                    and any(dotted_name(a) in ("jax.jit", "jit")
                            for a in dec.args)):
                continue
            for kw in dec.keywords:
                if kw.arg == "static_argnames" and isinstance(
                        kw.value, (ast.Tuple, ast.List)):
                    names = [e.value for e in kw.value.elts
                             if isinstance(e, ast.Constant)]
                    yield from self._unhashable_defaults(ctx, fn, names)

    def _unhashable_defaults(self, ctx: FileContext, fn: ast.AST,
                             static_names) -> Iterable[Finding]:
        args = fn.args
        all_args = list(args.posonlyargs) + list(args.args) \
            + list(args.kwonlyargs)
        defaults = dict(zip([a.arg for a in reversed(args.args)],
                            reversed(args.defaults)))
        defaults.update({a.arg: d for a, d in
                         zip(args.kwonlyargs, args.kw_defaults) if d})
        for a in all_args:
            if a.arg in static_names and isinstance(
                    defaults.get(a.arg),
                    (ast.List, ast.Dict, ast.Set)):
                yield ctx.finding(
                    self.name, defaults[a.arg],
                    f"static arg {a.arg!r} of jitted {fn.name!r} defaults "
                    f"to an unhashable {type(defaults[a.arg]).__name__} — "
                    f"static args must be hashable (use a tuple/frozen "
                    f"value) or jit raises/retraces per call")

    def _check_pow2_shape(self, ctx: FileContext,
                          node: ast.Call) -> Iterable[Finding]:
        if not node.args:
            return
        shape = node.args[0]
        if _contains_len(shape):
            yield ctx.finding(
                self.name, shape,
                "len(...) fed directly into an array shape — one XLA "
                "specialization per distinct length; bucket through "
                "next_pow2/bucket_width so the compile cache stays on "
                "the pow2 chain")
