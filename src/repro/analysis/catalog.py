"""The repo's rule catalog — one ``default_rules()`` so the CLI, the CI
job, and the tests all lint with the same set (DESIGN.md §12)."""
from __future__ import annotations

from typing import List

from repro.analysis.engine import Rule
from repro.analysis.rules_delta import DeltaLedgerRule
from repro.analysis.rules_fence import EpochFenceRule
from repro.analysis.rules_hostsync import HostSyncRule
from repro.analysis.rules_metrics import MetricsConformanceRule
from repro.analysis.rules_pallas import PallasBudgetRule
from repro.analysis.rules_recompile import RecompileHazardRule


def default_rules() -> List[Rule]:
    return [
        DeltaLedgerRule(),
        EpochFenceRule(),
        HostSyncRule(),
        RecompileHazardRule(),
        MetricsConformanceRule(),
        PallasBudgetRule(),
    ]
