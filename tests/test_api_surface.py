"""Public-API snapshot (PR-4 CI satellite): ``repro.api.__all__``, the
``ServeStats``/``KNNResult``/``QuerySpec`` schemas, and the ``Index``
method surface are pinned against ``tests/api_surface.json``.

A mismatch here is a BREAKING-CHANGE gate, not a bug: if the change is
intentional, update the snapshot (and bump ``repro.api.spec.SCHEMA_VERSION``
when a *result/stats schema* changed — JSON consumers key off it) in the
same commit and say so in the PR.
"""
import dataclasses
import json
import os

SNAPSHOT = os.path.join(os.path.dirname(__file__), "api_surface.json")


def _snapshot():
    with open(SNAPSHOT) as f:
        return json.load(f)


def _fields(cls):
    return [f.name for f in dataclasses.fields(cls)]


def test_api_all_matches_snapshot():
    import repro.api
    assert sorted(repro.api.__all__) == _snapshot()["api_all"]
    for name in repro.api.__all__:          # every name actually resolves
        assert getattr(repro.api, name) is not None


def test_serve_stats_schema_matches_snapshot():
    from repro.api import ServeStats
    from repro.api.spec import SCHEMA_VERSION
    snap = _snapshot()
    assert _fields(ServeStats) == snap["serve_stats_fields"]
    assert SCHEMA_VERSION == snap["schema_version"]
    # as_dict() emits exactly the fields plus the version tag
    d = ServeStats().as_dict()
    assert sorted(d) == sorted(snap["serve_stats_fields"]
                               + ["schema_version"])


def test_knn_result_and_query_spec_match_snapshot():
    from repro.api import KNNResult, QuerySpec
    snap = _snapshot()
    assert _fields(KNNResult) == snap["knn_result_fields"]
    assert _fields(QuerySpec) == snap["query_spec_fields"]


def test_index_method_surface_matches_snapshot():
    from repro.api import Index
    public = sorted(
        n for n, v in vars(Index).items()
        if not n.startswith("_")
        and (callable(v) or isinstance(v, (classmethod, staticmethod))))
    assert public == _snapshot()["index_methods"]


def test_deprecated_index_all_is_importable():
    """The old surface must keep importing (deprecation shims) — its
    __all__ is part of the compatibility contract."""
    import repro.index as old
    for name in old.__all__:
        assert getattr(old, name) is not None
    assert set(old._SHIMS) <= set(old.__all__)
