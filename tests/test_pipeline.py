"""GPipe pipeline parallelism: forward parity + grads-through-ppermute
(subprocess with a 4-way stage mesh)."""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(prog: str, devices: int = 4, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(prog)],
                         capture_output=True, text=True, env=env, cwd=ROOT,
                         timeout=timeout)
    assert out.returncode == 0 and "OK" in out.stdout, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"


def test_pipeline_forward_matches_sequential():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train.pipeline import pipeline_apply, split_stages
        mesh = jax.make_mesh((4,), ("stage",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        L, d, n_micro, mb = 8, 16, 6, 4
        key = jax.random.PRNGKey(0)
        W = jax.random.normal(key, (L, d, d)) * 0.2

        def stage_fn(w_group, x):
            def body(x, w):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(body, x, w_group)
            return x

        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
        got = pipeline_apply(stage_fn, split_stages(W, 4), x, mesh)
        want = jax.vmap(lambda xm: stage_fn(W, xm))(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        print("OK")
    """)


def test_pipeline_grads_match_sequential():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train.pipeline import pipeline_apply, split_stages
        mesh = jax.make_mesh((4,), ("stage",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        L, d, n_micro, mb = 4, 8, 5, 2
        W = jax.random.normal(jax.random.PRNGKey(0), (L, d, d)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

        def stage_fn(w_group, xm):
            def body(x, w):
                return jnp.tanh(x @ w), None
            xm, _ = jax.lax.scan(body, xm, w_group)
            return xm

        def loss_pipe(W):
            y = pipeline_apply(stage_fn, split_stages(W, 4), x, mesh)
            return jnp.sum(y ** 2)

        def loss_seq(W):
            y = jax.vmap(lambda xm: stage_fn(W, xm))(x)
            return jnp.sum(y ** 2)

        g1 = jax.grad(loss_pipe)(W)
        g2 = jax.grad(loss_seq)(W)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-5)
        print("OK")
    """)
