"""Sharded index subsystem (DESIGN.md §5): placement/addressing, exact
top-k parity with the single-shard fused driver across shard counts and
boxes, mutation through global ids, and the checkpoint-manifest round trip
including save-at-S → load-at-S′ re-sharding.

Device-needing tests are in-process but skip unless the interpreter already
sees enough devices — the CI job `sharded-mesh` runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``. Two subprocess
tests (the test_distributed.py harness) cover the critical parity and
manifest paths on every tier-1 run regardless of the parent's device count.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs.base import BMOConfig
from repro.core import oracle
from repro.data.synthetic import make_knn_benchmark_data
from repro.index import placement as plc

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(prog: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c",
                          "import repro\n" + textwrap.dedent(prog)],
                         capture_output=True, text=True, env=env,
                         cwd=ROOT, timeout=timeout)
    assert out.returncode == 0 and "OK" in out.stdout, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"


def _devices(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=f"needs {n} devices (run under XLA_FLAGS="
               f"--xla_force_host_platform_device_count={n})")


# ---------------------------------------------------------------------------
# placement + addressing (host-side, any device count)
# ---------------------------------------------------------------------------


def test_round_robin_is_balanced_and_deterministic():
    sid = plc.assign_round_robin(10, 4)
    np.testing.assert_array_equal(sid, [0, 1, 2, 3, 0, 1, 2, 3, 0, 1])
    assert plc.balance(np.bincount(sid, minlength=4)) <= 1.5


def test_least_loaded_fills_valleys_first():
    sid = plc.assign_least_loaded([5, 0, 3, 5], 8)
    # shard 1 (load 0) takes the first three items to reach 3, then 1/2
    # alternate up to 5, then everyone round-robins
    loads = np.asarray([5, 0, 3, 5]) + np.bincount(sid, minlength=4)
    assert loads.max() - loads.min() <= 1
    assert sid[0] == 1


def test_assign_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown placement"):
        plc.assign("hash", [0, 0], 4)


def test_global_addressing_round_trip():
    stride = 128
    gid = plc.global_id(3, 17, stride)
    assert (plc.shard_of(gid, stride), plc.local_of(gid, stride)) == (3, 17)


def test_build_returns_consistent_global_ids():
    corpus, _ = make_knn_benchmark_data("dense", 50, 256, 2, seed=0)
    from repro.index import build_sharded_index
    cfg = BMOConfig(k=3, delta=0.05, block=32, batch_arms=8, metric="l2")
    store, gids = build_sharded_index(corpus, cfg, jax.random.PRNGKey(0),
                                      shards=1)
    assert store.n_shards == 1 and store.capacity == store.stride
    assert len(set(gids.tolist())) == 50
    # the addressed slot holds the row it claims to
    for i in (0, 13, 49):
        s, l = plc.shard_of(gids[i], store.stride), plc.local_of(
            gids[i], store.stride)
        row = np.asarray(store.shards[s].x)[l][:256]
        np.testing.assert_allclose(row, corpus[i], rtol=1e-6)


def test_stride_remap_contract():
    from repro.index.sharded import _stride_remap
    old_ids = _stride_remap(2, 4, 8)
    # shard 0 slots 0..3 keep ids 0..3; shard 1 slots 8..11 held 4..7
    np.testing.assert_array_equal(old_ids[:4], [0, 1, 2, 3])
    np.testing.assert_array_equal(old_ids[4:8], [-1] * 4)
    np.testing.assert_array_equal(old_ids[8:12], [4, 5, 6, 7])


def test_manifest_contents(tmp_path):
    from repro.index import build_sharded_index, save_sharded_index
    from repro.index.sharded import is_sharded_index_dir, read_manifest
    corpus, _ = make_knn_benchmark_data("dense", 40, 256, 2, seed=1)
    cfg = BMOConfig(k=2, delta=0.05, block=32, batch_arms=8, metric="l2")
    store, _ = build_sharded_index(corpus, cfg, jax.random.PRNGKey(0),
                                   shards=1)
    path = os.path.join(tmp_path, "idx")
    save_sharded_index(store, path)
    assert is_sharded_index_dir(path)
    m = read_manifest(path)
    assert m["n_shards"] == 1 and m["stride"] == store.stride
    assert m["kind"] == "dense" and m["live_per_shard"] == [40]
    assert m["placement"] == "round_robin"


def test_single_shard_store_parity_and_k_guard():
    """S=1 runs on any machine: the sharded driver must agree with the
    single-shard fused driver and enforce the same k-vs-live guard."""
    from repro.index import (build_index, build_sharded_index, index_knn,
                             sharded_delete)
    corpus, queries = make_knn_benchmark_data("dense", 200, 512, 3, seed=5)
    cfg = BMOConfig(k=3, delta=0.01, block=64, batch_arms=16, metric="l2")
    single = build_index(corpus, cfg, jax.random.PRNGKey(0))
    want = index_knn(single, queries, jax.random.PRNGKey(1), mode="fused")
    store, gids = build_sharded_index(corpus, cfg, jax.random.PRNGKey(0),
                                      shards=1)
    got = index_knn(store, queries, jax.random.PRNGKey(1))
    row_of = np.full(store.capacity, -1)
    row_of[gids] = np.arange(len(gids))
    rows = row_of[np.asarray(got.indices)]
    assert [set(r.tolist()) for r in rows] == \
        [set(np.asarray(want.indices[i]).tolist()) for i in range(3)]
    assert got.shard_rounds.shape == (1,)

    store = sharded_delete(store, gids[: 198])
    with pytest.raises(ValueError, match="live slots"):
        index_knn(store, queries, jax.random.PRNGKey(2))


# ---------------------------------------------------------------------------
# parity across shard counts (needs devices; runs in the sharded-mesh CI job)
# ---------------------------------------------------------------------------


@_devices(8)
@pytest.mark.parametrize("shards", [2, 4, 8])
@pytest.mark.parametrize("mode", ["fused", "rounds"])
def test_sharded_parity_dense(shards, mode):
    from repro.index import build_index, build_sharded_index, index_knn
    corpus, queries = make_knn_benchmark_data("dense", 400, 1024, 6, seed=1)
    cfg = BMOConfig(k=3, delta=0.01, block=64, batch_arms=16,
                    pulls_per_round=2, metric="l2")
    single = build_index(corpus, cfg, jax.random.PRNGKey(0))
    want = index_knn(single, queries, jax.random.PRNGKey(1), mode="fused")
    ex = oracle.exact_knn(corpus, queries, 3, "l2")
    store, gids = build_sharded_index(corpus, cfg, jax.random.PRNGKey(0),
                                      shards=shards)
    res = index_knn(store, queries, jax.random.PRNGKey(1), mode=mode)
    row_of = np.full(store.capacity, -1)
    row_of[gids] = np.arange(len(gids))
    rows = [set(r.tolist()) for r in row_of[np.asarray(res.indices)]]
    assert rows == [set(np.asarray(want.indices[i]).tolist())
                    for i in range(6)]
    assert rows == [set(np.asarray(ex.indices[i]).tolist()) for i in range(6)]
    # merged values are exact θ, ascending
    vals = np.asarray(res.values)
    assert (np.diff(vals, axis=1) >= -1e-6).all()
    np.testing.assert_allclose(np.sort(vals, 1),
                               np.asarray(ex.values), rtol=1e-4, atol=1e-5)
    assert res.shard_rounds.shape == (shards,)
    assert float(np.asarray(res.coord_ops).sum()) > 0


@_devices(4)
def test_sharded_parity_rotated():
    from repro.index import build_index, build_sharded_index, index_knn
    corpus, queries = make_knn_benchmark_data("dense", 300, 512, 4, seed=2)
    cfg = BMOConfig(k=3, delta=0.01, block=64, batch_arms=16, metric="l2",
                    rotate=True)
    single = build_index(corpus, cfg, jax.random.PRNGKey(0))
    want = index_knn(single, queries, jax.random.PRNGKey(1), mode="fused")
    store, gids = build_sharded_index(corpus, cfg, jax.random.PRNGKey(0),
                                      shards=4)
    res = index_knn(store, queries, jax.random.PRNGKey(1))
    row_of = np.full(store.capacity, -1)
    row_of[gids] = np.arange(len(gids))
    rows = [set(r.tolist()) for r in row_of[np.asarray(res.indices)]]
    assert rows == [set(np.asarray(want.indices[i]).tolist())
                    for i in range(4)]


@_devices(4)
def test_sharded_parity_sparse():
    from repro.core.datasets import SparseDataset
    from repro.data.synthetic import clustered_sparse
    from repro.index import build_index, build_sharded_index, index_knn
    corpus = clustered_sparse(200, 2048, seed=4)
    ds = SparseDataset.build(corpus)
    queries = (ds.indices[:4], ds.values[:4], ds.nnz[:4])
    cfg = BMOConfig(k=3, delta=0.01, block=1, batch_arms=16,
                    pulls_per_round=8, init_pulls=16, metric="l1", sparse=True)
    single = build_index(corpus, cfg, jax.random.PRNGKey(0))
    want = index_knn(single, queries, jax.random.PRNGKey(5))
    store, gids = build_sharded_index(corpus, cfg, jax.random.PRNGKey(0),
                                      shards=4)
    res = index_knn(store, queries, jax.random.PRNGKey(5))
    row_of = np.full(store.capacity, -1)
    row_of[gids] = np.arange(len(gids))
    rows = [set(r.tolist()) for r in row_of[np.asarray(res.indices)]]
    assert rows == [set(np.asarray(want.indices[i]).tolist())
                    for i in range(4)]


@_devices(4)
def test_sharded_mutation_insert_delete_compact():
    """Full lifecycle through global ids: delete the certified NN, insert a
    closer point (least-loaded routing), auto-compact with payload remap —
    top-k stays exact at every step."""
    from repro.index import (build_sharded_index, index_knn, sharded_delete,
                             sharded_insert, sharded_maybe_compact)
    corpus, queries = make_knn_benchmark_data("dense", 200, 512, 3, seed=11)
    cfg = BMOConfig(k=3, delta=0.01, block=64, batch_arms=16, metric="l2")
    ex = oracle.exact_knn(corpus, queries, 3, "l2")
    store, gids = build_sharded_index(corpus, cfg, jax.random.PRNGKey(0),
                                      shards=4)
    kill_rows = np.asarray(ex.indices[0])[:2]
    store = sharded_delete(store, gids[kill_rows])
    res = index_knn(store, queries, jax.random.PRNGKey(1))
    killed = set(gids[kill_rows].tolist())
    for row in np.asarray(res.indices):
        assert not (set(row.tolist()) & killed)

    store, ins, grow_ids = sharded_insert(store, queries + 1e-3)
    res = index_knn(store, queries, jax.random.PRNGKey(2))
    for i in range(len(queries)):
        assert int(np.asarray(res.indices[i])[0]) == int(ins[i])

    # least-loaded routing: the shards that lost slots get refilled first
    live = store.live_per_shard
    assert max(live) - min(live) <= 1

    # tombstone most of the corpus → auto-compaction shrinks the stride
    dead_rows = [r for r in range(40, 200)
                 if int(gids[r]) not in set(ins.tolist())]
    store = sharded_delete(store, gids[dead_rows])
    before = index_knn(store, queries, jax.random.PRNGKey(3))
    store2, old_ids = sharded_maybe_compact(store, threshold=0.5)
    assert old_ids is not None and store2.stride < store.stride
    after = index_knn(store2, queries, jax.random.PRNGKey(3))
    remapped = [set(int(old_ids[j]) for j in row)
                for row in np.asarray(after.indices)]
    assert remapped == [set(r.tolist()) for r in np.asarray(before.indices)]


@_devices(8)
@pytest.mark.parametrize("kind_cfg", [
    ("dense", dict(metric="l2", block=64)),
    ("rotated", dict(metric="l2", block=64, rotate=True)),
    ("sparse", dict(metric="l1", block=1, pulls_per_round=8, init_pulls=16,
                    sparse=True)),
])
@pytest.mark.parametrize("s_new", [2, 8])
def test_manifest_round_trip_reshard(tmp_path, kind_cfg, s_new):
    """build at S=4 → mutate → save → load at S′ ∈ {2, 8} → exact parity
    with the pre-save results through the returned global-id remap."""
    from repro.core.datasets import SparseDataset
    from repro.data.synthetic import clustered_sparse
    from repro.index import (build_sharded_index, index_knn,
                             load_sharded_index, save_sharded_index,
                             sharded_delete, sharded_insert)
    kind, kw = kind_cfg
    cfg = BMOConfig(k=3, delta=0.01, batch_arms=16, **kw)
    if kind == "sparse":
        corpus = clustered_sparse(120, 512, seed=3)
        ds = SparseDataset.build(corpus)
        queries = (ds.indices[:2], ds.values[:2], ds.nnz[:2])
    else:
        corpus, queries = make_knn_benchmark_data("dense", 120, 256, 2, seed=3)
    store, gids = build_sharded_index(corpus, cfg, jax.random.PRNGKey(0),
                                      shards=4)
    store = sharded_delete(store, gids[[7, 19, 64]])
    if kind != "sparse":
        store, _, _ = sharded_insert(store, np.asarray(corpus[:2]) * 0.5)
    path = os.path.join(tmp_path, "idx")
    save_sharded_index(store, path)
    want = index_knn(store, queries, jax.random.PRNGKey(7))

    loaded, none_ids = load_sharded_index(path)
    assert none_ids is None and loaded.n_shards == 4
    same = index_knn(loaded, queries, jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(same.indices),
                                  np.asarray(want.indices))

    res2, old_ids = load_sharded_index(path, shards=s_new)
    assert res2.n_shards == s_new and res2.n_live == store.n_live
    got = index_knn(res2, queries, jax.random.PRNGKey(7))
    remapped = [set(int(old_ids[j]) for j in row)
                for row in np.asarray(got.indices)]
    assert remapped == [set(r.tolist()) for r in np.asarray(want.indices)]
    np.testing.assert_allclose(np.sort(np.asarray(got.values), 1),
                               np.sort(np.asarray(want.values), 1),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# subprocess coverage for single-device tier-1 runs
# ---------------------------------------------------------------------------


def test_sharded_parity_subprocess():
    """Dense + rotated parity at S=2 on a forced 2-device host mesh — runs
    on every tier-1 invocation regardless of the parent's device count."""
    _run("""
        import jax, numpy as np
        from repro.configs.base import BMOConfig
        from repro.core import oracle
        from repro.data.synthetic import make_knn_benchmark_data
        from repro.index import build_sharded_index, index_knn
        corpus, queries = make_knn_benchmark_data("dense", 256, 512, 4, seed=1)
        ex = oracle.exact_knn(corpus, queries, 3, "l2")
        for kw in (dict(), dict(rotate=True)):
            cfg = BMOConfig(k=3, delta=0.01, block=64, batch_arms=16,
                            pulls_per_round=2, metric="l2", **kw)
            store, gids = build_sharded_index(corpus, cfg,
                                              jax.random.PRNGKey(0), shards=2)
            res = index_knn(store, queries, jax.random.PRNGKey(1))
            row_of = np.full(store.capacity, -1)
            row_of[gids] = np.arange(len(gids))
            rows = row_of[np.asarray(res.indices)]
            acc = np.mean([set(rows[i].tolist())
                           == set(np.asarray(ex.indices[i]).tolist())
                           for i in range(4)])
            assert acc == 1.0, (kw, acc)
        print("OK")
    """, devices=2)


def test_manifest_reshard_subprocess(tmp_path):
    """Save at S=2 → load at S′=4 → parity through the remap (dense)."""
    _run(f"""
        import jax, numpy as np
        from repro.configs.base import BMOConfig
        from repro.data.synthetic import make_knn_benchmark_data
        from repro.index import (build_sharded_index, index_knn,
                                 load_sharded_index, save_sharded_index,
                                 sharded_delete)
        corpus, queries = make_knn_benchmark_data("dense", 128, 256, 2, seed=3)
        cfg = BMOConfig(k=3, delta=0.01, block=32, batch_arms=16, metric="l2")
        store, gids = build_sharded_index(corpus, cfg, jax.random.PRNGKey(0),
                                          shards=2)
        store = sharded_delete(store, gids[[3, 50]])
        want = index_knn(store, queries, jax.random.PRNGKey(7))
        path = r"{str(tmp_path)}/idx"
        save_sharded_index(store, path)
        st2, old_ids = load_sharded_index(path, shards=4)
        got = index_knn(st2, queries, jax.random.PRNGKey(7))
        remapped = [set(int(old_ids[j]) for j in row)
                    for row in np.asarray(got.indices)]
        assert remapped == [set(r.tolist())
                            for r in np.asarray(want.indices)], remapped
        print("OK")
    """, devices=4)
